//! Multi-worker router: each worker is a dedicated OS thread owning its own
//! backend (PJRT engines are `Rc`-based and thread-pinned) plus a
//! [`SamplerSet`] — one sampler per lowered batch bucket — all pulling
//! batches from the shared [`Batcher`] queue. Work-stealing via a single
//! MPMC queue gives least-loaded dispatch for free.
//!
//! ## Bucket routing
//!
//! The batcher forms batches of 1..=max-bucket real slots; the worker picks
//! the **smallest bucket covering the batch** and pads only the gap to that
//! bucket. Padding is real decode work (a padded slot costs as much as a
//! real one), so it is tracked in the `sjd_padded_slots` counter and the
//! per-bucket `sjd_bucket_{B}_batches` counters — the load bench and the
//! serving tests assert on both.
//!
//! ## Multi-in-flight scheduling (`RouterConfig::pipeline_depth`)
//!
//! At depth ≤ 1 a worker is the classic monolithic loop: pull a batch,
//! decode it end to end, complete its slots, repeat — one batch in flight
//! per worker. At depth ≥ 2 the worker becomes a **feeder** over a
//! `coordinator::pipeline::DecodePipeline`: it keeps pulling batches while
//! earlier ones are still mid-decode, so batch B occupies stage 0 while
//! batch A is in stage 1 (block-level pipelining; the pipeline's depth gate
//! backpressures the feeder, which backpressures the batcher queue). Slot
//! completion then happens on the pipeline's final-stage thread via the
//! job's completion callback. Output bits are identical either way.
//!
//! With [`RouterConfig::refill`] (`serve --refill`) the worker instead runs
//! a `coordinator::pipeline::ContinuousPipeline`: batch membership opens at
//! every block boundary — stage 0 refills drained slots from the queue,
//! boundaries sweep cancelled slots and migrate shrinking waves to smaller
//! buckets, and straggler waves merge instead of padding. Output bits are
//! *still* identical: each slot's prior comes from its own seed stream, so
//! its τ=0 image equals a solo serial decode regardless of which waves it
//! rode through.
//!
//! ## Replica tier & device spread (`RouterConfig::replicas` / `devices`)
//!
//! With `serve --replicas R` (R ≥ 2) the router runs R **independent
//! pipelines** behind the one bounded [`Batcher`]: one supervised worker per
//! replica, each with its own engines, gated by a shared [`DispatchBoard`]
//! so the replica with the fewest waves in flight pulls the next batch —
//! least-loaded dispatch weighted by actual in-flight work, not round-robin,
//! so a slow replica sheds load to its peers instead of head-of-line
//! blocking the queue. A replica lost past the restart budget is retired
//! from the board and drains through the existing [`FleetStatus`] /
//! `/healthz` path. `serve --devices N` spreads work across addressable
//! device ordinals: pipelined stage spans are placed contiguously via
//! [`super::pipeline::device_placement`], while monolithic workers (and
//! replicas) round-robin whole engines across ordinals. Per-replica load is
//! exported as `sjd_replica_{r}_inflight`.
//!
//! ## Online tuning (`RouterConfig::tuner`)
//!
//! With a [`PolicyTuner`] attached (`serve --tune`), every batch decodes
//! under `tuner.policy_for(bucket)` instead of the static configured
//! policy, and every decode's per-block traces feed `tuner.observe` — the
//! measurement the decode already produced, so closing the calibration
//! loop costs nothing extra on the hot path.
//!
//! ## Metrics
//!
//! Per batch: `sjd_batch_fill` (real slots), `sjd_decode_time`,
//! `sjd_batches_processed`, `sjd_bucket_{B}_batches`, `sjd_padded_slots`.
//! Per slot: `sjd_queue_wait` (submit → decode start; submit → pipeline
//! entry at depth ≥ 2) and `sjd_request_latency` (submit → image ready).
//! `sjd_encode_time` is recorded by the HTTP layer's encode jobs (see
//! `coordinator::server`). Per decoded block: `sjd_block_iters` (decode
//! steps) and `sjd_host_syncs` (blocking host syncs, see
//! `BlockTrace::host_syncs`) — together they expose per-request convergence
//! behavior and how well the fused chunked decode is amortizing its τ-test
//! round-trips. The pipelined path adds `sjd_stage_{t}_occupancy` and
//! `sjd_stage_wait` (see `coordinator::pipeline`).
//!
//! Speculative init (`--init proj|warm|draft`) adds `sjd_spec_init_hits`
//! (blocks whose fixed-point iteration started from a provider guess
//! instead of zeros) and, when tuned, `sjd_spec_wasted_updates` (position
//! updates a speculative decode spent *beyond* the tuner's zeros baseline —
//! the realized cost of speculation that did not pay; see
//! `PolicyTuner::observe`). With a tuner attached, each batch's init
//! strategy comes from `tuner.init_for(bucket)`, which falls back to zeros
//! per bucket when realized savings go negative.

use super::batcher::{Batcher, Slot};
use super::fault::{panic_msg, FaultPolicy, FaultTolerantBackend, Watchdog};
use super::jacobi::InitStrategy;
use super::pipeline::{
    ContinuousPipeline, DecodePipeline, PipelineConfig, PipelineJob, PipelineResult,
};
use super::policy::{OverloadGovernor, PolicyTuner};
use super::sampler::{SampleOptions, SamplerSet};
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::runtime::{classify, Backend, Engine, FaultClass, Manifest};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    /// Decode buckets to serve, ascending. Empty = every *complete* lowered
    /// per-batch artifact family ([`Router::start`] resolves it via
    /// `Manifest::decode_buckets`; the backend-generic
    /// [`Router::start_with`] falls back to `ModelMeta::batch_sizes`).
    pub buckets: Vec<usize>,
    pub workers: usize,
    pub options: SampleOptions,
    /// Batches each worker keeps in flight: ≤ 1 = monolithic single-batch
    /// decode (one engine per worker); ≥ 2 = stage-graph pipelining with
    /// this depth (one engine per *stage* thread — see the module docs).
    pub pipeline_depth: usize,
    /// Stage-executor threads per pipelined worker (0 = one per flow
    /// block, the maximum overlap; fewer threads bound the per-worker
    /// engine count at the cost of coarser overlap). Ignored at depth ≤ 1.
    pub stage_threads: usize,
    /// Online policy autotuner shared by every worker (`serve --tune`);
    /// `None` serves the static `options.policy`.
    pub tuner: Option<Arc<PolicyTuner>>,
    /// Warm-start cache bound per sampler (`--init warm:N`); `0` keeps the
    /// buffer pool's built-in default.
    pub warm_cap: usize,
    /// Continuous batching (`serve --refill`): workers run a
    /// [`ContinuousPipeline`] — waves refill drained slots from the queue
    /// at stage 0, sweep cancelled slots and migrate to smaller buckets at
    /// every block boundary, and merge straggler waves instead of padding
    /// them. Takes precedence over `pipeline_depth`'s feeder mode (the
    /// continuous pipeline is inherently multi-in-flight); the tuner is
    /// not consulted (wave membership changes mid-decode, so there is no
    /// stable per-batch bucket to tune against).
    pub refill: bool,
    /// Quality-elastic overload governor (`serve --elastic`), shared by
    /// every worker: each decode observes queue depth and completion
    /// latency, and decodes under the governor's current degradation-ladder
    /// options ([`OverloadGovernor::apply`] — a passthrough clone at level
    /// 0, so the healthy path stays bit-exact). Composes with the tuner:
    /// the ladder coarsens whatever policy the tuner picked.
    pub governor: Option<Arc<OverloadGovernor>>,
    /// Fault-tolerance policy: every worker's backend is wrapped in a
    /// [`FaultTolerantBackend`] (transient-fault retry with capped backoff
    /// budgeted against slot deadlines, per-artifact quarantine breakers),
    /// hung dispatches are failed by a per-call [`Watchdog`], and panicked
    /// or device-lost workers are respawned with a fresh engine up to
    /// `fault.worker_restarts` times (see the supervisor in `start_with`).
    pub fault: FaultPolicy,
    /// Independent decode pipelines behind the one bounded batcher
    /// (`serve --replicas R`): ≤ 1 is the classic worker fleet; ≥ 2 spawns
    /// one supervised worker per replica (overriding `workers`) and gates
    /// batcher pulls through a least-loaded [`DispatchBoard`] — the replica
    /// with the fewest waves in flight pulls next (in-flight-weighted, not
    /// round-robin). A replica retired past the restart budget leaves the
    /// board and drains via [`FleetStatus`]/`/healthz`. Under `refill` the
    /// continuous pipelines self-balance through their bounded stage-0
    /// queues instead of the board.
    pub replicas: usize,
    /// Addressable device ordinals to spread work across (`serve --devices
    /// N`): pipelined stage spans are placed contiguously onto ordinals via
    /// [`super::pipeline::device_placement`]; monolithic workers (and
    /// replicas) round-robin whole engines across ordinals (`widx %
    /// devices`). ≤ 1 keeps everything on ordinal 0, the legacy
    /// single-device layout. Ordinals beyond what the platform actually
    /// exposes fail fast at engine construction.
    pub devices: usize,
}

/// Least-loaded replica dispatch (`RouterConfig::replicas` ≥ 2): each
/// replica's batcher pulls are gated on it being among the least-loaded
/// *live* replicas by waves in flight. Ties proceed, so a fresh fleet
/// starts pulling immediately, and because the minimum is always attained
/// by some live replica, at least one replica can always pull — the gate
/// cannot deadlock the queue. Retired replicas (restart budget exhausted,
/// or drained at shutdown) leave the minimum computation so an idle corpse
/// cannot pin it at zero.
pub(crate) struct DispatchBoard {
    state: std::sync::Mutex<BoardState>,
    wake: std::sync::Condvar,
}

struct BoardState {
    inflight: Vec<usize>,
    dead: Vec<bool>,
}

impl DispatchBoard {
    fn new(replicas: usize) -> Arc<Self> {
        Arc::new(DispatchBoard {
            state: std::sync::Mutex::new(BoardState {
                inflight: vec![0; replicas],
                dead: vec![false; replicas],
            }),
            wake: std::sync::Condvar::new(),
        })
    }

    /// Block until replica `r` is least-loaded among live replicas (ties
    /// proceed). The timeout re-check keeps the wait robust to a wake
    /// racing a queue close — the caller's next `next_batch` resolves
    /// shutdown either way.
    fn wait_turn(&self, r: usize) {
        let mut st = self.state.lock().unwrap();
        loop {
            let min = st
                .inflight
                .iter()
                .zip(&st.dead)
                .filter(|(_, dead)| !**dead)
                .map(|(n, _)| *n)
                .min();
            match min {
                Some(m) if !st.dead[r] && st.inflight[r] > m => {
                    st = self
                        .wake
                        .wait_timeout(st, Duration::from_millis(50))
                        .unwrap()
                        .0;
                }
                _ => return,
            }
        }
    }

    fn wave_started(&self, r: usize) {
        self.state.lock().unwrap().inflight[r] += 1;
    }

    fn wave_done(&self, r: usize) {
        let mut st = self.state.lock().unwrap();
        st.inflight[r] = st.inflight[r].saturating_sub(1);
        drop(st);
        self.wake.notify_all();
    }

    fn retire(&self, r: usize) {
        let mut st = self.state.lock().unwrap();
        st.dead[r] = true;
        drop(st);
        self.wake.notify_all();
    }
}

/// One replica's handle onto the shared [`DispatchBoard`], plus its
/// `sjd_replica_{r}_inflight` gauge. Cloned into pipelined completion
/// callbacks so the wave decrement runs wherever the wave actually
/// finishes (the final-stage thread), not where it was submitted.
#[derive(Clone)]
pub(crate) struct ReplicaSlot {
    board: Arc<DispatchBoard>,
    r: usize,
    gauge: Arc<Gauge>,
}

impl ReplicaSlot {
    fn wait_turn(&self) {
        self.board.wait_turn(self.r);
    }

    fn started(&self) {
        self.board.wave_started(self.r);
        self.gauge.add(1);
    }

    fn done(&self) {
        self.board.wave_done(self.r);
        self.gauge.add(-1);
    }

    fn retire(&self) {
        self.board.retire(self.r);
    }
}

/// RAII wave accounting for the monolithic worker: the decrement fires on
/// every exit path — including the unwind the supervisor catches — so a
/// lost incarnation never leaves its replica looking loaded on the board.
struct WaveGuard<'a>(&'a ReplicaSlot);

impl<'a> WaveGuard<'a> {
    fn begin(slot: &'a ReplicaSlot) -> Self {
        slot.started();
        WaveGuard(slot)
    }
}

impl Drop for WaveGuard<'_> {
    fn drop(&mut self) {
        self.0.done();
    }
}

/// Live-vs-configured worker accounting, surfaced by `/healthz` (a degraded
/// fleet — fewer live workers than configured — answers non-200 so load
/// balancers can drain the replica before it wedges). `live` counts
/// supervisor threads, so a worker mid-respawn still counts as live; only a
/// *retired* worker (restart budget exhausted, or startup failure) drops it.
#[derive(Clone)]
pub struct FleetStatus {
    configured: usize,
    live: Arc<AtomicUsize>,
}

impl FleetStatus {
    fn new(configured: usize) -> Self {
        FleetStatus { configured, live: Arc::new(AtomicUsize::new(0)) }
    }

    /// Workers the router was started with.
    pub fn configured(&self) -> usize {
        self.configured
    }

    /// Worker supervisors currently running (== configured when healthy).
    pub fn live(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// True when at least one worker has been permanently lost.
    pub fn degraded(&self) -> bool {
        self.live() < self.configured
    }
}

/// Running worker fleet.
pub struct Router {
    pub batcher: Batcher,
    pub registry: Registry,
    workers: Vec<JoinHandle<()>>,
    fleet: FleetStatus,
}

/// Why a worker body returned. The supervisor loop in [`Router::start_with`]
/// maps these (plus caught panics) to respawn-or-retire decisions.
enum WorkerExit {
    /// The closed queue drained — normal shutdown.
    Drained,
    /// Engine/sampler construction failed. On first startup the error was
    /// reported through the readiness channel (and `start_with` fails); on a
    /// respawn it consumes restart budget like any other loss.
    StartupFailed,
    /// The engine is gone or untrustworthy (a `DeviceLost`-classified decode
    /// error, a fired watchdog, or a lost pipeline stage): every in-flight
    /// slot has been resolved `Err`; respawn with a fresh engine.
    DeviceLost,
}

impl Router {
    /// Spawn `cfg.workers` worker threads over real PJRT engines. Each
    /// validates its engine before the router returns (fail-fast on bad
    /// artifacts). Empty `cfg.buckets` resolves through
    /// [`Manifest::decode_buckets`], so an incomplete per-batch artifact
    /// family on disk is excluded instead of failing worker startup.
    pub fn start(mut cfg: RouterConfig, batcher: Batcher, registry: Registry) -> Result<Self> {
        if cfg.buckets.is_empty() {
            let manifest = Manifest::load(cfg.artifacts_dir.join("manifest.json"))?;
            cfg.buckets = manifest.decode_buckets(&cfg.model);
        }
        let dir = cfg.artifacts_dir.clone();
        Self::start_with_devices(cfg, batcher, registry, move |_widx, ordinal| {
            Engine::new_on(&dir, ordinal)
        })
    }

    /// Spawn workers over any backend. The factory runs *inside* each worker
    /// thread (backends may be thread-pinned, like the PJRT engine), so it
    /// must be `Send + Clone` but the backend itself need not be `Send`.
    /// This is the seam the mock-backend serving tests and the load bench
    /// plug into. The factory sees only the worker index; backends that care
    /// about device placement use [`Router::start_with_devices`] instead.
    pub fn start_with<B, F>(
        cfg: RouterConfig,
        batcher: Batcher,
        registry: Registry,
        factory: F,
    ) -> Result<Self>
    where
        B: Backend,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        Self::start_with_devices(cfg, batcher, registry, move |widx, _ordinal| factory(widx))
    }

    /// Spawn workers over any backend, with device placement: the factory
    /// receives `(worker index, device ordinal)` — the ordinal is the
    /// placement the backend instance should pin to (a pipelined worker
    /// calls it once per stage thread with that span's placed ordinal; a
    /// monolithic worker calls it once with `widx % devices`). This is the
    /// primary entry; [`Router::start_with`] and [`Router::start`] are thin
    /// wrappers over it.
    pub fn start_with_devices<B, F>(
        cfg: RouterConfig,
        batcher: Batcher,
        registry: Registry,
        factory: F,
    ) -> Result<Self>
    where
        B: Backend,
        F: Fn(usize, usize) -> Result<B> + Send + Clone + 'static,
    {
        // Replica tier: R ≥ 2 overrides the worker count — one supervised
        // worker per replica — and (outside continuous mode, which
        // self-balances through its bounded stage-0 queues) gates batcher
        // pulls through the least-loaded dispatch board.
        let nworkers = if cfg.replicas >= 2 { cfg.replicas } else { cfg.workers.max(1) };
        let board = (cfg.replicas >= 2 && !cfg.refill).then(|| DispatchBoard::new(nworkers));
        let mut workers = Vec::with_capacity(nworkers);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let fleet = FleetStatus::new(nworkers);

        let refill = cfg.refill;
        let pipelined = cfg.pipeline_depth >= 2;
        for widx in 0..nworkers {
            let cfg = cfg.clone();
            let batcher = batcher.clone();
            let registry = registry.clone();
            let ready = ready_tx.clone();
            let factory = factory.clone();
            let live = fleet.live.clone();
            let board = board.clone();
            // Supervisor loop: run the worker body under `catch_unwind`; a
            // panic or a DeviceLost exit respawns the body — the factory
            // runs again inside this same thread, building a fresh engine —
            // up to `fault.worker_restarts` times. In-flight slots of the
            // lost incarnation are already resolved `Err` (the completion
            // guard on `Slot` fires during unwind), so a respawn never
            // strands a waiter. Readiness is reported exactly once, from the
            // first incarnation.
            let body = move || {
                live.fetch_add(1, Ordering::SeqCst);
                let m_panics = registry.counter("sjd_worker_panics");
                let m_restarts = registry.counter("sjd_worker_restarts");
                // Replica handle onto the dispatch board (replicas ≥ 2
                // only): gates this worker's batcher pulls on it being
                // least-loaded, and exports `sjd_replica_{r}_inflight`.
                let replica = board.as_ref().map(|b| ReplicaSlot {
                    board: b.clone(),
                    r: widx,
                    gauge: registry.gauge(&format!("sjd_replica_{widx}_inflight")),
                });
                let mut ready = Some(ready);
                let mut restarts_left = cfg.fault.worker_restarts;
                let mut first = true;
                loop {
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if refill {
                            worker_continuous(widx, &cfg, &batcher, &registry, &mut ready, &factory)
                        } else if pipelined {
                            worker_pipelined(
                                widx, &cfg, &batcher, &registry, &mut ready, &factory, &replica,
                            )
                        } else {
                            worker_main(
                                widx, &cfg, &batcher, &registry, &mut ready, &factory, &replica,
                            )
                        }
                    }));
                    let exit = match run {
                        Ok(exit) => exit,
                        Err(p) => {
                            m_panics.inc();
                            log::error!("worker {widx} panicked mid-decode: {}", panic_msg(&p));
                            WorkerExit::DeviceLost
                        }
                    };
                    match exit {
                        WorkerExit::Drained => break,
                        // First-start failure already failed `start_with`
                        // through the readiness channel; nothing to respawn.
                        WorkerExit::StartupFailed if first => break,
                        WorkerExit::StartupFailed | WorkerExit::DeviceLost => {
                            if restarts_left == 0 {
                                log::error!(
                                    "worker {widx} retired: restart budget ({}) exhausted",
                                    cfg.fault.worker_restarts
                                );
                                break;
                            }
                            restarts_left -= 1;
                            m_restarts.inc();
                            log::warn!(
                                "worker {widx} respawning with a fresh engine ({restarts_left} restarts left)"
                            );
                        }
                    }
                    first = false;
                }
                // Retire from the dispatch board on every permanent exit —
                // budget exhaustion AND a clean drain — so an idle ex-replica
                // never pins the board minimum at zero while peers still
                // have waves to finish.
                if let Some(rep) = &replica {
                    rep.retire();
                }
                live.fetch_sub(1, Ordering::SeqCst);
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sjd-worker-{widx}"))
                    .spawn(body)
                    .expect("spawn worker"),
            );
        }
        drop(ready_tx);
        for _ in 0..nworkers {
            ready_rx.recv().expect("worker startup signal")?;
        }
        Ok(Router { batcher, registry, workers, fleet })
    }

    /// Live-vs-configured worker accounting for `/healthz`.
    pub fn fleet(&self) -> FleetStatus {
        self.fleet.clone()
    }

    /// Stop workers: close the queue (new submissions fail fast, see
    /// [`Batcher::submit`]), let workers drain what is already queued, then
    /// join them. A worker thread that died on an escaped panic (the
    /// supervisor catches decode-path panics, so this is the supervisor
    /// itself failing) is logged and counted in `sjd_worker_panics` instead
    /// of being silently swallowed.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            if let Err(p) = w.join() {
                self.registry.counter("sjd_worker_panics").inc();
                log::error!("worker thread died on an escaped panic: {}", panic_msg(&p));
            }
        }
    }
}


/// Report startup failure through the (one-shot) readiness channel.
fn ready_err(ready: &mut Option<std::sync::mpsc::Sender<Result<()>>>, e: anyhow::Error) {
    if let Some(tx) = ready.take() {
        let _ = tx.send(Err(e));
    } else {
        // Respawn startup failure: `start_with` returned long ago; the
        // supervisor's restart budget decides what happens next.
        log::error!("worker respawn startup failed: {e:#}");
    }
}

fn worker_main<B, F>(
    widx: usize,
    cfg: &RouterConfig,
    batcher: &Batcher,
    registry: &Registry,
    ready: &mut Option<std::sync::mpsc::Sender<Result<()>>>,
    factory: &F,
    replica: &Option<ReplicaSlot>,
) -> WorkerExit
where
    B: Backend,
    F: Fn(usize, usize) -> Result<B>,
{
    // Build the thread-pinned backend + per-bucket samplers; report readiness.
    // The engine is wrapped in the fault-tolerant layer: transient retries,
    // per-artifact quarantine (its `has_artifact` is what the samplers'
    // live `effective_block_mode` lookups consult), deadline-budgeted
    // backoff through the shared cell below.
    //
    // Monolithic workers own one whole engine, so device spread is at
    // engine granularity: worker/replica `widx` pins to ordinal
    // `widx % devices` (stage-span placement is the pipelined paths' job).
    let ordinal = if cfg.devices > 1 { widx % cfg.devices } else { 0 };
    let engine = match factory(widx, ordinal) {
        Ok(e) => FaultTolerantBackend::new(e, cfg.fault.clone(), registry),
        Err(e) => {
            ready_err(ready, e);
            return WorkerExit::StartupFailed;
        }
    };
    let deadline = engine.deadline_cell();
    let set = match SamplerSet::new(&engine, &cfg.model, &cfg.buckets) {
        Ok(s) => s,
        Err(e) => {
            ready_err(ready, e);
            return WorkerExit::StartupFailed;
        }
    };
    set.set_warm_cap(cfg.warm_cap);
    if let Some(tx) = ready.take() {
        let _ = tx.send(Ok(()));
    }
    let dog = cfg.fault.watchdog.map(|_| Watchdog::new(registry));

    let lat = registry.histogram("sjd_request_latency");
    let queue_wait = registry.histogram("sjd_queue_wait");
    let decode_time = registry.histogram("sjd_decode_time");
    let block_iters = registry.histogram("sjd_block_iters");
    let host_syncs = registry.histogram("sjd_host_syncs");
    let batch_fill = registry.histogram("sjd_batch_fill");
    let images = registry.counter("sjd_images_generated");
    let batches = registry.counter("sjd_batches_processed");
    let padded = registry.counter("sjd_padded_slots");
    let errors = registry.counter("sjd_worker_errors");
    let inflight = registry.gauge("sjd_batches_inflight");
    let spec_hits = registry.counter("sjd_spec_init_hits");
    let spec_wasted = registry.counter("sjd_spec_wasted_updates");
    let deadline_expired = registry.counter("sjd_deadline_expired");

    // Workers exit when the closed queue drains (`next_batch` → None), so a
    // shutdown never abandons an accepted slot. The loop lives in an
    // immediately-invoked closure so every exit path (drain, watchdog fire,
    // device loss) funnels through the single watchdog teardown below.
    let exit = (|| {
    loop {
        // Replica tier: pull only while least-loaded (ties proceed). The
        // wave guard balances the board on every exit path below.
        if let Some(rep) = replica {
            rep.wait_turn();
        }
        let Some(batch) = batcher.next_batch() else { break };
        let _wave = replica.as_ref().map(WaveGuard::begin);
        inflight.add(1);
        batch_fill.record(batch.slots.len() as u64);
        // Every slot MUST complete: an oversized batch (a batcher formed
        // past the largest bucket — a misconfiguration, but a recoverable
        // one) is decoded in max-bucket chunks instead of silently dropping
        // the slots the zip below would not cover.
        let mut slots = batch.slots;
        while !slots.is_empty() {
            // Deadline enforcement at chunk formation: a slot whose
            // deadline passed while earlier chunks decoded resolves 504
            // here instead of burning a decode it can no longer use.
            slots.retain(|s| {
                if s.expired() {
                    deadline_expired.inc();
                    s.resolve_expired("batch formation");
                    false
                } else {
                    true
                }
            });
            if slots.is_empty() {
                break;
            }
            let take = slots.len().min(set.max_bucket());
            let chunk: Vec<_> = slots.drain(..take).collect();
            // Smallest lowered bucket covering the chunk; pad only up to it.
            let sampler = set.select(chunk.len());
            padded.add(sampler.batch.saturating_sub(chunk.len()) as u64);
            registry.counter(&format!("sjd_bucket_{}_batches", sampler.batch)).inc();
            for slot in &chunk {
                queue_wait.record_duration(slot.enqueued.elapsed());
            }
            // Per-slot RNG streams: row i's prior comes from slot i's own
            // seed (`Sampler::sample_prior_slots`), so a request's image is
            // a pure function of its seed — batch position, padding, which
            // worker picked it up, or a later refill/migration can never
            // change which image a request gets.
            let seeds: Vec<u64> = chunk.iter().map(|s| s.seed).collect();
            // Live-tuned policy (serve --tune): decode this batch under the
            // tuner's current per-block modes for its bucket; the traces
            // feed back below — the measurement is the decode itself.
            let mut options = cfg.options.clone();
            if let Some(tuner) = &cfg.tuner {
                options.policy = tuner.policy_for(sampler.batch);
                // Tuner-gated speculation: the bucket's init provider, or
                // zeros while the bucket is reverted / being baselined.
                options.jacobi.init = tuner.init_for(sampler.batch);
            }
            // Overload governor (serve --elastic): decode this chunk at the
            // ladder's current level — a passthrough clone when healthy.
            if let Some(gov) = &cfg.governor {
                options = gov.apply(&options);
            }
            let t_decode = Instant::now();
            // Publish the chunk's earliest deadline (the retry layer budgets
            // backoff against it) and arm the hung-dispatch watchdog with
            // the chunk's completion channels.
            deadline.set(chunk.iter().filter_map(|s| s.deadline).min());
            let guard = dog.as_ref().zip(cfg.fault.watchdog).map(|(d, t)| {
                d.guard(t, chunk.iter().map(|s| s.done.clone()).collect())
            });
            let decoded = sampler
                .decode_tokens(sampler.sample_prior_slots(&seeds), &options)
                .and_then(|out| Ok((sampler.unpatchify(&out.tokens)?, out)));
            deadline.clear();
            if guard.as_ref().is_some_and(|g| g.fired()) {
                // The monitor already resolved every slot of this chunk
                // `Err`; a result arriving this late is untrustworthy, so
                // discard it and hand the engine back for replacement.
                errors.inc();
                log::error!("worker {widx} dispatch hung past the watchdog; respawning");
                inflight.add(-1);
                return WorkerExit::DeviceLost;
            }
            drop(guard);
            match decoded {
                Ok((imgs, trace)) => {
                    decode_time.record_duration(t_decode.elapsed());
                    spec_hits.add(trace.spec_hits() as u64);
                    if let Some(tuner) = &cfg.tuner {
                        spec_wasted.add(tuner.observe(sampler.batch, &trace) as u64);
                    }
                    // Per-block convergence + sync behavior of this decode.
                    for t in &trace.traces {
                        block_iters.record(t.steps as u64);
                        host_syncs.record(t.host_syncs as u64);
                    }
                    // Padded images (if any) fall off the end of the zip.
                    // `put_once` keeps resolution exactly-once against the
                    // watchdog/deadline sweeps racing this completion.
                    for (slot, img) in chunk.iter().zip(imgs.into_iter()) {
                        lat.record_duration(slot.enqueued.elapsed());
                        slot.done.put_once(Ok(img));
                        images.inc();
                    }
                    batches.inc();
                }
                Err(e) => {
                    errors.inc();
                    let lost = classify(&e) == FaultClass::DeviceLost;
                    log::error!("worker {widx} sample failed: {e:#}");
                    // Complete slots with the error so clients get a 500
                    // instead of hanging (or a silently-black 200).
                    let msg = format!("decode failed: {e:#}");
                    for slot in &chunk {
                        slot.done.put_once(Err(msg.clone()));
                    }
                    if lost {
                        // The device is gone: stop pulling work on this
                        // engine and let the supervisor respawn it. Slots
                        // still in `slots` resolve `Err` through their
                        // completion guard when they drop here.
                        inflight.add(-1);
                        return WorkerExit::DeviceLost;
                    }
                }
            }
            // Governor feedback at chunk cadence: what is queued behind
            // this worker, and the worst accepted latency it just produced.
            if let Some(gov) = &cfg.governor {
                let worst = chunk.iter().map(|s| s.enqueued.elapsed()).max();
                gov.observe(batcher.queued(), worst);
            }
        }
        inflight.add(-1);
    }
    WorkerExit::Drained
    })();
    if let Some(d) = &dog {
        d.shutdown();
    }
    exit
}

/// Pipelined worker (depth ≥ 2): a feeder loop over a stage-graph
/// [`DecodePipeline`]. Bucket selection, padding accounting and the RNG
/// convention match [`worker_main`] exactly — the outputs are bit-identical
/// — but slot completion moves into per-job completion callbacks running on
/// the pipeline's final-stage thread, so the feeder can keep pulling
/// batches while earlier ones are still mid-decode.
fn worker_pipelined<B, F>(
    widx: usize,
    cfg: &RouterConfig,
    batcher: &Batcher,
    registry: &Registry,
    ready: &mut Option<std::sync::mpsc::Sender<Result<()>>>,
    factory: &F,
    replica: &Option<ReplicaSlot>,
) -> WorkerExit
where
    B: Backend,
    F: Fn(usize, usize) -> Result<B> + Send + Clone + 'static,
{
    // Stage threads of this worker share its factory index; the pipeline
    // hands each stage thread its span's placed device ordinal (see
    // `device_placement`), which flows through to the factory so each
    // stage's engine pins to the right device.
    let stage_factory = {
        let factory = factory.clone();
        move |ordinal: usize| factory(widx, ordinal)
    };
    let pipeline_cfg = PipelineConfig {
        depth: cfg.pipeline_depth,
        stage_threads: cfg.stage_threads,
        warm_cap: cfg.warm_cap,
        fault: cfg.fault.clone(),
        devices: cfg.devices,
    };
    let pipeline = match DecodePipeline::start(
        &cfg.model,
        &cfg.buckets,
        pipeline_cfg,
        registry.clone(),
        stage_factory,
    ) {
        Ok(p) => p,
        Err(e) => {
            ready_err(ready, e);
            return WorkerExit::StartupFailed;
        }
    };
    if let Some(tx) = ready.take() {
        let _ = tx.send(Ok(()));
    }

    let queue_wait = registry.histogram("sjd_queue_wait");
    let batch_fill = registry.histogram("sjd_batch_fill");
    let padded = registry.counter("sjd_padded_slots");
    let deadline_expired = registry.counter("sjd_deadline_expired");
    // Completion-side handles resolved once, off the submit hot path; each
    // chunk's callback clones the Arcs.
    let metrics = ChunkMetrics {
        lat: registry.histogram("sjd_request_latency"),
        decode_time: registry.histogram("sjd_decode_time"),
        block_iters: registry.histogram("sjd_block_iters"),
        host_syncs: registry.histogram("sjd_host_syncs"),
        images: registry.counter("sjd_images_generated"),
        batches: registry.counter("sjd_batches_processed"),
        errors: registry.counter("sjd_worker_errors"),
        inflight: registry.gauge("sjd_batches_inflight"),
        spec_hits: registry.counter("sjd_spec_init_hits"),
        spec_wasted: registry.counter("sjd_spec_wasted_updates"),
    };
    let max_bucket = pipeline.buckets.last().copied().unwrap_or(1);

    'feed: loop {
        // Replica tier: the feeder pulls only while least-loaded. Waves
        // finish on the final-stage thread, so the board decrement lives in
        // the completion callback, not here.
        if let Some(rep) = replica {
            rep.wait_turn();
        }
        let Some(batch) = batcher.next_batch() else { break };
        batch_fill.record(batch.slots.len() as u64);
        let mut slots = batch.slots;
        while !slots.is_empty() {
            // Same chunk-formation deadline enforcement as `worker_main`.
            slots.retain(|s| {
                if s.expired() {
                    deadline_expired.inc();
                    s.resolve_expired("batch formation");
                    false
                } else {
                    true
                }
            });
            if slots.is_empty() {
                break;
            }
            let take = slots.len().min(max_bucket);
            let chunk: Vec<Slot> = slots.drain(..take).collect();
            // Smallest lowered bucket covering the chunk (the same
            // `covering_bucket` law the stage samplers select by); pad only
            // up to it.
            let bucket = super::sampler::covering_bucket(&pipeline.buckets, chunk.len())
                .unwrap_or(max_bucket);
            padded.add(bucket.saturating_sub(chunk.len()) as u64);
            registry.counter(&format!("sjd_bucket_{bucket}_batches")).inc();
            // Per-slot RNG streams (see `worker_main`): the job carries every
            // slot's own seed, and stage 0 draws row i's prior from seed i.
            let seeds: Vec<u64> = chunk.iter().map(|s| s.seed).collect();
            let enqueued: Vec<Instant> = chunk.iter().map(|s| s.enqueued).collect();
            let mut opts = cfg.options.clone();
            if let Some(tuner) = &cfg.tuner {
                opts.policy = tuner.policy_for(bucket);
                opts.jacobi.init = tuner.init_for(bucket);
            }
            if let Some(gov) = &cfg.governor {
                // Submit-side half of the feedback loop: sample queue
                // pressure here; the completion callback reports latency.
                gov.observe(batcher.queued(), None);
                opts = gov.apply(&opts);
            }
            metrics.inflight.add(1);
            if let Some(rep) = replica {
                rep.started();
            }
            let done = completion(
                widx,
                bucket,
                chunk,
                cfg.tuner.clone(),
                cfg.governor.clone(),
                metrics.clone(),
                replica.clone(),
            );
            let job = PipelineJob { seeds, opts, done };
            match pipeline.submit(job) {
                Ok(()) => {
                    // Recorded *after* submit so the histogram covers the
                    // depth-gate backpressure wait too (its documented
                    // "submit → pipeline entry" meaning at depth ≥ 2).
                    for e in &enqueued {
                        queue_wait.record_duration(e.elapsed());
                    }
                }
                // The completion callback owns the inflight decrement.
                Err(job) => (job.done)(Err("pipeline shut down".into())),
            }
            // A lost stage (panic or device loss) closed the stage queues:
            // stop feeding and hand the whole pipeline back for respawn.
            // Undelivered slots resolve `Err` through their completion
            // guard when `slots` drops.
            if pipeline.lost() {
                break 'feed;
            }
        }
    }
    // Drain the in-flight tail (completion callbacks fire during join),
    // then tear the stage threads down.
    let lost = pipeline.lost();
    pipeline.shutdown();
    if lost {
        WorkerExit::DeviceLost
    } else {
        WorkerExit::Drained
    }
}

/// Continuous-batching worker (`serve --refill`): the
/// [`ContinuousPipeline`]'s stage 0 owns the batcher pull + refill loop, so
/// this thread only supervises startup and then waits for the pipeline to
/// drain (which happens when [`Router::shutdown`] closes the batcher).
/// Several workers share the one batcher safely — `next_batch` and
/// `take_upto` are atomic drains of the same queue.
fn worker_continuous<B, F>(
    widx: usize,
    cfg: &RouterConfig,
    batcher: &Batcher,
    registry: &Registry,
    ready: &mut Option<std::sync::mpsc::Sender<Result<()>>>,
    factory: &F,
) -> WorkerExit
where
    B: Backend,
    F: Fn(usize, usize) -> Result<B> + Send + Clone + 'static,
{
    // Same ordinal flow as `worker_pipelined`: the continuous pipeline
    // hands each stage thread its span's placed device ordinal. Replica
    // balancing needs no board here — R continuous pipelines sharing the
    // batcher self-balance through their bounded stage-0 queues (a busy
    // replica simply stops pulling when its queue caps out).
    let stage_factory = {
        let factory = factory.clone();
        move |ordinal: usize| factory(widx, ordinal)
    };
    let pipeline_cfg = PipelineConfig {
        depth: cfg.pipeline_depth.max(1),
        stage_threads: cfg.stage_threads,
        warm_cap: cfg.warm_cap,
        fault: cfg.fault.clone(),
        devices: cfg.devices,
    };
    let mut options = cfg.options.clone();
    // Same demotion rule as `DecodePipeline::submit`: draft-then-refine
    // needs a full-sequence pass no stage span can run.
    if options.jacobi.init == InitStrategy::Draft {
        options.jacobi.init = InitStrategy::Zeros;
    }
    let pipeline = match ContinuousPipeline::start_with_governor(
        &cfg.model,
        &cfg.buckets,
        pipeline_cfg,
        registry.clone(),
        batcher.clone(),
        options,
        cfg.governor.clone(),
        stage_factory,
    ) {
        Ok(p) => p,
        Err(e) => {
            ready_err(ready, e);
            return WorkerExit::StartupFailed;
        }
    };
    if let Some(tx) = ready.take() {
        let _ = tx.send(Ok(()));
    }
    // A lost stage (panic, device loss, or a fired watchdog) exits its loop
    // and cascades queue closes, so `join` returns with the batcher still
    // open — the supervisor then respawns this whole pipeline with fresh
    // engines and serving resumes.
    let lost = pipeline.lost_flag();
    pipeline.join();
    if lost.load(Ordering::SeqCst) {
        WorkerExit::DeviceLost
    } else {
        WorkerExit::Drained
    }
}

/// Completion-side metric handles of the pipelined worker, resolved once
/// per worker instead of once per chunk.
#[derive(Clone)]
struct ChunkMetrics {
    lat: Arc<Histogram>,
    decode_time: Arc<Histogram>,
    block_iters: Arc<Histogram>,
    host_syncs: Arc<Histogram>,
    images: Arc<Counter>,
    batches: Arc<Counter>,
    errors: Arc<Counter>,
    inflight: Arc<Gauge>,
    spec_hits: Arc<Counter>,
    spec_wasted: Arc<Counter>,
}

/// Build the completion callback for one pipelined chunk: records the batch
/// metrics, feeds the tuner, and completes every slot (images on success,
/// the shared error message on failure — HTTP 500, never a hang).
fn completion(
    widx: usize,
    bucket: usize,
    chunk: Vec<Slot>,
    tuner: Option<Arc<PolicyTuner>>,
    governor: Option<Arc<OverloadGovernor>>,
    m: ChunkMetrics,
    replica: Option<ReplicaSlot>,
) -> Box<dyn FnOnce(PipelineResult) + Send + 'static> {
    Box::new(move |result: PipelineResult| {
        match result {
            Ok((imgs, out)) => {
                // Comparable with the monolithic histogram: charge the
                // batch's *busy* wall (block decodes + prior/permutation/
                // sync work), not the inter-stage queue waits that
                // total_wall also contains under depth ≥ 2.
                let busy = out.traces.iter().map(|t| t.wall).sum::<Duration>() + out.other_wall;
                m.decode_time.record_duration(busy);
                m.spec_hits.add(out.spec_hits() as u64);
                if let Some(tuner) = &tuner {
                    m.spec_wasted.add(tuner.observe(bucket, &out) as u64);
                }
                for t in &out.traces {
                    m.block_iters.record(t.steps as u64);
                    m.host_syncs.record(t.host_syncs as u64);
                }
                // Padded images (if any) fall off the end of the zip.
                for (slot, img) in chunk.iter().zip(imgs.into_iter()) {
                    m.lat.record_duration(slot.enqueued.elapsed());
                    slot.done.put_once(Ok(img));
                    m.images.inc();
                }
                // Completion half of the governor feedback loop.
                if let (Some(gov), Some(worst)) =
                    (&governor, chunk.iter().map(|s| s.enqueued.elapsed()).max())
                {
                    gov.observe_latency(worst);
                }
                m.batches.inc();
            }
            Err(msg) => {
                m.errors.inc();
                log::error!("worker {widx} pipelined decode failed: {msg}");
                for slot in &chunk {
                    slot.done.put_once(Err(msg.clone()));
                }
            }
        }
        m.inflight.add(-1);
        // Replica tier: this wave is off the board — wake any peer (or
        // this replica's own feeder) waiting to become least-loaded.
        if let Some(rep) = &replica {
            rep.done();
        }
    })
}
