//! Continuous-batching correctness harness.
//!
//! The contract under test (ISSUE 7 / ROADMAP "continuous batching"): with
//! `RouterConfig::refill` on, requests enter and leave a decode at block
//! boundaries — stage 0 refills drained slots from the queue, shrinking
//! waves migrate to smaller covering buckets through the slot-remap gather,
//! and cancelled slots are swept out mid-flight — and **none of it may
//! change a single output bit at τ = 0**. Every request's image must equal
//! its solo serial decode regardless of which waves it rode through
//! (Prop 3.2: the per-block fixed point is independent of the starting
//! iterate, and the remap gather only permutes whole batch rows).
//!
//! Three tiers:
//! * a deterministic mid-flight migration regression (per-slot RNG streams
//!   derived from request seeds, not batch positions),
//! * a 300-schedule pseudo-random join/leave/migrate property sweep, and
//! * a padding monotonicity check against the held-batch baseline.

use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::policy::{BlockDecode, DecodePolicy};
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::metrics::Registry;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic PCG-style stream: the 300 schedules must replay
/// identically on every run (no OS entropy).
struct ScheduleRng(u64);

impl ScheduleRng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

/// τ = 0 decode options for one policy.
fn opts(policy: &DecodePolicy) -> SampleOptions {
    let mut o = SampleOptions { policy: policy.clone(), ..Default::default() };
    o.jacobi.tau = 0.0;
    o
}

/// The ground truth each request is held to: a bucket-1 solo decode of the
/// same seed on a fresh backend — no batching, no refill, no migration.
fn solo_reference(policy: &DecodePolicy, seed: u64) -> Vec<f32> {
    let be = MockServeBackend::new(&[1, 2, 4], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 1).expect("solo sampler");
    let z = sampler.sample_prior_slots(&[seed]);
    let out = sampler.decode_tokens(z, &opts(policy)).expect("solo decode");
    sampler.unpatchify(&out.tokens).expect("solo unpatchify")[0].data().to_vec()
}

/// Boot a single-worker continuous (`refill: true`) or held-batch router.
fn start_router(
    refill: bool,
    options: SampleOptions,
    slot_delay: Duration,
    batcher: &Batcher,
    registry: &Registry,
    ledger: &Arc<MockLedger>,
) -> Router {
    let ledger = ledger.clone();
    Router::start_with(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options,
            pipeline_depth: 1,
            stage_threads: 0,
            refill,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        move |_| Ok(MockServeBackend::new(&[1, 2, 4], slot_delay, ledger.clone())),
    )
    .expect("router")
}

#[test]
fn slot_rng_streams_survive_mid_flight_migration() {
    // Satellite regression: each slot's prior must come from its own
    // request-seed RNG stream, not its batch position — the bug this pins
    // was batch RNG seeded from the first slot's seed. Two runs over the
    // same four seeds: one rides a full wave end to end, one loses two
    // slots mid-flight (sweep → remap gather → bucket 4 → 2 migration).
    // Every surviving slot must be bit-identical to its solo decode — and
    // therefore to itself across the two runs.
    let policy = DecodePolicy::UniformJacobi;
    let seeds = [11u64, 12, 13, 14];
    let want: Vec<Vec<f32>> = seeds.iter().map(|&s| solo_reference(&policy, s)).collect();

    // Run 1: undisturbed full wave.
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(200));
    let ledger = MockLedger::new();
    let router =
        start_router(true, opts(&policy), Duration::ZERO, &batcher, &registry, &ledger);
    let handles: Vec<_> =
        seeds.iter().map(|&s| batcher.submit_slot(s, s).expect("submit")).collect();
    for (i, h) in handles.iter().enumerate() {
        let img = h.done.wait_timeout(Duration::from_secs(30)).expect("resolves").expect("image");
        assert_eq!(
            img.data(),
            &want[i][..],
            "slot {i}: batch position must not leak into the RNG stream"
        );
    }
    router.shutdown();
    assert_eq!(registry.counter("sjd_bucket_migrations").get(), 0);

    // Run 2: slots 1 and 2 cancel mid-decode; the wave sweeps them at the
    // next block boundary, compacts rows through the slot-remap gather and
    // migrates bucket 4 → 2. A 2 ms per-slot decode delay stretches stage 0
    // to ≥ 60 ms so the cancellation provably lands mid-flight (gated on
    // the ledger seeing the first decode call).
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(200));
    let ledger = MockLedger::new();
    let router = start_router(
        true,
        opts(&policy),
        Duration::from_millis(2),
        &batcher,
        &registry,
        &ledger,
    );
    let handles: Vec<_> =
        seeds.iter().map(|&s| batcher.submit_slot(s, s).expect("submit")).collect();
    let t0 = Instant::now();
    while ledger.count_containing("_jstep") == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "decode never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    handles[1].cancel();
    handles[2].cancel();
    for (i, h) in handles.iter().enumerate() {
        let res = h.done.wait_timeout(Duration::from_secs(30)).expect("resolves");
        if i == 1 || i == 2 {
            let msg = res.expect_err("cancelled slot completes with an error");
            assert!(msg.contains("cancelled"), "{msg}");
        } else {
            let img = res.expect("surviving slot decodes");
            assert_eq!(
                img.data(),
                &want[i][..],
                "slot {i}: migration must not change a single output bit"
            );
        }
    }
    router.shutdown();
    assert_eq!(registry.counter("sjd_slots_cancelled").get(), 2);
    assert!(
        registry.counter("sjd_bucket_migrations").get() >= 1,
        "the shrunken wave must migrate to the smaller covering bucket"
    );
    assert!(
        ledger.count_containing("_slot_gather_") >= 1,
        "the sweep must compact rows through the slot-remap gather artifact"
    );
}

#[test]
fn property_300_schedules_bit_exact_with_no_lost_slots() {
    // Satellite property sweep: 300 pseudo-random join/leave schedules over
    // the continuous router. Invariants, per schedule:
    // * every submitted slot resolves exactly once (no drops, no hangs),
    // * every delivered image is bit-identical to its solo decode at τ = 0,
    //   whatever waves/buckets/merges/migrations it rode through,
    // * only slots this test cancelled may resolve with an error,
    // * the queue is empty after shutdown.
    let policies: Vec<DecodePolicy> = vec![
        DecodePolicy::UniformJacobi,
        DecodePolicy::Selective { seq_blocks: 1 },
        DecodePolicy::PerBlock {
            modes: vec![
                BlockDecode::Sequential,
                BlockDecode::GsFused { windows: 2, chunk: 2 },
                BlockDecode::Fused { chunk: 3 },
                BlockDecode::GsJacobi { windows: 4 },
            ],
        },
    ];
    // Solo references are deterministic per (policy, seed): cache them.
    let mut solo: HashMap<(usize, u64), Vec<f32>> = HashMap::new();

    for schedule in 0..300u64 {
        let pidx = (schedule as usize) % policies.len();
        let mut rng = ScheduleRng(schedule.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1);
        let registry = Registry::new();
        let batcher = Batcher::new(4, Duration::from_millis(2));
        let ledger = MockLedger::new();
        let router = start_router(
            true,
            opts(&policies[pidx]),
            Duration::ZERO,
            &batcher,
            &registry,
            &ledger,
        );

        let mut submitted: Vec<(u64, sjd::coordinator::batcher::SlotHandle, bool)> = Vec::new();
        for _event in 0..(rng.next() % 5 + 2) {
            if rng.next() % 3 < 2 {
                // Join: a burst of 1..=4 new requests.
                for _ in 0..(rng.next() % 4 + 1) {
                    let seed = rng.next() % 12;
                    let h = batcher.submit_slot(seed, seed).expect("submit");
                    submitted.push((seed, h, false));
                }
            } else if !submitted.is_empty() {
                // Leave: cancel a random slot — it may already be decoded
                // (delivers Ok), be mid-wave (swept at the next boundary)
                // or still be queued (swept at formation).
                let i = (rng.next() as usize) % submitted.len();
                submitted[i].1.cancel();
                submitted[i].2 = true;
            }
            if rng.next() % 2 == 0 {
                std::thread::sleep(Duration::from_micros(rng.next() % 1500));
            }
        }
        router.shutdown();

        let (mut ok, mut errs) = (0usize, 0usize);
        for (seed, h, cancelled) in &submitted {
            let res = h
                .done
                .wait_timeout(Duration::from_secs(30))
                .expect("every slot resolves — no drops, no hangs");
            match res {
                Ok(img) => {
                    ok += 1;
                    let want = solo
                        .entry((pidx, *seed))
                        .or_insert_with(|| solo_reference(&policies[pidx], *seed));
                    assert_eq!(
                        img.data(),
                        &want[..],
                        "schedule {schedule}: seed {seed} must be bit-exact with solo decode"
                    );
                }
                Err(msg) => {
                    errs += 1;
                    assert!(
                        *cancelled,
                        "schedule {schedule}: only cancelled slots may error: {msg}"
                    );
                    assert!(msg.contains("cancelled"), "{msg}");
                }
            }
        }
        assert_eq!(ok + errs, submitted.len(), "schedule {schedule}: double/missing completion");
        assert_eq!(batcher.queued(), 0, "schedule {schedule}: queue must drain on close");
    }
}

#[test]
fn refill_padding_never_exceeds_held_batch_baseline() {
    // Padding monotonicity on cancel-free deterministic schedules: prefill
    // the queue before the router starts (full waves first, one partial
    // tail), then compare the continuous path's per-block padded rows
    // against the held-batch baseline, which decodes each padded slot
    // through all K = 4 blocks.
    const BLOCKS: u64 = 4;
    for n in 1..=10usize {
        let run = |refill: bool| -> (u64, u64) {
            let registry = Registry::new();
            let batcher = Batcher::new(4, Duration::from_millis(2));
            let handles: Vec<_> = (0..n as u64)
                .map(|s| batcher.submit_slot(s, 100 + s).expect("submit"))
                .collect();
            let ledger = MockLedger::new();
            let router = start_router(
                refill,
                opts(&DecodePolicy::UniformJacobi),
                Duration::ZERO,
                &batcher,
                &registry,
                &ledger,
            );
            for h in handles {
                h.done
                    .wait_timeout(Duration::from_secs(30))
                    .expect("resolves")
                    .expect("image");
            }
            router.shutdown();
            (
                registry.counter("sjd_padded_slots").get(),
                registry.counter("sjd_padded_slot_blocks").get(),
            )
        };
        let (base_slots, _) = run(false);
        let (cont_slots, cont_blocks) = run(true);
        assert!(
            cont_blocks <= base_slots * BLOCKS,
            "n={n}: continuous decoded {cont_blocks} padded slot-blocks, held-batch baseline {}",
            base_slots * BLOCKS
        );
        assert!(
            cont_slots <= base_slots,
            "n={n}: formation-time padding must not regress ({cont_slots} > {base_slots})"
        );
    }
}
