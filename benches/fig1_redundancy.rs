//! **Fig 1 / Fig A1**: sequential redundancy — cosine similarity and L2
//! distance between per-layer outputs of standard inference and inference
//! with the `o` nearest preceding dependencies masked (eq 6), o ∈ {1, 2, 5}.
//!
//! Paper shape: the first generation layer (decode position 0) deviates far
//! more than subsequent layers — low redundancy at the noise-consuming layer,
//! high redundancy in the refinement layers.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::jacobi::JacobiConfig;
use sjd::coordinator::sampler::Sampler;
use sjd::runtime::HostTensor;
use sjd::tensor::{Pcg64, Tensor};

fn to_tensor(h: &HostTensor) -> Tensor {
    Tensor::new(h.shape(), h.as_f32().unwrap().to_vec()).unwrap()
}

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = "tf10";
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let kk = sampler.meta.blocks;
    let exact = JacobiConfig { tau: 1e-5, ..Default::default() };

    let mut report = Report::new("Fig 1/A1 — layer-output deviation under o-masked dependencies");
    let mut rows = Vec::new();

    for o in [1usize, 2, 5] {
        // Standard and masked inference from the same prior draw, comparing
        // the layer outputs h_k at every decode position.
        let mut rng = Pcg64::seed(11);
        let z0 = sampler.sample_prior(&mut rng);
        let mut h_std = z0.clone();
        let mut h_msk = z0;
        let mut cos_row = Vec::new();
        let mut l2_row = Vec::new();
        for pos in 0..kk {
            let k = kk - 1 - pos;
            let (u_std, _) = sampler.jacobi_decode(k, &h_std, &exact, 0)?;
            let (u_msk, _) = sampler.jacobi_decode(k, &h_msk, &exact, o)?;
            h_std = if k % 2 == 1 { sampler.reverse_tokens(&u_std)? } else { u_std };
            h_msk = if k % 2 == 1 { sampler.reverse_tokens(&u_msk)? } else { u_msk };
            let a = to_tensor(&h_std);
            let b = to_tensor(&h_msk);
            let cos = a.cosine_sim(&b)?;
            let l2 = a.l2_dist(&b)? / (a.numel() as f32).sqrt();
            cos_row.push(cos as f64);
            l2_row.push(l2 as f64);
            rows.push(vec![
                format!("o={o}"),
                format!("layer {}", pos + 1),
                format!("{cos:.4}"),
                format!("{l2:.4}"),
            ]);
        }
        println!("o={o}: cosine per layer {cos_row:?}");
        report.series(&format!("cosine_sim_o{o}"), &cos_row);
        report.series(&format!("l2_dist_o{o}"), &l2_row);
    }

    report.table(&["Mask", "Layer (decode order)", "Cosine sim", "L2/√N"], &rows);
    report.note("Paper shape: layer 1 (decode position 0) deviates most; later layers stay close to 1.0 cosine.");
    report.finish();
    Ok(())
}
