//! Recursive-descent JSON parser.

use super::Value;
use std::collections::BTreeMap;
use std::fmt;

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Container-nesting cap. The parser is recursive descent, so document
/// nesting is caller-controlled *stack* depth: without a cap, a few KB of
/// `[[[[…` overflows the thread stack, which aborts the process instead of
/// returning an error. No real policy/config document nests anywhere near
/// this deep.
const MAX_DEPTH: usize = 128;

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.enter()?;
        let v = self.object_inner();
        self.depth -= 1;
        v
    }

    fn object_inner(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.enter()?;
        let v = self.array_inner();
        self.depth -= 1;
        v
    }

    fn array_inner(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Combine surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| ParseError { offset: start, msg: format!("invalid number '{s}'") })
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}
