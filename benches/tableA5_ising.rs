//! **Table A5**: MAF on the Boltzmann-distribution task — sequential vs ours
//! (all-layer Jacobi): inference time, average energy/site, average |M|.
//! Physics observables must match the Metropolis MCMC reference.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::maf::{MafMode, MafSampler};
use sjd::physics::IsingModel;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    if engine.manifest().model("maf_ising").is_err() {
        println!("SKIP: maf_ising not in manifest");
        return Ok(());
    }
    let batch = *engine.manifest().model("maf_ising")?.batch_sizes.first().unwrap();
    let sampler = MafSampler::new(&engine, "maf_ising", batch)?;
    let model = IsingModel::new(8, 3.0);
    let batches = if quick() { 2 } else { 8 };
    let cfg = sjd::coordinator::maf::maf_config(0.05);

    let mut report = Report::new("Table A5 — MAF Boltzmann approximation (8×8 Ising, T = 3.0)");
    let mut rows = Vec::new();

    // References.
    if let Some(m) = engine.manifest().datasets.get("ising_ref") {
        let e = m.extra.get("energy_per_site").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let mag = m.extra.get("abs_magnetization").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        rows.push(vec!["MCMC reference".into(), "—".into(), format!("{e:.4}"), format!("{mag:.4}")]);
    }

    let mut seq_time = None;
    for (mode, label) in [(MafMode::Sequential, "Sequential"), (MafMode::Jacobi, "Ours")] {
        // Warmup compile.
        let mut rng = sjd::tensor::Pcg64::seed(1);
        let _ = sampler.sample(mode, &cfg, &mut rng)?;
        let mut rng = sjd::tensor::Pcg64::seed(77);
        let mut wall = 0.0;
        let mut evals = 0;
        let mut all = Vec::new();
        for _ in 0..batches {
            let out = sampler.sample(mode, &cfg, &mut rng)?;
            wall += out.total_wall.as_secs_f64();
            evals += out.made_evals();
            all.extend_from_slice(out.samples.as_f32()?);
        }
        let stats = model.stats_from_continuous(&all);
        let speed = match seq_time {
            None => {
                seq_time = Some(wall);
                "1.0x".to_string()
            }
            Some(s) => format!("{:.1}x", s / wall),
        };
        println!(
            "{label}: {wall:.2}s ({evals} MADE evals, {speed}) E/site {:.4} |M| {:.4}",
            stats.energy_per_site, stats.abs_magnetization
        );
        rows.push(vec![
            label.into(),
            format!("{wall:.2}s ({speed})"),
            format!("{:.4}", stats.energy_per_site),
            format!("{:.4}", stats.abs_magnetization),
        ]);
    }

    report.table(
        &["Method", "Inference time", "Avg energy/site", "Avg |magnetization|"],
        &rows,
    );
    report.note("Paper shape: large speedup (paper 15.7x on GPU), observables match MCMC within noise.");
    report.finish();
    Ok(())
}
