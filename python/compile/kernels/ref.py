"""Pure-jnp reference implementations (correctness oracles for the Pallas
kernels, and the fast path used during training).

Conventions
-----------
* Attention operates on (B, H, L, Dh) tensors.
* The causal mask with dependency offset ``o`` implements the paper's eq 6:
  the query at net position ``l`` may attend key positions ``j`` with
  ``j <= l - o``; net position 0 (the shifted zero pad, which carries no
  sub-variable information) is always attendable so the masked model still
  has a well-defined input. ``o = 0`` reduces to standard causal attention.
* The affine inverse update is the body of the paper's Alg 1:
  ``z' = y * exp(-s) + g`` with the first token passed through unchanged,
  plus the residual ``max_l,d |z' - z_prev|`` per batch element.
"""

import jax.numpy as jnp


def attention_mask(seq_len: int, o):
    """(L, L) boolean mask: True = attendable. ``o`` may be a traced scalar."""
    rows = jnp.arange(seq_len)[:, None]
    cols = jnp.arange(seq_len)[None, :]
    base = cols <= rows - o
    pad_col = cols == 0
    return base | pad_col


def causal_attention_ref(q, k, v, o=0):
    """Masked multi-head attention.

    Args:
      q, k, v: (B, H, L, Dh)
      o: dependency mask offset (python int or traced i32 scalar)

    Returns:
      (B, H, L, Dh)
    """
    b, h, l, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = attention_mask(l, o)
    scores = jnp.where(mask[None, None, :, :], scores, jnp.asarray(-1e30, q.dtype))
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)


def affine_inverse_update_ref(z_prev, y, s, g):
    """One parallel Jacobi update (Alg 1 body) + convergence residual.

    Args:
      z_prev: (B, L, D) previous iterate z^t
      y:      (B, L, D) block input z_{k+1}
      s, g:   (B, L, D) shift/scale predicted from z_prev

    Returns:
      z_next: (B, L, D) with z_next[:, 0] = y[:, 0]
      resid:  (B,) = max over (L, D) of |z_next - z_prev|
    """
    z_next = y * jnp.exp(-s) + g
    z_next = z_next.at[:, 0, :].set(y[:, 0, :])
    resid = jnp.max(jnp.abs(z_next - z_prev), axis=(1, 2))
    return z_next, resid


def affine_inverse_update_window_ref(z_prev, y, s, g, off, wlen):
    """Windowed Jacobi update (GS-Jacobi inner step) + windowed residual.

    Positions outside [off, off+wlen) are copied through from ``z_prev``
    (the frozen converged prefix on the left, the not-yet-swept suffix on
    the right); because frozen positions contribute |z' − z| = 0, the plain
    max-reduction equals the residual over the active window only.

    Args:
      z_prev, y, s, g: (B, L, D)
      off, wlen: window offset / length (python ints or traced i32 scalars)

    Returns:
      (z_next (B, L, D), resid (B,))
    """
    l = z_prev.shape[1]
    z_next = y * jnp.exp(-s) + g
    rows = jnp.arange(l)[None, :, None]
    z_next = jnp.where(rows == 0, y, z_next)
    in_window = (rows >= off) & (rows < off + wlen)
    z_next = jnp.where(in_window, z_next, z_prev)
    resid = jnp.max(jnp.abs(z_next - z_prev), axis=(1, 2))
    return z_next, resid


def init_extrapolate_ref(y, s, g):
    """Speculative z⁰ extrapolation (cross-block init provider).

    One affine inverse update evaluated at ``z = y`` — i.e. the Alg 1 body
    with the (s, g) conditioner run on the block *input* instead of a prior
    iterate — producing a predicted starting iterate for the Jacobi solve.
    Unlike :func:`affine_inverse_update_ref` there is no residual output:
    the prediction is a seed, not an iterate under the τ test.

    Args:
      y:    (B, L, D) block input z_{k+1}
      s, g: (B, L, D) shift/scale predicted from y

    Returns:
      z0: (B, L, D) with z0[:, 0] = y[:, 0]
    """
    z0 = y * jnp.exp(-s) + g
    return z0.at[:, 0, :].set(y[:, 0, :])


def affine_forward_ref(u, s, g):
    """Forward affine transform (encode direction, eq 4) + logdet.

    v_l = (u_l - g_l) * exp(s_l) for l >= 1; v_0 = u_0.
    logdet per sample = sum_{l>=1, d} s.
    """
    v = (u - g) * jnp.exp(s)
    v = v.at[:, 0, :].set(u[:, 0, :])
    logdet = jnp.sum(s[:, 1:, :], axis=(1, 2))
    return v, logdet
