//! [`Value`]: the unit of data flowing through a [`crate::runtime::Backend`].
//!
//! A value is either host-resident data ([`HostTensor`]) or a device-resident
//! handle ([`DeviceValue`]) produced by a previous backend call. Device
//! handles are opaque to the coordinator: only the backend that minted one
//! can execute with it or sync it back (`Engine` stores a PJRT buffer, the
//! test mock stores a plain tensor). Shape and dtype metadata ride along so
//! drivers can validate and allocate without a device round trip.
//!
//! Device handles are reference-counted with [`Rc`] and therefore inherit the
//! engine's thread pinning: a `Value::Device` must stay on the thread of the
//! backend that created it. Cross-thread traffic (router workers, HTTP
//! responses) goes through [`Backend::to_host`](crate::runtime::Backend),
//! which yields plain `Send` [`HostTensor`]s.

use super::manifest::DType;
use super::HostTensor;
use std::any::Any;
use std::fmt;
use std::rc::Rc;

/// A device-resident tensor handle minted by a backend.
///
/// Cloning is cheap (one `Rc` bump) and never copies device memory; the
/// underlying buffer is freed when the last clone drops.
#[derive(Clone)]
pub struct DeviceValue {
    shape: Vec<usize>,
    dtype: DType,
    handle: Rc<dyn Any>,
}

impl DeviceValue {
    /// Wrap a backend-specific handle with its tensor metadata.
    pub fn new(shape: Vec<usize>, dtype: DType, handle: Rc<dyn Any>) -> Self {
        DeviceValue { shape, dtype, handle }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Borrow the backend-specific payload, if it is a `T`.
    ///
    /// Returns `None` when the value was minted by a different backend —
    /// callers should surface that as an error rather than panic.
    pub fn downcast<T: 'static>(&self) -> Option<&T> {
        self.handle.downcast_ref::<T>()
    }
}

impl fmt::Debug for DeviceValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DeviceValue")
            .field("shape", &self.shape)
            .field("dtype", &self.dtype)
            .finish_non_exhaustive()
    }
}

/// Host data or a device-resident handle — what backend calls consume and
/// produce. See the [module docs](self) for the residency rules.
#[derive(Clone, Debug)]
pub enum Value {
    Host(HostTensor),
    Device(DeviceValue),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::Host(t) => t.shape(),
            Value::Device(d) => d.shape(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::Host(HostTensor::F32 { .. }) => DType::F32,
            Value::Host(HostTensor::I32 { .. }) => DType::I32,
            Value::Device(d) => d.dtype(),
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_device(&self) -> bool {
        matches!(self, Value::Device(_))
    }

    /// Borrow the host tensor if this value is host-resident.
    pub fn as_host(&self) -> Option<&HostTensor> {
        match self {
            Value::Host(t) => Some(t),
            Value::Device(_) => None,
        }
    }
}

impl From<HostTensor> for Value {
    fn from(t: HostTensor) -> Self {
        Value::Host(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_metadata() {
        let v = Value::from(HostTensor::f32(&[2, 3], vec![0.0; 6]));
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), DType::F32);
        assert_eq!(v.numel(), 6);
        assert!(!v.is_device());
        assert!(v.as_host().is_some());
    }

    #[test]
    fn device_value_downcast_and_clone() {
        let d = DeviceValue::new(vec![4], DType::I32, Rc::new(42u32));
        let v = Value::Device(d.clone());
        assert_eq!(v.shape(), &[4]);
        assert_eq!(v.dtype(), DType::I32);
        assert!(v.is_device());
        assert!(v.as_host().is_none());
        assert_eq!(d.downcast::<u32>(), Some(&42));
        assert_eq!(d.downcast::<i64>(), None);
        // Clones share the payload.
        let d2 = d.clone();
        assert!(Rc::ptr_eq(&d.handle, &d2.handle));
    }
}
