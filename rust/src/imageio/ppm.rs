//! Plain PPM (P6) writer — dependency-free fallback and debugging format.

use super::Image;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// Write an [`Image`] to a binary PPM file.
pub fn write_ppm(img: &Image, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    write!(f, "P6\n{} {}\n255\n", img.width, img.height)?;
    f.write_all(&img.pixels)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_and_body() {
        let mut img = Image::new(2, 1);
        img.set(0, 0, [9, 8, 7]);
        let p = std::env::temp_dir().join("sjd_ppm_test.ppm");
        write_ppm(&img, &p).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P6\n2 1\n255\n"));
        assert_eq!(&data[data.len() - 6..], &[9, 8, 7, 0, 0, 0]);
    }
}
