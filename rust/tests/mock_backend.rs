//! Coordinator unit tests over a **mock backend** — an analytically
//! invertible autoregressive flow implemented in pure rust, exposing the
//! same artifact ABI the real engine serves. Lets us test decode logic
//! (policy routing, permutations, Jacobi semantics, trace accounting)
//! hermetically, without artifacts or PJRT.
//!
//! The mock implements the **value-based** backend API: its "device" is an
//! `Rc<HostTensor>` behind an opaque [`DeviceValue`] handle, and it records
//! every host↔device crossing (uploads, syncs, host-arg promotions per
//! artifact). The residency tests assert the hot loops' marshal behavior —
//! Jacobi uploads `y` once and syncs only the `[B]` residual per iteration;
//! sequential decode never round-trips the KV caches — exactly the traffic
//! contract `Sampler`/`jacobi_decode_block_v` document.
//!
//! Mock flow per block k (AR domain), with coupling strength a_k:
//!   forward: v_0 = u_0;  v_l = u_l − a_k · mean(u_{<l})
//!   inverse: u_l = v_l + a_k · mean(u_{<l})   (triangular ⇒ Jacobi applies)

use sjd::coordinator::jacobi::{
    gs_jacobi_decode_block, gs_jacobi_decode_block_fused_v, gs_jacobi_decode_block_v,
    jacobi_decode_block, jacobi_decode_block_fused_v, jacobi_decode_block_v,
    window_partition, InitStrategy, JacobiConfig,
};
use sjd::coordinator::pipeline::{DecodePipeline, PipelineConfig, PipelineJob};
use sjd::coordinator::policy::{BlockDecode, DecodePolicy};
use sjd::coordinator::sampler::{SampleOptions, Sampler, SamplerSet};
use sjd::coordinator::state::{BufferPool, SCALAR_CACHE_CAP};
use sjd::runtime::{Backend, DType, DeviceValue, HostTensor, ModelMeta, Value};
use sjd::tensor::{Pcg64, Tensor};
// The analytic flow math (batch-generic) is shared with the serving tests
// and the load bench; this file owns the *device-simulating* backend that
// wraps it with a traffic ledger for the residency contracts.
use sjd::testkit::mockflow::{MockFlow, MockLedger, MockServeBackend};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

const K: usize = 4;
const L: usize = 8;
const D: usize = 3;
const NL: usize = 1;
const DM: usize = 4;

/// Ledger of every host↔device crossing the mock observes.
#[derive(Default)]
struct Traffic {
    /// Shapes passed to `to_device`.
    uploads: Vec<Vec<usize>>,
    /// Shapes of device values fetched via `to_host`.
    syncs: Vec<Vec<usize>>,
    /// Per-artifact count of `Value::Host` inputs promoted inside `call_v`.
    promoted: BTreeMap<String, usize>,
    /// Per-artifact count of device-resident inputs consumed in place.
    device_ins: BTreeMap<String, usize>,
}

/// Backend serving the mock flow under the standard artifact names.
struct MockBackend {
    flow: MockFlow,
    calls: RefCell<BTreeMap<String, usize>>,
    traffic: RefCell<Traffic>,
    /// Expose the optional `{m}_reverse_b{B}` device-side gather artifact.
    device_reverse: bool,
    /// Expose the optional `{m}_block_jstep_win_b{B}` GS-Jacobi artifact
    /// (false models a pre-windowing artifact dir → Sampler falls back).
    windowed_jstep: bool,
    /// Expose the optional fused multi-step artifacts
    /// (`{m}_block_jstep_fuse_b{B}` / `{m}_block_jstep_win_fuse_b{B}`);
    /// false models a pre-fusion artifact dir → per-iteration fallback.
    fused_jstep: bool,
    /// Expose the optional `{m}_init_proj_b{B}` cross-block extrapolation
    /// artifact; false models a pre-speculation artifact dir → `--init proj`
    /// must silently fall back to the Zeros init.
    init_proj: bool,
}

/// Mint a mock device value: the payload is just an `Rc`'d host tensor.
fn dev(t: HostTensor) -> Value {
    let dtype = match &t {
        HostTensor::F32 { .. } => DType::F32,
        HostTensor::I32 { .. } => DType::I32,
    };
    Value::Device(DeviceValue::new(t.shape().to_vec(), dtype, Rc::new(t)))
}

/// Read a value's data regardless of residency (no traffic accounting —
/// the mock's "device memory" is host memory).
fn fetch(v: &Value) -> HostTensor {
    match v {
        Value::Host(t) => t.clone(),
        Value::Device(d) => d.downcast::<HostTensor>().expect("mock device value").clone(),
    }
}

impl MockBackend {
    fn new() -> Self {
        MockBackend {
            flow: MockFlow::standard(),
            calls: Default::default(),
            traffic: Default::default(),
            device_reverse: false,
            windowed_jstep: true,
            fused_jstep: true,
            init_proj: true,
        }
    }

    fn with_device_reverse() -> Self {
        MockBackend { device_reverse: true, ..MockBackend::new() }
    }

    fn without_jstep_win() -> Self {
        MockBackend { windowed_jstep: false, ..MockBackend::new() }
    }

    fn without_fuse() -> Self {
        MockBackend { fused_jstep: false, ..MockBackend::new() }
    }

    fn without_init_proj() -> Self {
        MockBackend { init_proj: false, ..MockBackend::new() }
    }

    fn count(&self, name: &str) -> usize {
        self.calls.borrow().get(name).copied().unwrap_or(0)
    }

    fn promoted(&self, name: &str) -> usize {
        self.traffic.borrow().promoted.get(name).copied().unwrap_or(0)
    }

    fn uploads_of(&self, shape: &[usize]) -> usize {
        self.traffic.borrow().uploads.iter().filter(|s| s.as_slice() == shape).count()
    }

    fn syncs_of(&self, shape: &[usize]) -> usize {
        self.traffic.borrow().syncs.iter().filter(|s| s.as_slice() == shape).count()
    }

    /// The artifact math, on host tensors (shared by every entry path):
    /// delegated to the batch-generic [`MockFlow`] dispatch.
    fn exec_host(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        self.flow.exec(name, inputs)
    }
}

impl Backend for MockBackend {
    fn call_v(&self, name: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        *self.calls.borrow_mut().entry(name.to_string()).or_default() += 1;
        {
            let mut tr = self.traffic.borrow_mut();
            for v in inputs {
                match v {
                    Value::Host(_) => *tr.promoted.entry(name.to_string()).or_default() += 1,
                    Value::Device(_) => {
                        *tr.device_ins.entry(name.to_string()).or_default() += 1
                    }
                }
            }
        }
        let host: Vec<HostTensor> = inputs.iter().map(fetch).collect();
        let outs = self.exec_host(name, &host)?;
        // Outputs are always "device"-resident, like the real engine.
        Ok(outs.into_iter().map(dev).collect())
    }

    fn to_device(&self, t: &HostTensor) -> anyhow::Result<Value> {
        self.traffic.borrow_mut().uploads.push(t.shape().to_vec());
        Ok(dev(t.clone()))
    }

    fn to_host(&self, v: Value) -> anyhow::Result<HostTensor> {
        if let Value::Device(d) = &v {
            self.traffic.borrow_mut().syncs.push(d.shape().to_vec());
        }
        Ok(fetch(&v))
    }

    fn has_artifact(&self, name: &str) -> bool {
        if name.contains("_reverse_") {
            return self.device_reverse;
        }
        if name.contains("fuse") {
            return self.fused_jstep;
        }
        if name.contains("init_proj") {
            return self.init_proj;
        }
        if name.contains("jstep_win") {
            return self.windowed_jstep;
        }
        true
    }

    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta> {
        Ok(ModelMeta {
            name: model.to_string(),
            kind: "tarflow".into(),
            seq_len: L,
            blocks: K,
            token_dim: D,
            model_dim: DM,
            layers_per_block: NL,
            // Non-square 2×4 grid with patch 1: L = 2·4 = 8, D = 1·1·3 = 3.
            image_hwc: Some([2, 4, 3]),
            patch: 1,
            noise_std: 0.0,
            batch_sizes: vec![2],
            extra: BTreeMap::new(),
        })
    }
}

fn mk_sampler(backend: &MockBackend) -> Sampler<'_, MockBackend> {
    Sampler::new(backend, "mock", 2).expect("mock sampler")
}

fn randn(shape: &[usize], seed: u64) -> HostTensor {
    let mut rng = Pcg64::seed(seed);
    HostTensor::f32(shape, (0..shape.iter().product()).map(|_| rng.next_gaussian()).collect())
}

fn max_abs_diff(a: &HostTensor, b: &HostTensor) -> f32 {
    a.as_f32()
        .unwrap()
        .iter()
        .zip(b.as_f32().unwrap())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn jacobi_converges_to_mock_inverse() {
    let be = MockBackend::new();
    let u = randn(&[2, L, D], 1);
    let v_vec = be.flow.fwd(2, u.as_f32().unwrap(), 2);
    let v = HostTensor::f32(&[2, L, D], v_vec);
    let cfg = JacobiConfig { tau: 1e-6, ..Default::default() };
    let (u_rec, stats) = jacobi_decode_block(&be, "mock_block_jstep_b2", 2, &v, L, &cfg, 0).unwrap();
    let err = max_abs_diff(&u, &u_rec);
    assert!(err < 1e-4, "err {err}");
    assert!(stats.iterations <= L);
    assert!(stats.converged);
    // Residuals strictly decreasing for this linear triangular system.
    for w in stats.residuals.windows(2) {
        assert!(w[1] <= w[0] + 1e-6, "{:?}", stats.residuals);
    }
}

#[test]
fn weak_coupling_converges_faster_than_strong() {
    // Blocks differ only in coupling strength a_k: stronger coupling ⇒ more
    // iterations (the paper's redundancy heterogeneity, distilled).
    let be = MockBackend::new();
    let y = randn(&[2, L, D], 2);
    let cfg = JacobiConfig { tau: 1e-4, ..Default::default() };
    let (_, strong) = jacobi_decode_block(&be, "m_block_jstep", 0, &y, L, &cfg, 0).unwrap(); // a=0.9
    let (_, weak) = jacobi_decode_block(&be, "m_block_jstep", 2, &y, L, &cfg, 0).unwrap(); // a=0.15
    assert!(
        weak.iterations < strong.iterations,
        "weak {} vs strong {}",
        weak.iterations,
        strong.iterations
    );
}

#[test]
fn jacobi_keeps_iterate_device_resident() {
    // The tentpole contract: one upload of y, device→device chaining of the
    // iterate, and per-iteration sync of ONLY the [B] residual.
    let be = MockBackend::new();
    let u = randn(&[2, L, D], 21);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(0, u.as_f32().unwrap(), 2));
    // PrevLayer init: z⁰ reuses y's device handle, so [B,L,D] uploads == 1.
    let cfg =
        JacobiConfig { tau: 1e-6, init: InitStrategy::PrevLayer, ..Default::default() };
    let (zv, stats) =
        jacobi_decode_block_v(&be, "mock_block_jstep_b2", 0, &Value::Host(v), L, &cfg, 0)
            .unwrap();
    assert!(stats.iterations >= 3, "strong coupling should take several iters");
    // Exactly one host→device upload of the block input y.
    assert_eq!(be.uploads_of(&[2, L, D]), 1, "y must be uploaded exactly once");
    // No host-marshalled inputs ever reach the jstep artifact.
    assert_eq!(be.promoted("mock_block_jstep_b2"), 0);
    // Per iteration, only the [B] residual crosses back.
    assert_eq!(be.syncs_of(&[2]), stats.iterations);
    assert_eq!(be.syncs_of(&[2, L, D]), 0, "the iterate must stay on device");
    // The result is still device-resident; fetching it is the caller's sync.
    assert!(zv.is_device());
    let z = be.to_host(zv).unwrap();
    assert_eq!(be.syncs_of(&[2, L, D]), 1);
    assert!(max_abs_diff(&u, &z) < 1e-4);
}

#[test]
fn jacobi_zeros_init_uploads_iterate_once() {
    // Zeros init costs one extra [B,L,D] upload (z⁰) — but still none per
    // iteration, whatever the iteration count.
    let be = MockBackend::new();
    let y = randn(&[2, L, D], 22);
    let cfg = JacobiConfig { tau: 0.0, max_iters: Some(6), ..Default::default() };
    let (_, stats) =
        jacobi_decode_block_v(&be, "mock_block_jstep_b2", 0, &Value::Host(y), L, &cfg, 0)
            .unwrap();
    assert_eq!(stats.iterations, 6);
    assert_eq!(be.uploads_of(&[2, L, D]), 2, "y + z⁰, independent of iterations");
    assert_eq!(be.promoted("mock_block_jstep_b2"), 0);
}

#[test]
fn sequential_decode_matches_jacobi_fixed_point() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let u = randn(&[2, L, D], 3);
    let v_vec = be.flow.fwd(1, u.as_f32().unwrap(), 2);
    let v = HostTensor::f32(&[2, L, D], v_vec);
    let (u_seq, steps) = sampler.sequential_decode_block(1, &v).unwrap();
    assert_eq!(steps, L);
    let err = max_abs_diff(&u, &u_seq);
    assert!(err < 1e-4, "sequential inverse error {err}");
}

#[test]
fn sequential_decode_keeps_kv_caches_device_resident() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let kv_shape = [NL, 2, L, DM];
    let u = randn(&[2, L, D], 23);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(1, u.as_f32().unwrap(), 2));
    let (u_seq, _) = sampler.sequential_decode_block(1, &v).unwrap();
    assert!(max_abs_diff(&u, &u_seq) < 1e-4);
    // The two zero caches upload once each (pool cache) and NEVER sync back.
    assert_eq!(be.uploads_of(&kv_shape), 2, "kv_k + kv_v zeros, uploaded once");
    assert_eq!(be.syncs_of(&kv_shape), 0, "KV caches must never round-trip");
    // Per step the artifact sees exactly two host inputs: v_tok and pos.
    assert_eq!(be.promoted("mock_block_seqstep_b2"), 2 * L);
    // A second block reuses the pooled zero caches: still 2 uploads total.
    let v2 = HostTensor::f32(&[2, L, D], be.flow.fwd(2, u.as_f32().unwrap(), 2));
    let _ = sampler.sequential_decode_block(2, &v2).unwrap();
    assert_eq!(be.uploads_of(&kv_shape), 2, "pooled zeros reused across blocks");
    assert_eq!(be.syncs_of(&kv_shape), 0);
}

#[test]
fn policy_routes_blocks_correctly() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 4);
    let opts = SampleOptions {
        policy: DecodePolicy::Selective { seq_blocks: 1 },
        ..Default::default()
    };
    let out = sampler.decode_tokens(z, &opts).unwrap();
    assert_eq!(out.traces.len(), K);
    assert!(!out.traces[0].used_jacobi, "first decode position must be sequential");
    for t in &out.traces[1..] {
        assert!(t.used_jacobi);
    }
    // Sequential position consumed exactly L seqstep calls.
    assert_eq!(be.count("mock_block_seqstep_b2"), L);
    // Block indices run K-1 .. 0.
    let blocks: Vec<usize> = out.traces.iter().map(|t| t.block).collect();
    assert_eq!(blocks, vec![3, 2, 1, 0]);
}

#[test]
fn uniform_jacobi_never_calls_seqstep() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 5);
    let opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    let _ = sampler.decode_tokens(z, &opts).unwrap();
    assert_eq!(be.count("mock_block_seqstep_b2"), 0);
    assert!(be.count("mock_block_jstep_b2") >= K);
}

#[test]
fn decode_tokens_chains_blocks_device_to_device() {
    // With the device-side reverse artifact available, a full uniform-Jacobi
    // decode fetches the [B,L,D] tokens exactly once — at the very end.
    let be = MockBackend::with_device_reverse();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 6);
    let mut opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    opts.jacobi.tau = 1e-7;
    let out = sampler.decode_tokens(z, &opts).unwrap();
    assert_eq!(out.traces.len(), K);
    assert_eq!(be.syncs_of(&[2, L, D]), 1, "tokens fetched once at the end");
    // Odd-k reversal ran device-side (K=4 ⇒ blocks 3 and 1 are odd).
    assert_eq!(be.count("mock_reverse_b2"), 2);
    // Exactly two [B,L,D] uploads for the whole K-block decode: the latent
    // (as the first block's y) and ONE pooled z⁰ shared by all Jacobi blocks.
    assert_eq!(be.uploads_of(&[2, L, D]), 2);
}

#[test]
fn decode_without_reverse_artifact_syncs_once_per_odd_block() {
    // Host-fallback reversal: each odd-k block adds one documented [B,L,D]
    // sync, plus the final tokens fetch.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 7);
    let mut opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    opts.jacobi.tau = 1e-7;
    let _ = sampler.decode_tokens(z, &opts).unwrap();
    assert_eq!(be.count("mock_reverse_b2"), 0);
    // K=4: odd blocks 3 and 1 ⇒ 2 reversal syncs + 1 final fetch.
    assert_eq!(be.syncs_of(&[2, L, D]), 3);
}

#[test]
fn decode_then_encode_is_identity() {
    // Full decode (all policies exact) followed by the rust-composed forward
    // must reproduce the prior — validates permutation handling end to end
    // against the mock flow, on both reversal paths.
    for be in [MockBackend::new(), MockBackend::with_device_reverse()] {
        let sampler = mk_sampler(&be);
        let z0 = randn(&[2, L, D], 8);
        let mut opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
        opts.jacobi.tau = 1e-7;
        let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();

        // Re-encode: h_{k+1} = A_k(P_k h_k).
        let mut h = out.tokens;
        for k in 0..K {
            let u = if k % 2 == 1 { sampler.reverse_tokens(&h).unwrap() } else { h };
            h = sampler.block_forward(k, &u).unwrap();
        }
        let err = max_abs_diff(&z0, &h);
        assert!(err < 1e-3, "decode∘encode identity error {err}");
    }
}

#[test]
fn masked_decode_deviates_more_with_larger_o() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let u = randn(&[2, L, D], 9);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(0, u.as_f32().unwrap(), 2));
    let cfg = JacobiConfig { tau: 1e-7, ..Default::default() };
    let mut errs = Vec::new();
    for o in [0usize, 2, 5] {
        let (u_rec, _) = sampler.jacobi_decode(0, &v, &cfg, o).unwrap();
        let err: f32 = u
            .as_f32()
            .unwrap()
            .iter()
            .zip(u_rec.as_f32().unwrap())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        errs.push(err);
    }
    assert!(errs[0] < 1e-3, "o=0 must be exact: {errs:?}");
    assert!(errs[1] > errs[0] && errs[2] > errs[1], "monotone in o: {errs:?}");
}

#[test]
fn trace_accounting_sums() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 10);
    let out = sampler.decode_tokens(z, &SampleOptions::default()).unwrap();
    let jacobi_iters: usize =
        out.traces.iter().filter(|t| t.used_jacobi).map(|t| t.steps).sum();
    assert_eq!(out.total_jacobi_iters(), jacobi_iters);
    assert_eq!(be.count("mock_block_jstep_b2"), jacobi_iters);
    let decode_total: std::time::Duration = out.traces.iter().map(|t| t.wall).sum();
    assert!(out.total_wall >= decode_total);
}

#[test]
fn max_iters_cap_respected() {
    let be = MockBackend::new();
    let y = randn(&[2, L, D], 11);
    let cfg = JacobiConfig { tau: 0.0, max_iters: Some(3), ..Default::default() };
    let (_, stats) = jacobi_decode_block(&be, "m_block_jstep", 0, &y, L, &cfg, 0).unwrap();
    assert_eq!(stats.iterations, 3);
    assert!(!stats.converged);
}

#[test]
fn reverse_tokens_is_an_involution_on_non_square_shapes() {
    // L=8 ≠ D=3: reversing twice must be the identity, and reversing once
    // must not be (catches silent no-op or transpose-style bugs).
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let t = randn(&[2, L, D], 12);
    let r = sampler.reverse_tokens(&t).unwrap();
    assert_ne!(r.as_f32().unwrap(), t.as_f32().unwrap());
    let rr = sampler.reverse_tokens(&r).unwrap();
    assert_eq!(rr, t, "reverse∘reverse must be the identity");
    // Spot-check the permutation: token l maps to token L-1-l.
    let td = t.as_f32().unwrap();
    let rd = r.as_f32().unwrap();
    for bi in 0..2 {
        for li in 0..L {
            let src = &td[(bi * L + li) * D..(bi * L + li + 1) * D];
            let dst = &rd[(bi * L + (L - 1 - li)) * D..(bi * L + (L - 1 - li) + 1) * D];
            assert_eq!(src, dst);
        }
    }
    // The value-path reversal agrees with the host path, both with and
    // without the device gather artifact.
    for be2 in [MockBackend::new(), MockBackend::with_device_reverse()] {
        let s2 = mk_sampler(&be2);
        let rv = s2.reverse_tokens_v(&Value::Host(t.clone())).unwrap();
        assert_eq!(be2.to_host(rv).unwrap(), r);
    }
}

#[test]
fn patchify_unpatchify_roundtrip_non_square() {
    // Mock geometry is a non-square 2×4 grid (patch 1, 3 channels):
    // unpatchify∘patchify and patchify∘unpatchify must both be exact.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let [h, w, c] = sampler.meta.image_hwc.unwrap();
    assert_ne!(h, w, "test requires a non-square image grid");
    let mut rng = Pcg64::seed(13);
    let imgs: Vec<Tensor> = (0..2).map(|_| Tensor::randn(&[h, w, c], &mut rng)).collect();

    let toks = sampler.patchify(&imgs).unwrap();
    assert_eq!(toks.shape(), &[2, L, D]);
    let back = sampler.unpatchify(&toks).unwrap();
    assert_eq!(back.len(), imgs.len());
    for (a, b) in imgs.iter().zip(&back) {
        assert!(a.mse(b).unwrap() < 1e-12, "image roundtrip drift");
    }

    // tokens → images → tokens.
    let toks2 = randn(&[2, L, D], 14);
    let imgs2 = sampler.unpatchify(&toks2).unwrap();
    let toks2_back = sampler.patchify(&imgs2).unwrap();
    assert_eq!(toks2_back, toks2, "token roundtrip must be exact");
}

// ---------------------------------------------------------------------------
// Windowed GS-Jacobi decoding
// ---------------------------------------------------------------------------

#[test]
fn gs_jacobi_bit_exact_with_sequential() {
    // With τ = 0 every window runs its exactness cap (`len` iterations,
    // Prop 3.2 per window), so the GS sweep must reproduce the sequential
    // decode BIT-EXACTLY — same conditioner arithmetic on exactly-converged
    // prefixes — for every window count, including W=1 (plain Jacobi),
    // W=L (sequential-equivalent) and non-divisible partitions.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let u = randn(&[2, L, D], 31);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(1, u.as_f32().unwrap(), 2));
    let (u_seq, _) = sampler.sequential_decode_block(1, &v).unwrap();
    let exact = JacobiConfig { tau: 0.0, ..Default::default() };
    for windows in [1, 2, 3, 5, L] {
        let (u_gs, stats) =
            gs_jacobi_decode_block(&be, "mock_block_jstep_win_b2", 1, &v, L, windows, &exact)
                .unwrap();
        assert_eq!(
            u_gs.as_f32().unwrap(),
            u_seq.as_f32().unwrap(),
            "W={windows} must be bit-exact with sequential decode"
        );
        // τ = 0 ⇒ every window ran its full exactness cap.
        let expected: usize = window_partition(L, windows).iter().map(|(_, l)| l * l).sum();
        assert_eq!(stats.position_updates, expected);
        assert_eq!(stats.windows.len(), windows.min(L));
    }
}

#[test]
fn gs_w1_matches_plain_jacobi_bitwise() {
    // W=1 runs the identical per-iteration arithmetic as full-sequence
    // Jacobi (one window covering everything), so even intermediate-τ runs
    // are bitwise interchangeable at τ = 0 / full cap.
    let be = MockBackend::new();
    let y = randn(&[2, L, D], 32);
    let cfg = JacobiConfig { tau: 0.0, ..Default::default() };
    let (z_gs, gstats) =
        gs_jacobi_decode_block(&be, "m_jstep_win", 0, &y, L, 1, &cfg).unwrap();
    let cfg_j = JacobiConfig { tau: 0.0, max_iters: Some(L), ..Default::default() };
    let (z_j, jstats) = jacobi_decode_block(&be, "m_block_jstep", 0, &y, L, &cfg_j, 0).unwrap();
    assert_eq!(z_gs.as_f32().unwrap(), z_j.as_f32().unwrap());
    assert_eq!(gstats.iterations, jstats.iterations);
    assert_eq!(gstats.position_updates, L * L);
}

#[test]
fn gs_fewer_position_updates_than_ujd_at_equal_tau() {
    // The acceptance property: at the same τ, the windowed sweep performs
    // strictly fewer position-updates than full-sequence Jacobi on a
    // strongly coupled block, while converging to the same fixed point.
    let be = MockBackend::new();
    let u = randn(&[2, L, D], 33);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(0, u.as_f32().unwrap(), 2));
    let tau = 1e-5f32;
    let cfg = JacobiConfig { tau, ..Default::default() };
    let (z_ujd, ujd) = jacobi_decode_block(&be, "m_block_jstep", 0, &v, L, &cfg, 0).unwrap();
    let ujd_updates = ujd.iterations * L;
    for windows in [2, 4] {
        let (z_gs, gs) =
            gs_jacobi_decode_block(&be, "m_jstep_win", 0, &v, L, windows, &cfg).unwrap();
        assert!(gs.converged, "W={windows} must converge at τ={tau}");
        assert!(
            gs.position_updates < ujd_updates,
            "W={windows}: {} position-updates vs UJD's {ujd_updates}",
            gs.position_updates
        );
        assert!(max_abs_diff(&z_gs, &z_ujd) < 10.0 * tau);
        assert!(max_abs_diff(&z_gs, &u) < 10.0 * tau);
    }
}

#[test]
fn gs_front_tracking_and_window_stats() {
    let be = MockBackend::new();
    let u = randn(&[2, L, D], 34);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(2, u.as_f32().unwrap(), 2));
    // Short windows at tight τ: every window runs its full exactness cap
    // (the last movement inside a 2-position window exceeds τ), yet the
    // front advances to L via Prop 3.2 and the result is final.
    let cfg = JacobiConfig { tau: 1e-6, ..Default::default() };
    let (_, stats) = gs_jacobi_decode_block(&be, "m_jstep_win", 2, &v, L, 4, &cfg).unwrap();
    assert!(stats.converged);
    assert_eq!(stats.front, vec![L, L]);
    // Window bookkeeping is consistent with the partition.
    let parts = window_partition(L, 4);
    assert_eq!(stats.windows.len(), parts.len());
    let mut iter_sum = 0;
    let mut update_sum = 0;
    for (ws, (off, len)) in stats.windows.iter().zip(parts) {
        assert_eq!((ws.offset, ws.len), (off, len));
        assert!(ws.iterations >= 1 && ws.iterations <= len);
        assert_eq!(ws.residuals.len(), ws.iterations);
        iter_sum += ws.iterations;
        update_sum += ws.iterations * len;
    }
    assert_eq!(stats.iterations, iter_sum);
    assert_eq!(stats.position_updates, update_sum);

    // Weak coupling + a long window + loose τ: the movement contracts below
    // τ before the cap, so per-element converged_at records the τ iteration
    // and the window is τ-certified.
    let cfg = JacobiConfig { tau: 1e-2, ..Default::default() };
    let (_, stats) = gs_jacobi_decode_block(&be, "m_jstep_win", 2, &v, L, 1, &cfg).unwrap();
    assert!(stats.converged);
    assert_eq!(stats.front, vec![L, L]);
    let ws = &stats.windows[0];
    assert!(ws.converged, "weak coupling must τ-converge before the cap");
    assert!(ws.iterations < L, "τ must stop the window early, got {}", ws.iterations);
    for c in &ws.converged_at {
        let c = c.expect("converged_at recorded per batch element");
        assert!(c >= 1 && c <= ws.iterations);
    }

    // max_iters is a TOTAL budget shared across windows (same meaning as in
    // plain Jacobi): one iteration overall, not one per window — once it is
    // exhausted the sweep STOPS (no empty WindowStats for windows that
    // could never run), and with τ never fired and the exactness cap never
    // completed, the front must not advance.
    let cfg = JacobiConfig { tau: 1e-9, max_iters: Some(1), ..Default::default() };
    let (_, stats) = gs_jacobi_decode_block(&be, "m_jstep_win", 0, &v, L, 2, &cfg).unwrap();
    assert_eq!(stats.iterations, 1, "budget of 1 must cover the whole block");
    assert_eq!(
        stats.windows.len(),
        1,
        "the sweep must stop once the budget is exhausted mid-block"
    );
    assert!(!stats.converged);
    assert_eq!(stats.front, vec![0, 0]);
}

#[test]
fn gs_keeps_iterate_device_resident() {
    // Same traffic contract as full-sequence Jacobi: y uploads once, the
    // iterate chains device→device across windows AND iterations, only the
    // [B] windowed residual syncs per iteration.
    let be = MockBackend::new();
    let u = randn(&[2, L, D], 35);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(0, u.as_f32().unwrap(), 2));
    let cfg =
        JacobiConfig { tau: 1e-6, init: InitStrategy::PrevLayer, ..Default::default() };
    let (zv, stats) = gs_jacobi_decode_block_v(
        &be,
        "mock_block_jstep_win_b2",
        0,
        &Value::Host(v),
        L,
        4,
        &cfg,
        None,
        None,
    )
    .unwrap();
    // PrevLayer init: z⁰ reuses y's device handle ⇒ exactly one upload.
    assert_eq!(be.uploads_of(&[2, L, D]), 1, "y must be uploaded exactly once");
    assert_eq!(be.promoted("mock_block_jstep_win_b2"), 0);
    assert_eq!(be.syncs_of(&[2]), stats.iterations);
    assert_eq!(be.syncs_of(&[2, L, D]), 0, "the iterate must stay on device");
    assert!(zv.is_device());
    let z = be.to_host(zv).unwrap();
    assert_eq!(be.syncs_of(&[2, L, D]), 1);
    assert!(max_abs_diff(&u, &z) < 1e-3);
}

#[test]
fn decode_tokens_gs_policy_routes_and_accounts() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 36);
    let mut opts =
        SampleOptions { policy: DecodePolicy::GsJacobi { windows: 2 }, ..Default::default() };
    opts.jacobi.tau = 1e-7;
    let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    assert_eq!(be.count("mock_block_seqstep_b2"), 0);
    assert_eq!(be.count("mock_block_jstep_b2"), 0, "GS policy must not call plain jstep");
    assert!(be.count("mock_block_jstep_win_b2") >= K);
    let mut updates = 0;
    for t in &out.traces {
        assert!(t.used_jacobi);
        let gs = t.gs.as_ref().expect("gs stats recorded");
        assert!(t.jacobi.is_none());
        assert_eq!(t.steps, gs.iterations);
        assert_eq!(t.position_updates, gs.position_updates);
        updates += gs.position_updates;
    }
    assert_eq!(out.total_position_updates(), updates);

    // Decode∘encode identity holds through the GS path too.
    let mut h = out.tokens;
    for k in 0..K {
        let u = if k % 2 == 1 { sampler.reverse_tokens(&h).unwrap() } else { h };
        h = sampler.block_forward(k, &u).unwrap();
    }
    assert!(max_abs_diff(&z0, &h) < 1e-3, "decode∘encode identity through GS");
}

#[test]
fn gs_policy_falls_back_to_jacobi_without_artifact() {
    // Artifact dirs lowered before the windowed step exist: the sampler must
    // degrade GS block modes to full-sequence Jacobi, not fail.
    let be = MockBackend::without_jstep_win();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 37);
    let mut opts =
        SampleOptions { policy: DecodePolicy::GsJacobi { windows: 4 }, ..Default::default() };
    opts.jacobi.tau = 1e-7;
    let out = sampler.decode_tokens(z0, &opts).unwrap();
    assert_eq!(be.count("mock_block_jstep_win_b2"), 0);
    assert!(be.count("mock_block_jstep_b2") >= K);
    for t in &out.traces {
        assert!(t.used_jacobi);
        assert!(t.gs.is_none(), "fallback must be recorded as plain Jacobi");
        assert!(t.jacobi.is_some());
        assert_eq!(t.position_updates, t.steps * L);
    }

    // A masked (eq-6) decode must also bypass the windowed artifact even
    // when it exists: jstep_win computes the exact o=0 update only, and
    // mask semantics must not depend on the lowered artifact set.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 39);
    let opts = SampleOptions {
        policy: DecodePolicy::GsJacobi { windows: 4 },
        mask_o: 2,
        ..Default::default()
    };
    let _ = sampler.decode_tokens(z0, &opts).unwrap();
    assert_eq!(be.count("mock_block_jstep_win_b2"), 0);
    assert!(be.count("mock_block_jstep_b2") >= K);
}

#[test]
fn per_block_policy_mixes_all_three_modes() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 38);
    let mut opts = SampleOptions {
        policy: DecodePolicy::PerBlock {
            modes: vec![
                BlockDecode::Sequential,
                BlockDecode::GsJacobi { windows: 2 },
                BlockDecode::Jacobi,
                BlockDecode::GsJacobi { windows: L },
            ],
        },
        ..Default::default()
    };
    opts.jacobi.tau = 1e-7;
    let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    assert_eq!(be.count("mock_block_seqstep_b2"), L);
    assert!(be.count("mock_block_jstep_b2") >= 1);
    assert!(be.count("mock_block_jstep_win_b2") >= 2);
    assert!(!out.traces[0].used_jacobi);
    assert!(out.traces[1].gs.is_some());
    assert!(out.traces[2].jacobi.is_some());
    assert!(out.traces[3].gs.is_some());
    // The W=L position got one exact update per position.
    assert_eq!(out.traces[3].position_updates, L);
    assert_eq!(out.traces[0].position_updates, L);

    // End-to-end correctness across mixed modes.
    let mut h = out.tokens;
    for k in 0..K {
        let u = if k % 2 == 1 { sampler.reverse_tokens(&h).unwrap() } else { h };
        h = sampler.block_forward(k, &u).unwrap();
    }
    assert!(max_abs_diff(&z0, &h) < 1e-3);
}

// ---------------------------------------------------------------------------
// Fused multi-step chunked decoding
// ---------------------------------------------------------------------------

#[test]
fn fused_bit_exact_with_per_iteration_at_tau0_and_ledger_pins_syncs() {
    // τ = 0 never stops early: both drivers run exactly L updates of the
    // same arithmetic, so the iterates must agree BIT-EXACTLY for every
    // chunk schedule — while host syncs drop from `iterations` (one [B]
    // residual per step) to the chunk count (one [S,B] history per chunk),
    // ⌈iterations/S⌉ when the first chunk is seeded at S.
    let s_max = MockFlow::standard().fuse_s_max;
    let tau0 = JacobiConfig { tau: 0.0, ..Default::default() };
    let u = randn(&[2, L, D], 50);
    let be_ref = MockBackend::new();
    let v = HostTensor::f32(&[2, L, D], be_ref.flow.fwd(0, u.as_f32().unwrap(), 2));
    let (z_ref, ref_stats) = jacobi_decode_block_v(
        &be_ref,
        "mock_block_jstep_b2",
        0,
        &Value::Host(v.clone()),
        L,
        &tau0,
        0,
    )
    .unwrap();
    assert_eq!(ref_stats.iterations, L);
    assert_eq!(ref_stats.host_syncs, L, "per-iteration driver syncs every τ test");
    let z_ref = be_ref.to_host(z_ref).unwrap();

    for first_chunk in [1usize, 3, s_max, L] {
        let be = MockBackend::new();
        let (zv, stats) = jacobi_decode_block_fused_v(
            &be,
            "mock_block_jstep_fuse_b2",
            0,
            &Value::Host(v.clone()),
            L,
            &tau0,
            None,
            None,
            first_chunk,
        )
        .unwrap();
        assert_eq!(stats.iterations, L, "chunk={first_chunk}");
        assert!(!stats.converged, "τ=0 never τ-converges, like the per-step driver");
        assert_eq!(stats.residuals, ref_stats.residuals, "chunk={first_chunk}");
        // After the seed chunk, τ=0 chunks are maximal (S_max-sized) —
        // ⌈L/S⌉ total when seeded at S (the acceptance formula).
        let expected_chunks = 1 + (L - first_chunk.min(s_max)).div_ceil(s_max);
        assert_eq!(stats.host_syncs, expected_chunks, "chunk={first_chunk}");
        assert_eq!(
            be.syncs_of(&[s_max, 2]),
            expected_chunks,
            "ledger: exactly one [S,B] history sync per chunk"
        );
        assert_eq!(be.syncs_of(&[2]), 0, "no per-iteration [B] syncs on the fused path");
        assert_eq!(be.syncs_of(&[2, L, D]), 0, "the iterate must stay on device");
        assert_eq!(be.promoted("mock_block_jstep_fuse_b2"), 0);
        let z = be.to_host(zv).unwrap();
        assert_eq!(be.syncs_of(&[2, L, D]), 1, "+1 for the final iterate");
        assert_eq!(
            z.as_f32().unwrap(),
            z_ref.as_f32().unwrap(),
            "bit-exact with the per-iteration driver at τ=0 (chunk={first_chunk})"
        );
    }
    // The acceptance numbers spelled out: seeding at S = s_max gives
    // ⌈L/S⌉ = 2 syncs for this block instead of the per-iteration L = 8.
    assert_eq!(1 + (L - s_max).div_ceil(s_max), L.div_ceil(s_max));
}

#[test]
fn fused_matches_per_iteration_at_default_tau() {
    // Default τ = 0.5: a calibrated first-chunk hint (the block's measured
    // iteration count, what `calibrate_chunks` seeds) lands the chunk
    // exactly on the τ crossing — ONE host sync, bit-identical iterate.
    let cfg = JacobiConfig::default();
    assert_eq!(cfg.tau, 0.5);
    let u = randn(&[2, L, D], 51);
    let be = MockBackend::new();
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(2, u.as_f32().unwrap(), 2));
    let (z_ref, ref_stats) = jacobi_decode_block_v(
        &be,
        "mock_block_jstep_b2",
        2,
        &Value::Host(v.clone()),
        L,
        &cfg,
        0,
    )
    .unwrap();
    let z_ref = be.to_host(z_ref).unwrap();
    let t = ref_stats.iterations;
    assert!(
        ref_stats.converged && t >= 2 && t <= MockFlow::standard().fuse_s_max,
        "weakly coupled mock block must τ-converge within one fused chunk, got {t}"
    );

    let be2 = MockBackend::new();
    let (zv, stats) = jacobi_decode_block_fused_v(
        &be2,
        "mock_block_jstep_fuse_b2",
        2,
        &Value::Host(v.clone()),
        L,
        &cfg,
        None,
        None,
        t,
    )
    .unwrap();
    assert!(stats.converged);
    assert_eq!(stats.iterations, t);
    assert_eq!(stats.residuals, ref_stats.residuals);
    assert_eq!(stats.host_syncs, 1, "calibrated hint ⇒ single-chunk decode");
    let z = be2.to_host(zv).unwrap();
    assert_eq!(
        z.as_f32().unwrap(),
        z_ref.as_f32().unwrap(),
        "bit-exact at τ=0.5 with the calibrated chunk seed"
    );

    // An uncalibrated 1-step seed still recovers the exact per-iteration
    // STATS (τ stop, residual prefix, convergence flag); the iterate may
    // carry documented overshoot steps past τ, which only contract it
    // further toward the same fixed point.
    let be3 = MockBackend::new();
    let (zv3, stats3) = jacobi_decode_block_fused_v(
        &be3,
        "mock_block_jstep_fuse_b2",
        2,
        &Value::Host(v.clone()),
        L,
        &cfg,
        None,
        None,
        1,
    )
    .unwrap();
    assert!(stats3.converged);
    assert_eq!(stats3.iterations, t);
    assert_eq!(stats3.residuals, ref_stats.residuals);
    assert!(stats3.host_syncs <= ref_stats.host_syncs);
    let z3 = be3.to_host(zv3).unwrap();
    let err_ref = max_abs_diff(&z_ref, &u);
    let err3 = max_abs_diff(&z3, &u);
    assert!(err3 <= err_ref + 1e-6, "overshoot must not regress accuracy");
}

#[test]
fn gs_fused_bit_exact_at_tau0_with_fewer_syncs() {
    // Chunked GS sweep: τ = 0 runs every window's full exactness cap, so
    // the fused windowed driver must reproduce sequential decode
    // bit-exactly (like the per-iteration GS sweep) while syncing once per
    // chunk instead of once per inner iteration.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let u = randn(&[2, L, D], 52);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(1, u.as_f32().unwrap(), 2));
    let (u_seq, _) = sampler.sequential_decode_block(1, &v).unwrap();
    let exact = JacobiConfig { tau: 0.0, ..Default::default() };
    let s_max = MockFlow::standard().fuse_s_max;
    for windows in [1usize, 2, 3, L] {
        let be2 = MockBackend::new();
        let (zv, stats) = gs_jacobi_decode_block_fused_v(
            &be2,
            "mock_block_jstep_win_fuse_b2",
            1,
            &Value::Host(v.clone()),
            L,
            windows,
            &exact,
            None,
            None,
            s_max,
        )
        .unwrap();
        let z = be2.to_host(zv).unwrap();
        assert_eq!(
            z.as_f32().unwrap(),
            u_seq.as_f32().unwrap(),
            "W={windows} fused sweep must be bit-exact with sequential decode"
        );
        // Same per-iteration accounting as the per-iteration sweep …
        let expected: usize = window_partition(L, windows).iter().map(|(_, l)| l * l).sum();
        assert_eq!(stats.position_updates, expected);
        assert!(stats.converged);
        assert_eq!(stats.front, vec![L, L]);
        // … with chunk-level sync accounting: Σ over windows of ⌈len/S⌉.
        let expected_syncs: usize =
            window_partition(L, windows).iter().map(|(_, l)| l.div_ceil(s_max)).sum();
        assert_eq!(stats.host_syncs, expected_syncs, "W={windows}");
        assert_eq!(be2.syncs_of(&[s_max, 2]), stats.host_syncs);
        assert_eq!(be2.syncs_of(&[2]), 0, "no per-iteration [B] syncs");
    }
    // Spelled out for W=2 (window len 4 = S_max): 8 iterations, 2 syncs.
    let be3 = MockBackend::new();
    let (_, stats) = gs_jacobi_decode_block_fused_v(
        &be3,
        "mock_block_jstep_win_fuse_b2",
        1,
        &Value::Host(v.clone()),
        L,
        2,
        &exact,
        None,
        None,
        s_max,
    )
    .unwrap();
    assert_eq!(stats.iterations, L);
    assert_eq!(stats.host_syncs, 2);
}

#[test]
fn decode_tokens_fused_policy_routes_and_accounts() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 53);
    let mut opts =
        SampleOptions { policy: DecodePolicy::Fused { chunk: 4 }, ..Default::default() };
    opts.jacobi.tau = 1e-7;
    let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    assert_eq!(be.count("mock_block_jstep_b2"), 0, "fused policy must not call the per-step artifact");
    assert!(be.count("mock_block_jstep_fuse_b2") >= K);
    let mut syncs = 0;
    for t in &out.traces {
        assert!(t.used_jacobi);
        let j = t.jacobi.as_ref().expect("fused decode records JacobiStats");
        assert_eq!(t.steps, j.iterations);
        assert_eq!(t.host_syncs, j.host_syncs);
        assert!(t.host_syncs <= t.steps);
        syncs += t.host_syncs;
    }
    assert_eq!(out.total_host_syncs(), syncs);
    assert!(
        out.total_host_syncs() < out.total_jacobi_iters(),
        "chunking must reduce host syncs ({} vs {} iters)",
        out.total_host_syncs(),
        out.total_jacobi_iters()
    );

    // decode∘encode identity holds through the fused path.
    let mut h = out.tokens;
    for k in 0..K {
        let u = if k % 2 == 1 { sampler.reverse_tokens(&h).unwrap() } else { h };
        h = sampler.block_forward(k, &u).unwrap();
    }
    assert!(max_abs_diff(&z0, &h) < 1e-3, "decode∘encode identity through fused decode");
}

#[test]
fn decode_tokens_gs_fused_policy_routes() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 57);
    let mut opts = SampleOptions {
        policy: DecodePolicy::PerBlock {
            modes: vec![BlockDecode::GsFused { windows: 2, chunk: 4 }; K],
        },
        ..Default::default()
    };
    opts.jacobi.tau = 1e-7;
    let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    assert!(be.count("mock_block_jstep_win_fuse_b2") >= K);
    assert_eq!(be.count("mock_block_jstep_win_b2"), 0);
    assert_eq!(be.count("mock_block_jstep_b2"), 0);
    for t in &out.traces {
        let gs = t.gs.as_ref().expect("gs stats recorded");
        assert_eq!(t.host_syncs, gs.host_syncs);
        assert!(t.host_syncs <= t.steps);
    }
    let mut h = out.tokens;
    for k in 0..K {
        let u = if k % 2 == 1 { sampler.reverse_tokens(&h).unwrap() } else { h };
        h = sampler.block_forward(k, &u).unwrap();
    }
    assert!(max_abs_diff(&z0, &h) < 1e-3);
}

#[test]
fn fused_policy_falls_back_without_artifacts_and_for_masked_decodes() {
    // Pre-fusion artifact dir: Fused degrades to plain per-iteration Jacobi.
    let be = MockBackend::without_fuse();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 54);
    let opts =
        SampleOptions { policy: DecodePolicy::Fused { chunk: 4 }, ..Default::default() };
    let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    assert_eq!(be.count("mock_block_jstep_fuse_b2"), 0);
    assert!(be.count("mock_block_jstep_b2") >= K);
    for t in &out.traces {
        assert_eq!(t.host_syncs, t.steps, "per-iteration fallback syncs every iteration");
    }

    // A masked eq-6 decode bypasses the fused artifact even when present:
    // it computes the exact o = 0 update only.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 55);
    let opts = SampleOptions {
        policy: DecodePolicy::Fused { chunk: 4 },
        mask_o: 2,
        ..Default::default()
    };
    let _ = sampler.decode_tokens(z0, &opts).unwrap();
    assert_eq!(be.count("mock_block_jstep_fuse_b2"), 0);
    assert!(be.count("mock_block_jstep_b2") >= K);

    // GsFused degrades one step at a time: no win_fuse → per-iteration GS;
    // no windowed step either → plain Jacobi.
    let modes = vec![BlockDecode::GsFused { windows: 2, chunk: 4 }; K];
    let be = MockBackend::without_fuse();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 56);
    let opts = SampleOptions {
        policy: DecodePolicy::PerBlock { modes: modes.clone() },
        ..Default::default()
    };
    let _ = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    assert_eq!(be.count("mock_block_jstep_win_fuse_b2"), 0);
    assert!(be.count("mock_block_jstep_win_b2") >= K);

    let be = MockBackend { windowed_jstep: false, ..MockBackend::without_fuse() };
    let sampler = mk_sampler(&be);
    let opts = SampleOptions { policy: DecodePolicy::PerBlock { modes }, ..Default::default() };
    let _ = sampler.decode_tokens(z0, &opts).unwrap();
    assert_eq!(be.count("mock_block_jstep_win_fuse_b2"), 0);
    assert_eq!(be.count("mock_block_jstep_win_b2"), 0);
    assert!(be.count("mock_block_jstep_b2") >= K);
}

#[test]
fn scalar_loop_constants_upload_once_per_value() {
    // Satellite contract: the pool pins i32 loop constants (k, mask_o,
    // window off/len, chunk sizes) once per distinct value — a second
    // decode through the same sampler re-uploads NO scalars at all.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let mut opts = SampleOptions {
        policy: DecodePolicy::PerBlock {
            modes: vec![
                BlockDecode::Sequential,
                BlockDecode::Jacobi,
                BlockDecode::GsJacobi { windows: 2 },
                BlockDecode::Fused { chunk: 3 },
            ],
        },
        ..Default::default()
    };
    opts.jacobi.tau = 1e-7;
    let z0 = randn(&[2, L, D], 58);
    let _ = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    let scalars_after_first = be.uploads_of(&[]);
    assert!(scalars_after_first > 0, "first decode pins its scalar constants");
    let _ = sampler.decode_tokens(z0, &opts).unwrap();
    assert_eq!(
        be.uploads_of(&[]),
        scalars_after_first,
        "second decode must reuse every pinned scalar"
    );
}

// ---------------------------------------------------------------------------
// Bucketed sampler sets
// ---------------------------------------------------------------------------

#[test]
fn sampler_set_selects_smallest_covering_bucket() {
    let be = MockServeBackend::new(&[4, 1, 2], std::time::Duration::ZERO, MockLedger::new());
    let set = SamplerSet::new(&be, "mock", &[]).unwrap();
    assert_eq!(set.buckets(), vec![1, 2, 4], "buckets sorted ascending");
    assert_eq!(set.max_bucket(), 4);
    assert_eq!(set.meta().seq_len, L);
    assert_eq!(set.select(1).batch, 1);
    assert_eq!(set.select(2).batch, 2);
    assert_eq!(set.select(3).batch, 4, "3 slots need the next bucket up");
    assert_eq!(set.select(4).batch, 4);
    assert_eq!(set.select(9).batch, 4, "oversized batch falls back to the largest");
    // An explicitly requested bucket that was never lowered fails fast.
    assert!(SamplerSet::new(&be, "mock", &[3]).is_err());
    // An explicit subset restricts routing to it.
    let sub = SamplerSet::new(&be, "mock", &[1, 4]).unwrap();
    assert_eq!(sub.select(2).batch, 4);
}

#[test]
fn sampler_set_decodes_per_bucket_with_shared_weights() {
    // The same mock weights serve every bucket: decoding the same latent
    // content through bucket 1 and bucket 2 must agree row-for-row.
    let be = MockServeBackend::new(&[1, 2], std::time::Duration::ZERO, MockLedger::new());
    let set = SamplerSet::new(&be, "mock", &[]).unwrap();
    let mut opts =
        SampleOptions { policy: DecodePolicy::Selective { seq_blocks: 1 }, ..Default::default() };
    opts.jacobi.tau = 1e-7;
    let z1 = randn(&[1, L, D], 41);
    let mut z2_data = z1.as_f32().unwrap().to_vec();
    z2_data.extend_from_slice(z1.as_f32().unwrap());
    let z2 = HostTensor::f32(&[2, L, D], z2_data);
    let out1 = set.select(1).decode_tokens(z1, &opts).unwrap();
    let out2 = set.select(2).decode_tokens(z2, &opts).unwrap();
    let t1 = out1.tokens.as_f32().unwrap();
    let t2 = out2.tokens.as_f32().unwrap();
    assert_eq!(out1.tokens.shape(), &[1, L, D]);
    assert_eq!(out2.tokens.shape(), &[2, L, D]);
    for (a, b) in t1.iter().zip(&t2[..L * D]) {
        assert!((a - b).abs() < 1e-5, "bucket-1 and bucket-2 decodes diverged");
    }
    // Decode went through the per-bucket artifact families.
    assert!(be.ledger.count_containing("_b1") > 0);
    assert!(be.ledger.count_containing("_b2") > 0);
}

// ---------------------------------------------------------------------------
// Stage-graph pipeline
// ---------------------------------------------------------------------------

#[test]
fn pipeline_bit_exact_with_monolithic_decode() {
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};

    // Acceptance contract: the stage-graph pipeline (2 batches in flight,
    // one stage thread per block) produces bit-identical tokens, traces and
    // images to the monolithic Sampler::decode_tokens at τ = 0, across
    // policies covering every decode mode.
    let policies = vec![
        DecodePolicy::UniformJacobi,
        DecodePolicy::Selective { seq_blocks: 1 },
        DecodePolicy::PerBlock {
            modes: vec![
                BlockDecode::Sequential,
                BlockDecode::GsFused { windows: 2, chunk: 2 },
                BlockDecode::Fused { chunk: 3 },
                BlockDecode::GsJacobi { windows: 4 },
            ],
        },
    ];
    for policy in policies {
        let mut opts = SampleOptions { policy: policy.clone(), ..Default::default() };
        opts.jacobi.tau = 0.0; // exactness sweeps — the bit-exact regime

        // Pipelined decode over the shared serve mock (host-only values).
        let cfg = PipelineConfig { depth: 2, stage_threads: 0, warm_cap: 0, ..Default::default() };
        let factory = move |_stage: usize| {
            Ok(MockServeBackend::new(&[2], std::time::Duration::ZERO, MockLedger::new()))
        };
        let pipeline =
            DecodePipeline::start("mock", &[2], cfg, sjd::metrics::Registry::new(), factory)
                .unwrap();
        assert_eq!(pipeline.blocks, K);
        let results = Arc::new(Mutex::new(BTreeMap::new()));
        for seed in 0..4u64 {
            let results = results.clone();
            let job = PipelineJob {
                seeds: vec![seed, seed.wrapping_add(100)],
                opts: opts.clone(),
                done: Box::new(move |res| {
                    results.lock().unwrap().insert(seed, res.expect("pipeline decode"));
                }),
            };
            pipeline.submit(job).map_err(|_| "submit").unwrap();
        }
        pipeline.shutdown(); // drains all four batches
        let results = results.lock().unwrap();
        assert_eq!(results.len(), 4);

        // Monolithic reference, same per-slot RNG convention as stage 0.
        let be = MockServeBackend::new(&[2], std::time::Duration::ZERO, MockLedger::new());
        let sampler = Sampler::new(&be, "mock", 2).unwrap();
        for seed in 0..4u64 {
            let z = sampler.sample_prior_slots(&[seed, seed.wrapping_add(100)]);
            let want = sampler.decode_tokens(z, &opts).unwrap();
            let want_imgs = sampler.unpatchify(&want.tokens).unwrap();
            let (imgs, out) = &results[&seed];
            assert_eq!(out.tokens, want.tokens, "{} seed {seed}", policy.label());
            assert_eq!(out.traces.len(), want.traces.len());
            for (a, b) in out.traces.iter().zip(&want.traces) {
                assert_eq!(a.block, b.block);
                assert_eq!(a.steps, b.steps, "per-block steps must match");
                assert_eq!(a.position_updates, b.position_updates);
                assert_eq!(a.host_syncs, b.host_syncs);
            }
            assert_eq!(imgs.len(), want_imgs.len());
            for (a, b) in imgs.iter().zip(&want_imgs) {
                assert_eq!(a.data(), b.data(), "images must be bit-identical");
            }
        }
    }
}

#[test]
fn pipeline_reports_stage_metrics_and_inflight_bound() {
    let cfg = PipelineConfig { depth: 1, stage_threads: 2, warm_cap: 0, ..Default::default() };
    let factory = move |_stage: usize| {
        Ok(MockServeBackend::new(&[2], std::time::Duration::ZERO, MockLedger::new()))
    };
    let registry = sjd::metrics::Registry::new();
    let pipeline = DecodePipeline::start("mock", &[2], cfg, registry.clone(), factory).unwrap();
    assert_eq!(pipeline.buckets, vec![2]);
    let done = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
    for seed in 0..3u64 {
        let done = done.clone();
        let job = PipelineJob {
            seeds: vec![seed, seed.wrapping_add(100)],
            opts: SampleOptions::default(),
            done: Box::new(move |res| {
                res.expect("pipeline decode");
                done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            }),
        };
        pipeline.submit(job).map_err(|_| "submit").unwrap();
        // Depth 1: the previous batch fully completed before submit returned
        // a second time, so in-flight can never exceed the gate.
        assert!(pipeline.in_flight() <= 1);
    }
    pipeline.shutdown();
    assert_eq!(done.load(std::sync::atomic::Ordering::SeqCst), 3);
    // Both stage threads processed work and the wait histogram saw every
    // batch at every stage.
    assert_eq!(registry.histogram("sjd_stage_wait").count(), 6);
    let g0 = registry.gauge("sjd_stage_0_occupancy").get();
    let g1 = registry.gauge("sjd_stage_1_occupancy").get();
    assert_eq!((g0, g1), (0, 0), "occupancy gauges must return to zero");
}

#[test]
fn pipeline_startup_failure_errors_without_leaking_stages() {
    // One stage's backend fails to build: start() must surface the error
    // AND join the already-spawned healthy stages (this test hangs if a
    // stage is left blocked on its queue).
    let cfg = PipelineConfig { depth: 2, stage_threads: 0, warm_cap: 0, ..Default::default() };
    let factory = move |stage: usize| {
        if stage == 2 {
            anyhow::bail!("stage 2 backend exploded");
        }
        Ok(MockServeBackend::new(&[2], std::time::Duration::ZERO, MockLedger::new()))
    };
    let err = DecodePipeline::start("mock", &[2], cfg, sjd::metrics::Registry::new(), factory)
        .map(|_| ())
        .unwrap_err();
    assert!(err.to_string().contains("exploded"), "{err}");
}

// ---------------------------------------------------------------------------
// Degradation chain under partial manifests
// ---------------------------------------------------------------------------

#[test]
fn partial_manifest_routes_each_bucket_to_its_best_mode() {
    // Bucket 1's fused windowed step predates the lowering; bucket 2 is
    // fully lowered. A gs_fuse policy must route bucket 1 through the
    // per-iteration GS driver and bucket 2 through the fused one — per
    // block, per bucket, never all-or-nothing.
    let ledger = MockLedger::new();
    let be = MockServeBackend::new(&[1, 2], std::time::Duration::ZERO, ledger.clone())
        .without_role_in_bucket("block_jstep_win_fuse", 1);
    let set = SamplerSet::new(&be, "mock", &[]).unwrap();
    let gsf = BlockDecode::GsFused { windows: 2, chunk: 2 };
    assert_eq!(set.select(1).effective_block_mode(gsf, 0), BlockDecode::GsJacobi { windows: 2 });
    assert_eq!(set.select(2).effective_block_mode(gsf, 0), gsf);
    // The full-sequence fused role is still present in bucket 1.
    let fused = BlockDecode::Fused { chunk: 3 };
    assert_eq!(set.select(1).effective_block_mode(fused, 0), fused);

    let opts = SampleOptions {
        policy: DecodePolicy::PerBlock { modes: vec![gsf; K] },
        ..Default::default()
    };
    let _ = set.select(1).decode_tokens(randn(&[1, L, D], 7), &opts).unwrap();
    assert!(ledger.count("mock_block_jstep_win_b1") > 0, "bucket 1 degrades to gs");
    assert_eq!(ledger.count("mock_block_jstep_win_fuse_b1"), 0);
    let _ = set.select(2).decode_tokens(randn(&[2, L, D], 8), &opts).unwrap();
    assert!(ledger.count("mock_block_jstep_win_fuse_b2") > 0, "bucket 2 stays fused");
    assert_eq!(ledger.count("mock_block_jstep_win_b2"), 0);
}

#[test]
fn partial_manifest_degrades_transitively_to_plain_jacobi() {
    // Every optional role missing: gs_fuse falls through gs to plain
    // Jacobi, fuse falls to Jacobi — and only the base jstep is called.
    let ledger = MockLedger::new();
    let be = MockServeBackend::new(&[1], std::time::Duration::ZERO, ledger.clone())
        .without_role("block_jstep_win_fuse")
        .without_role("block_jstep_win")
        .without_role("block_jstep_fuse");
    let sampler = Sampler::new(&be, "mock", 1).unwrap();
    let gsf = BlockDecode::GsFused { windows: 4, chunk: 2 };
    let fused3 = BlockDecode::Fused { chunk: 3 };
    assert_eq!(sampler.effective_block_mode(gsf, 0), BlockDecode::Jacobi);
    assert_eq!(sampler.effective_block_mode(fused3, 0), BlockDecode::Jacobi);
    assert_eq!(
        sampler.effective_block_mode(BlockDecode::GsJacobi { windows: 4 }, 0),
        BlockDecode::Jacobi
    );
    let opts = SampleOptions {
        policy: DecodePolicy::PerBlock { modes: vec![gsf; K] },
        ..Default::default()
    };
    let _ = sampler.decode_tokens(randn(&[1, L, D], 9), &opts).unwrap();
    assert!(ledger.count("mock_block_jstep_b1") >= K);
    assert_eq!(ledger.count_containing("win"), 0);
    assert_eq!(ledger.count_containing("fuse"), 0);
}

#[test]
fn scalar_cache_bound_holds_under_mock_uploads() {
    // Satellite bugfix: the pool must not pin one device scalar per
    // distinct value forever — the mock's upload ledger sees re-uploads
    // only for values that were LRU-evicted past the cap.
    let be = MockBackend::new();
    let pool = BufferPool::new();
    let n = SCALAR_CACHE_CAP + 20;
    for v in 0..n as i32 {
        pool.device_scalar_i32(v, |t| be.to_device(t)).unwrap();
    }
    assert_eq!(pool.scalar_cache_len(), SCALAR_CACHE_CAP, "cache is bounded");
    assert_eq!(be.uploads_of(&[]), n);
    // A hot value is served from cache; an evicted one re-uploads.
    pool.device_scalar_i32(n as i32 - 1, |t| be.to_device(t)).unwrap();
    assert_eq!(be.uploads_of(&[]), n);
    pool.device_scalar_i32(0, |t| be.to_device(t)).unwrap();
    assert_eq!(be.uploads_of(&[]), n + 1);
    assert_eq!(pool.scalar_cache_len(), SCALAR_CACHE_CAP);
}

#[test]
fn legacy_call_shim_matches_call_v() {
    // Backend::call (the default shim) and the value path must agree.
    let be = MockBackend::new();
    let y = randn(&[2, L, D], 15);
    let z0 = HostTensor::f32(&[2, L, D], vec![0.0; 2 * L * D]);
    let host_out = be
        .call(
            "mock_block_jstep_b2",
            &[
                HostTensor::scalar_i32(1),
                z0.clone(),
                y.clone(),
                HostTensor::scalar_i32(0),
            ],
        )
        .unwrap();
    let val_out = be
        .call_v(
            "mock_block_jstep_b2",
            &[
                Value::Host(HostTensor::scalar_i32(1)),
                Value::Host(z0),
                Value::Host(y),
                Value::Host(HostTensor::scalar_i32(0)),
            ],
        )
        .unwrap();
    assert_eq!(host_out.len(), val_out.len());
    for (h, v) in host_out.iter().zip(val_out) {
        assert!(v.is_device(), "mock outputs are device-resident");
        assert_eq!(*h, be.to_host(v).unwrap());
    }
}

// ---------------------------------------------------------------------------
// Speculative initialization providers (`--init`)
// ---------------------------------------------------------------------------

/// Exact-decode options: a vanishing τ makes convergence mean "the iterate
/// is the bit-exact fixed point" (the mock's residual is exactly 0 there
/// and positive everywhere else), and the +1 iteration budget lets the
/// from-zeros solve reach its resid-0 verify iteration (position i of the
/// triangular mock needs i+1 updates, so full exactness lands at L and the
/// driver observes it at L+1).
fn exact_opts() -> SampleOptions {
    let mut opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    opts.jacobi.tau = 1e-9;
    opts.jacobi.max_iters = Some(L + 1);
    opts.seed = 11;
    opts
}

/// Decode `z` exactly with the given init strategy and return the output.
fn decode_with_init(
    sampler: &Sampler<'_, MockBackend>,
    z: &HostTensor,
    init: InitStrategy,
) -> sjd::coordinator::sampler::SampleOutput {
    let mut opts = exact_opts();
    opts.jacobi.init = init;
    sampler.decode_tokens(z.clone(), &opts).unwrap()
}

#[test]
fn init_providers_bit_exact_and_no_costlier_at_tau0() {
    // Prop 3.2: the τ=0 fixed point is independent of z⁰, so every init
    // provider must reproduce the Zeros output bit-for-bit. The projected
    // seed additionally must not *cost* more than it saves: with its one
    // speculative update charged (`total_updates_with_spec`), it stays ≤
    // the Zeros total.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 42);
    let base = decode_with_init(&sampler, &z, InitStrategy::Zeros);
    assert_eq!(base.spec_hits(), 0);

    for init in [InitStrategy::Normal, InitStrategy::PrevLayer, InitStrategy::Proj] {
        let out = decode_with_init(&sampler, &z, init);
        assert_eq!(
            out.tokens.as_f32().unwrap(),
            base.tokens.as_f32().unwrap(),
            "{init:?} must be bit-exact at tau=0"
        );
        assert!(
            out.total_updates_with_spec() <= base.total_updates_with_spec(),
            "{init:?}: {} > zeros {}",
            out.total_updates_with_spec(),
            base.total_updates_with_spec()
        );
    }

    // The projection seeds every Jacobi block and converges strictly faster
    // (the mock's projected seed lands positions 0 and 1 exactly).
    let proj = decode_with_init(&sampler, &z, InitStrategy::Proj);
    assert_eq!(proj.spec_hits(), K, "every block takes the projected z⁰");
    assert!(
        proj.total_position_updates() < base.total_position_updates(),
        "projection must shrink the refine itself"
    );
    assert!(proj.total_host_syncs() < base.total_host_syncs());
}

#[test]
fn draft_then_refine_bit_exact_with_draft_cost_accounted() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 43);
    let base = decode_with_init(&sampler, &z, InitStrategy::Zeros);
    let draft = decode_with_init(&sampler, &z, InitStrategy::Draft);
    assert_eq!(
        draft.tokens.as_f32().unwrap(),
        base.tokens.as_f32().unwrap(),
        "draft-then-refine must be bit-exact at tau=0"
    );
    // Every refine block was seeded from a draft state…
    assert_eq!(draft.spec_hits(), K);
    // …which makes the exact refine itself cheaper than a cold solve, but
    // the draft pass's own updates are charged as speculation cost — on the
    // mock flow the full bill is *not* a win (the tuner's job is to notice
    // exactly this and fall back to Zeros).
    assert!(draft.total_position_updates() < base.total_position_updates());
    let spec_cost: usize = draft.traces.iter().map(|t| t.spec_cost_updates).sum();
    assert!(spec_cost > 0, "draft pass must be accounted, not hidden");
}

#[test]
fn warm_start_pays_on_repeat_seed_and_stays_bit_exact() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 44);
    let base = decode_with_init(&sampler, &z, InitStrategy::Zeros);

    // Cold pass: every (seed, position) misses, falls back to Zeros.
    let cold = decode_with_init(&sampler, &z, InitStrategy::Warm);
    assert_eq!(cold.spec_hits(), 0, "first decode has nothing cached");
    assert_eq!(cold.tokens.as_f32().unwrap(), base.tokens.as_f32().unwrap());

    // Repeat pass (same seed, same latent): every block hits the cached
    // converged iterate and verifies in one residual-0 iteration.
    let warm = decode_with_init(&sampler, &z, InitStrategy::Warm);
    assert_eq!(warm.spec_hits(), K, "every block must hit the warm cache");
    assert_eq!(warm.tokens.as_f32().unwrap(), base.tokens.as_f32().unwrap());
    assert!(
        warm.total_updates_with_spec() < base.total_updates_with_spec(),
        "warm {} vs zeros {}",
        warm.total_updates_with_spec(),
        base.total_updates_with_spec()
    );
    assert!(warm.total_host_syncs() < base.total_host_syncs());
}

#[test]
fn warm_cache_cap_bounds_entries_lru() {
    // `--init warm:N` bounds the cache: with room for exactly one decode's
    // K entries, a second seed evicts the first (LRU), and re-decoding the
    // evicted seed gets zero hits while the resident seed still hits.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    sampler.set_warm_cap(K);
    let z = randn(&[2, L, D], 45);
    let mut opts = exact_opts();
    opts.jacobi.init = InitStrategy::Warm;

    opts.seed = 1;
    let _ = sampler.decode_tokens(z.clone(), &opts).unwrap();
    opts.seed = 2;
    let _ = sampler.decode_tokens(z.clone(), &opts).unwrap(); // evicts seed 1
    let hit = sampler.decode_tokens(z.clone(), &opts).unwrap();
    assert_eq!(hit.spec_hits(), K, "resident seed must hit");
    opts.seed = 1;
    let miss = sampler.decode_tokens(z.clone(), &opts).unwrap();
    assert_eq!(miss.spec_hits(), 0, "evicted seed must miss");
}

#[test]
fn normal_init_uploads_each_block_seed_once() {
    // Satellite bugfix: `InitStrategy::Normal` used to re-upload its seeded
    // z⁰ on every decode. The pool's (shape, seed) init cache pins each
    // block's z⁰ once; a second identical decode uploads only the latent.
    let be = MockBackend::with_device_reverse();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 46);
    let mut opts = exact_opts();
    opts.jacobi.init = InitStrategy::Normal;
    opts.seed = 30;

    let _ = sampler.decode_tokens(z.clone(), &opts).unwrap();
    // One latent upload + one seeded z⁰ per block (cfg.seed varies by
    // decode position, so the K inits are distinct cache entries).
    assert_eq!(be.uploads_of(&[2, L, D]), 1 + K);
    let _ = sampler.decode_tokens(z.clone(), &opts).unwrap();
    // Pre-fix this was 2 + 2K: every block re-uploaded its init.
    assert_eq!(be.uploads_of(&[2, L, D]), 2 + K, "cached inits must not re-upload");
}

#[test]
fn proj_init_stays_device_resident() {
    // ISSUE residency rule: the speculative path must not bounce through
    // the host. The projection consumes the already-uploaded y and a pooled
    // device scalar — zero host-arg promotions — and the only [B,L,D] sync
    // of the whole decode is the final token fetch.
    let be = MockBackend::with_device_reverse();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 47);
    let _ = decode_with_init(&sampler, &z, InitStrategy::Proj);
    assert_eq!(be.count("mock_init_proj_b2"), K);
    assert_eq!(be.promoted("mock_init_proj_b2"), 0, "projection inputs must be device-resident");
    assert_eq!(be.syncs_of(&[2, L, D]), 1, "tokens fetched once at the end");
    // The latent uploads once; no pooled zero init is ever built (the
    // projection replaces it for every block).
    assert_eq!(be.uploads_of(&[2, L, D]), 1);
}

#[test]
fn proj_falls_back_to_zeros_without_artifact() {
    // Pre-speculation artifact dirs don't ship `{m}_init_proj_b{B}`:
    // `--init proj` must degrade to the Zeros init, not fail.
    let be = MockBackend::without_init_proj();
    let sampler = mk_sampler(&be);
    assert!(!sampler.has_init_proj_artifact());
    let z = randn(&[2, L, D], 48);
    let base = decode_with_init(&sampler, &z, InitStrategy::Zeros);
    let out = decode_with_init(&sampler, &z, InitStrategy::Proj);
    assert_eq!(be.count("mock_init_proj_b2"), 0);
    assert_eq!(out.spec_hits(), 0, "no artifact ⇒ no speculation");
    assert_eq!(out.tokens.as_f32().unwrap(), base.tokens.as_f32().unwrap());
    assert_eq!(out.total_position_updates(), base.total_position_updates());
}
