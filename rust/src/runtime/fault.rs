//! Typed fault taxonomy for backend execution errors.
//!
//! Every failure of [`Backend::call_v`](super::Backend::call_v) or a
//! transfer (`to_device`/`to_host`) falls into one of three recovery
//! classes, carried through `anyhow` context chains as a typed [`Fault`]
//! marker (same downcast pattern as the batcher's `QueueFull`):
//!
//! | class | meaning | recovery |
//! |-------|---------|----------|
//! | [`Transient`](FaultClass::Transient) | momentary glitch (device busy, spurious transfer failure) — the same call can succeed | retry with capped exponential backoff, budgeted against the slot deadline |
//! | [`DeviceLost`](FaultClass::DeviceLost) | the executing device/engine is gone — *no* call on this engine can succeed | fail the wave, respawn the worker with a fresh `Engine` |
//! | [`Poison`](FaultClass::Poison) | deterministic failure pinned to one artifact (miscompiled program, bad lowering) — retrying reproduces it | count against the artifact's circuit breaker; quarantine reroutes through the degradation chain |
//!
//! **Unmarked errors classify as Poison.** An error nobody tagged is by
//! definition not known to be retryable, and treating it as deterministic
//! is the safe default: no retry storm, and repeated failures of one
//! artifact trip its breaker instead of looping forever. Producers that
//! *know* a failure is momentary or fatal-to-the-engine must say so by
//! attaching a marker via [`Fault::transient`] / [`Fault::device_lost`].
//!
//! Classification looks through `anyhow` context chains (`classify` walks
//! the chain), so wrapping a marked error in `.context(..)` preserves its
//! class — the same property the server relies on for `QueueFull` → 429.

use std::fmt;

/// Recovery class of a backend execution fault. See the [module
/// docs](self) for the taxonomy table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    /// Momentary; the identical call may succeed on retry.
    Transient,
    /// The engine/device is unusable; only a fresh engine can recover.
    DeviceLost,
    /// Deterministic, pinned to the artifact; retrying reproduces it.
    Poison,
}

impl fmt::Display for FaultClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultClass::Transient => write!(f, "transient"),
            FaultClass::DeviceLost => write!(f, "device-lost"),
            FaultClass::Poison => write!(f, "poison"),
        }
    }
}

/// Typed marker error carrying a [`FaultClass`] through `anyhow` chains.
///
/// Constructed via [`Fault::transient`] / [`Fault::device_lost`] /
/// [`Fault::poison`] and recovered with [`classify`]; the `artifact` names
/// the program whose dispatch failed so circuit breakers key on it even
/// after the error crossed several context frames.
#[derive(Clone, Debug)]
pub struct Fault {
    pub class: FaultClass,
    /// Artifact whose dispatch produced the fault (breaker key).
    pub artifact: String,
}

impl Fault {
    pub fn new(class: FaultClass, artifact: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(Fault { class, artifact: artifact.into() })
    }

    /// A retryable fault of `artifact`.
    pub fn transient(artifact: impl Into<String>) -> anyhow::Error {
        Self::new(FaultClass::Transient, artifact)
    }

    /// A fault that invalidates the whole engine.
    pub fn device_lost(artifact: impl Into<String>) -> anyhow::Error {
        Self::new(FaultClass::DeviceLost, artifact)
    }

    /// A deterministic per-artifact fault.
    pub fn poison(artifact: impl Into<String>) -> anyhow::Error {
        Self::new(FaultClass::Poison, artifact)
    }
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} fault in artifact '{}'", self.class, self.artifact)
    }
}

impl std::error::Error for Fault {}

/// The fault class of an error: the marker's class if one is anywhere in
/// the `anyhow` chain, else [`FaultClass::Poison`] (see module docs for
/// why unmarked defaults to the non-retryable class).
pub fn classify(e: &anyhow::Error) -> FaultClass {
    match e.downcast_ref::<Fault>() {
        Some(f) => f.class,
        None => FaultClass::Poison,
    }
}

/// The artifact a marked fault is pinned to, when the chain carries one.
pub fn fault_artifact(e: &anyhow::Error) -> Option<&str> {
    e.downcast_ref::<Fault>().map(|f| f.artifact.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Context;

    #[test]
    fn classify_reads_marker_through_context_chain() {
        let e = Fault::transient("tf10_block_jstep_b4").context("dispatching block 3");
        assert_eq!(classify(&e), FaultClass::Transient);
        assert_eq!(fault_artifact(&e), Some("tf10_block_jstep_b4"));

        let e = Fault::device_lost("tf10_reverse_b1")
            .context("decode")
            .context("wave 7");
        assert_eq!(classify(&e), FaultClass::DeviceLost);
    }

    #[test]
    fn unmarked_errors_classify_poison() {
        let e = anyhow::anyhow!("mock: artifact 'x' is not lowered");
        assert_eq!(classify(&e), FaultClass::Poison);
        assert_eq!(fault_artifact(&e), None);
    }

    #[test]
    fn display_names_class_and_artifact() {
        let e = Fault::poison("m_seqstep_b2");
        let s = format!("{e}");
        assert!(s.contains("poison"), "{s}");
        assert!(s.contains("m_seqstep_b2"), "{s}");
    }
}
