//! Per-request decode state: KV-cache buffers (pooled, reused across blocks)
//! and memory accounting for the §D memory analysis.

use crate::runtime::{HostTensor, Value};
use std::cell::RefCell;

/// Capacity of the [`BufferPool::device_scalar_i32`] cache: enough for every
/// loop constant a steady-state decode re-uses (block indices, window
/// offsets/lengths, calibrated chunk sizes are all small sets), small enough
/// that a pathological stream of distinct values — e.g. adaptive chunk
/// schedules reacting to per-request residual trajectories — cannot pin
/// unbounded device memory.
pub const SCALAR_CACHE_CAP: usize = 64;

/// Capacity of the [`BufferPool::device_init`] cache of seeded initial
/// iterates (`InitStrategy::Normal` z⁰ tensors, keyed by shape + seed).
/// A sampler re-decodes the same few (shape, seed) combinations across
/// blocks and requests, but per-request seeds form an unbounded stream —
/// the cap keeps a pathological seed-per-request workload from pinning one
/// (B, L, D) device buffer per seed forever.
pub const INIT_CACHE_CAP: usize = 16;

/// Capacity of the per-pool warm-start cache ([`BufferPool::warm_put`]):
/// converged block latents keyed by (seed family, decode position),
/// LRU-bounded exactly like the scalar cache. Entries are full (B, L, D)
/// tensors, so the cap is deliberately small.
pub const WARM_CACHE_CAP: usize = 32;

/// A pool of reusable zeroed f32 buffers keyed by shape, used for the KV
/// cache tensors of the sequential decode path. Sequential decode consumes
/// two (NL, B, L, Dm) caches per block; pooling keeps the hot loop
/// allocation-free after the first block.
///
/// The pool hands out both host buffers ([`BufferPool::take_zeroed`]) and
/// **device-resident** zero values ([`BufferPool::device_zeroed`]): artifacts
/// are functional (they return fresh outputs and never alias their inputs),
/// so one uploaded zero tensor per shape is immutable and reusable across
/// blocks and requests — the initial KV caches cost one upload for the whole
/// process lifetime instead of two host marshals per block.
#[derive(Default)]
pub struct BufferPool {
    free: RefCell<Vec<(Vec<usize>, Vec<f32>)>>,
    /// Immutable device-resident zero tensors, one per shape.
    device_zeros: RefCell<Vec<(Vec<usize>, Value)>>,
    /// Immutable device-resident i32 scalars, one per distinct value — the
    /// decode loop constants (block index `k`, mask offset, window
    /// offset/length, fused chunk sizes) repeat across blocks, windows and
    /// requests, so each uploads once while it stays hot. Capped at
    /// [`SCALAR_CACHE_CAP`] entries with LRU eviction (most recently used
    /// last): adaptive chunk schedules can emit a long tail of distinct
    /// step counts over a server's lifetime, and an uncapped cache would
    /// pin one device buffer per value forever.
    device_scalars: RefCell<Vec<(i32, Value)>>,
    /// Immutable device-resident seeded initial iterates keyed by
    /// (shape, seed) — the `InitStrategy::Normal` z⁰ tensors, which are
    /// deterministic in their seed and therefore as reusable as the zero
    /// cache above. LRU-bounded at [`INIT_CACHE_CAP`].
    device_inits: RefCell<Vec<((Vec<usize>, u64), Value)>>,
    /// Warm-start cache: converged block latents keyed by
    /// (seed family, decode position), LRU-bounded at [`WARM_CACHE_CAP`].
    /// Unlike the caches above these are *predictions*, not constants — a
    /// hit seeds the next Jacobi solve of the same (seed, position) pair,
    /// which at τ=0 verifies it in one residual-0 iteration.
    warm_starts: RefCell<Vec<((u64, usize), Value)>>,
    /// Configured warm-start capacity; 0 means "unset" and resolves to
    /// [`WARM_CACHE_CAP`] (the `Default` derive zero-initializes this — see
    /// [`BufferPool::set_warm_cap`] / the `warm:N` init-policy spelling).
    warm_cap: std::cell::Cell<usize>,
    /// High-water mark of host bytes handed out simultaneously.
    peak_bytes: RefCell<usize>,
    live_bytes: RefCell<usize>,
    /// Bytes pinned on device by the zero-value + scalar caches.
    device_bytes: RefCell<usize>,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a zeroed tensor of `shape` (recycling a previous buffer if one
    /// of the same shape is free).
    pub fn take_zeroed(&self, shape: &[usize]) -> HostTensor {
        let numel: usize = shape.iter().product();
        let mut free = self.free.borrow_mut();
        let data = if let Some(idx) = free.iter().position(|(s, _)| s == shape) {
            let (_, mut buf) = free.swap_remove(idx);
            buf.iter_mut().for_each(|x| *x = 0.0);
            buf
        } else {
            vec![0.0f32; numel]
        };
        drop(free);
        let mut live = self.live_bytes.borrow_mut();
        *live += numel * 4;
        let mut peak = self.peak_bytes.borrow_mut();
        *peak = (*peak).max(*live);
        HostTensor::f32(shape, data)
    }

    /// Return a tensor's storage to the pool.
    pub fn give_back(&self, t: HostTensor) {
        if let HostTensor::F32 { shape, data } = t {
            *self.live_bytes.borrow_mut() -= data.len() * 4;
            self.free.borrow_mut().push((shape, data));
        }
    }

    /// A device-resident zero tensor of `shape`, uploaded at most once per
    /// shape via `upload` and cached for the pool's lifetime.
    ///
    /// Callers must treat the returned value as immutable — the contract
    /// holds because artifacts return fresh output buffers rather than
    /// mutating inputs. Backends without device memory get a host value from
    /// their `to_device` default; those are cached identically.
    pub fn device_zeroed(
        &self,
        shape: &[usize],
        upload: impl FnOnce(&HostTensor) -> anyhow::Result<Value>,
    ) -> anyhow::Result<Value> {
        if let Some((_, v)) =
            self.device_zeros.borrow().iter().find(|(s, _)| s.as_slice() == shape)
        {
            return Ok(v.clone());
        }
        let numel: usize = shape.iter().product();
        let v = upload(&HostTensor::f32(shape, vec![0.0f32; numel]))?;
        *self.device_bytes.borrow_mut() += numel * 4;
        self.device_zeros.borrow_mut().push((shape.to_vec(), v.clone()));
        Ok(v)
    }

    /// A device-resident i32 scalar, uploaded at most once per distinct
    /// value via `upload` and cached while it stays among the
    /// [`SCALAR_CACHE_CAP`] most recently used values. Same immutability
    /// contract as [`BufferPool::device_zeroed`]; used by the decode
    /// drivers to pin loop constants (`k`, `mask_o`, window offset/length,
    /// fused chunk sizes) instead of re-uploading them per
    /// block/window/chunk.
    ///
    /// Eviction drops the pool's clone of the value; the device buffer is
    /// freed once every outstanding handle drops, and a later request for
    /// the same value simply re-uploads it.
    pub fn device_scalar_i32(
        &self,
        v: i32,
        upload: impl FnOnce(&HostTensor) -> anyhow::Result<Value>,
    ) -> anyhow::Result<Value> {
        {
            let mut cache = self.device_scalars.borrow_mut();
            if let Some(idx) = cache.iter().position(|(x, _)| *x == v) {
                // Refresh recency: most recently used entries live at the
                // back, evictions pop the front.
                let entry = cache.remove(idx);
                let val = entry.1.clone();
                cache.push(entry);
                return Ok(val);
            }
        }
        let val = upload(&HostTensor::scalar_i32(v))?;
        let mut cache = self.device_scalars.borrow_mut();
        if cache.len() >= SCALAR_CACHE_CAP {
            cache.remove(0);
            *self.device_bytes.borrow_mut() -= 4;
        }
        *self.device_bytes.borrow_mut() += 4;
        cache.push((v, val.clone()));
        Ok(val)
    }

    /// Distinct scalar values currently pinned — always `<=`
    /// [`SCALAR_CACHE_CAP`].
    pub fn scalar_cache_len(&self) -> usize {
        self.device_scalars.borrow().len()
    }

    /// A device-resident seeded initial iterate for (shape, seed), built and
    /// uploaded at most once per key via `make` while it stays among the
    /// [`INIT_CACHE_CAP`] most recently used keys. Same immutability
    /// contract as [`BufferPool::device_zeroed`] — `InitStrategy::Normal`'s
    /// z⁰ is a pure function of (shape, seed), so repeated block decodes
    /// reuse one upload instead of rebuilding and re-uploading each time.
    pub fn device_init(
        &self,
        shape: &[usize],
        seed: u64,
        make: impl FnOnce() -> anyhow::Result<Value>,
    ) -> anyhow::Result<Value> {
        {
            let mut cache = self.device_inits.borrow_mut();
            if let Some(idx) =
                cache.iter().position(|((s, sd), _)| s.as_slice() == shape && *sd == seed)
            {
                // Refresh recency: MRU at the back, evictions pop the front.
                let entry = cache.remove(idx);
                let val = entry.1.clone();
                cache.push(entry);
                return Ok(val);
            }
        }
        let val = make()?;
        let numel: usize = shape.iter().product();
        let mut cache = self.device_inits.borrow_mut();
        if cache.len() >= INIT_CACHE_CAP {
            let ((old_shape, _), _) = cache.remove(0);
            *self.device_bytes.borrow_mut() -=
                old_shape.iter().product::<usize>() * 4;
        }
        *self.device_bytes.borrow_mut() += numel * 4;
        cache.push(((shape.to_vec(), seed), val.clone()));
        Ok(val)
    }

    /// Distinct seeded inits currently pinned — always `<=`
    /// [`INIT_CACHE_CAP`].
    pub fn init_cache_len(&self) -> usize {
        self.device_inits.borrow().len()
    }

    /// Look up a warm-start latent for (seed family, decode position); a hit
    /// refreshes the entry's LRU recency. The returned value is a converged
    /// iterate cached by [`BufferPool::warm_put`] — device-resident on real
    /// backends, so seeding a decode from it costs zero host traffic.
    pub fn warm_get(&self, seed: u64, pos: usize) -> Option<Value> {
        let mut cache = self.warm_starts.borrow_mut();
        let idx = cache.iter().position(|((s, p), _)| *s == seed && *p == pos)?;
        let entry = cache.remove(idx);
        let val = entry.1.clone();
        cache.push(entry);
        Some(val)
    }

    /// Bound the warm-start cache at `cap` entries (the `N` of the
    /// `warm:N` init-policy spelling); unset pools use [`WARM_CACHE_CAP`].
    /// Shrinking below the current population evicts from the LRU front on
    /// the next [`BufferPool::warm_put`].
    pub fn set_warm_cap(&self, cap: usize) {
        self.warm_cap.set(cap.max(1));
    }

    /// Cache a converged block latent under (seed family, decode position),
    /// replacing any previous entry for the key and evicting least recently
    /// used entries once the configured capacity ([`WARM_CACHE_CAP`] unless
    /// [`BufferPool::set_warm_cap`] overrode it) is pinned.
    pub fn warm_put(&self, seed: u64, pos: usize, v: Value) {
        let cap = match self.warm_cap.get() {
            0 => WARM_CACHE_CAP,
            c => c,
        };
        let bytes = v.shape().iter().product::<usize>() * 4;
        let mut cache = self.warm_starts.borrow_mut();
        if let Some(idx) = cache.iter().position(|((s, p), _)| *s == seed && *p == pos) {
            let ((_, _), old) = cache.remove(idx);
            *self.device_bytes.borrow_mut() -= old.shape().iter().product::<usize>() * 4;
            drop(old);
        }
        while cache.len() >= cap {
            let (_, old) = cache.remove(0);
            *self.device_bytes.borrow_mut() -= old.shape().iter().product::<usize>() * 4;
        }
        *self.device_bytes.borrow_mut() += bytes;
        cache.push(((seed, pos), v));
    }

    /// Warm-start entries currently pinned — always `<=` [`WARM_CACHE_CAP`].
    pub fn warm_cache_len(&self) -> usize {
        self.warm_starts.borrow().len()
    }

    pub fn peak_bytes(&self) -> usize {
        *self.peak_bytes.borrow()
    }

    pub fn live_bytes(&self) -> usize {
        *self.live_bytes.borrow()
    }

    /// Bytes held on device by the cached zero values.
    pub fn device_cache_bytes(&self) -> usize {
        *self.device_bytes.borrow()
    }
}

/// The warm-cache/RNG "seed family" of a slot composition: an FNV-1a fold
/// over the live slots' request seeds, in row order. Continuous-batching
/// waves key their per-wave decode config (`SampleOptions::seed`, and
/// through it the warm-start cache) by this value, recomputed after every
/// refill/migration/merge — identical compositions share warm entries,
/// any change to membership or order misses instead of serving a stale
/// iterate. τ=0 bit-exactness never depends on it (Prop 3.2: the z⁰ only
/// steers iteration count, not the fixed point).
pub fn slot_composition_seed(seeds: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &s in seeds {
        for b in s.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

/// Estimated working-set sizes (bytes) of the two decode strategies for a
/// block — the §D memory comparison. `nl` layers, batch `b`, sequence `l`,
/// model width `dm`, token dim `d`.
#[derive(Clone, Copy, Debug)]
pub struct MemoryEstimate {
    pub sequential_kv_bytes: usize,
    pub jacobi_iterate_bytes: usize,
    /// Windowed GS-Jacobi: the same iterate + block input as full Jacobi
    /// (the jstep_win artifact masks positions, it does not slice tensors)
    /// plus the two per-window i32 scalar pins — memory-wise GS-Jacobi
    /// inherits Jacobi's footprint, it only redistributes *compute*.
    pub gs_jacobi_bytes: usize,
}

pub fn estimate_memory(nl: usize, b: usize, l: usize, dm: usize, d: usize) -> MemoryEstimate {
    let jacobi_iterate_bytes = 2 * b * l * d * 4;
    MemoryEstimate {
        // Two caches (K and V), each (NL, B, L, Dm) f32.
        sequential_kv_bytes: 2 * nl * b * l * dm * 4,
        // Jacobi holds the iterate + the block input, each (B, L, D) f32.
        jacobi_iterate_bytes,
        gs_jacobi_bytes: jacobi_iterate_bytes + 2 * 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_buffers() {
        let pool = BufferPool::new();
        let t = pool.take_zeroed(&[2, 3]);
        assert_eq!(pool.live_bytes(), 24);
        pool.give_back(t);
        assert_eq!(pool.live_bytes(), 0);
        let t2 = pool.take_zeroed(&[2, 3]);
        assert_eq!(t2.as_f32().unwrap(), &[0.0; 6]);
        assert_eq!(pool.peak_bytes(), 24);
    }

    #[test]
    fn pool_zeroes_recycled_memory() {
        let pool = BufferPool::new();
        let mut t = pool.take_zeroed(&[4]);
        if let HostTensor::F32 { data, .. } = &mut t {
            data[0] = 99.0;
        }
        pool.give_back(t);
        let t2 = pool.take_zeroed(&[4]);
        assert_eq!(t2.as_f32().unwrap(), &[0.0; 4]);
    }

    #[test]
    fn peak_tracks_simultaneous() {
        let pool = BufferPool::new();
        let a = pool.take_zeroed(&[10]);
        let b = pool.take_zeroed(&[10]);
        assert_eq!(pool.peak_bytes(), 80);
        pool.give_back(a);
        pool.give_back(b);
        let _c = pool.take_zeroed(&[10]);
        assert_eq!(pool.peak_bytes(), 80); // unchanged
    }

    #[test]
    fn device_zeros_upload_once_per_shape() {
        let pool = BufferPool::new();
        let uploads = std::cell::Cell::new(0usize);
        let mk = |t: &HostTensor| {
            uploads.set(uploads.get() + 1);
            Ok(Value::Host(t.clone()))
        };
        let a = pool.device_zeroed(&[2, 4], mk).unwrap();
        let b = pool.device_zeroed(&[2, 4], mk).unwrap();
        let c = pool.device_zeroed(&[3], mk).unwrap();
        assert_eq!(uploads.get(), 2, "one upload per distinct shape");
        assert_eq!(a.shape(), &[2, 4]);
        assert_eq!(b.shape(), &[2, 4]);
        assert_eq!(c.shape(), &[3]);
        assert_eq!(a.as_host().unwrap().as_f32().unwrap(), &[0.0; 8]);
        assert_eq!(pool.device_cache_bytes(), (8 + 3) * 4);
    }

    #[test]
    fn device_scalars_upload_once_per_value() {
        let pool = BufferPool::new();
        let uploads = std::cell::Cell::new(0usize);
        let mk = |t: &HostTensor| {
            uploads.set(uploads.get() + 1);
            Ok(Value::Host(t.clone()))
        };
        let a = pool.device_scalar_i32(3, mk).unwrap();
        let b = pool.device_scalar_i32(3, mk).unwrap();
        let c = pool.device_scalar_i32(-1, mk).unwrap();
        assert_eq!(uploads.get(), 2, "one upload per distinct value");
        assert_eq!(a.as_host().unwrap().as_i32().unwrap(), &[3]);
        assert_eq!(b.as_host().unwrap().as_i32().unwrap(), &[3]);
        assert_eq!(c.as_host().unwrap().as_i32().unwrap(), &[-1]);
        assert_eq!(pool.device_cache_bytes(), 8);
    }

    #[test]
    fn scalar_cache_is_bounded_with_lru_eviction() {
        let pool = BufferPool::new();
        let uploads = std::cell::Cell::new(0usize);
        let mk = |t: &HostTensor| {
            uploads.set(uploads.get() + 1);
            Ok(Value::Host(t.clone()))
        };
        // Overfill by 10: every distinct value uploads once, but the cache
        // (and its device-byte accounting) stays at the cap.
        for v in 0..(SCALAR_CACHE_CAP + 10) as i32 {
            pool.device_scalar_i32(v, mk).unwrap();
        }
        assert_eq!(uploads.get(), SCALAR_CACHE_CAP + 10);
        assert_eq!(pool.scalar_cache_len(), SCALAR_CACHE_CAP);
        assert_eq!(pool.device_cache_bytes(), SCALAR_CACHE_CAP * 4);
        // The oldest values were evicted — re-pinning one re-uploads.
        pool.device_scalar_i32(0, mk).unwrap();
        assert_eq!(uploads.get(), SCALAR_CACHE_CAP + 11);
        // The newest survived — re-pinning it is a cache hit.
        pool.device_scalar_i32((SCALAR_CACHE_CAP + 9) as i32, mk).unwrap();
        assert_eq!(uploads.get(), SCALAR_CACHE_CAP + 11);
        assert_eq!(pool.scalar_cache_len(), SCALAR_CACHE_CAP);
    }

    #[test]
    fn scalar_cache_hit_refreshes_recency() {
        let pool = BufferPool::new();
        let uploads = std::cell::Cell::new(0usize);
        let mk = |t: &HostTensor| {
            uploads.set(uploads.get() + 1);
            Ok(Value::Host(t.clone()))
        };
        for v in 0..SCALAR_CACHE_CAP as i32 {
            pool.device_scalar_i32(v, mk).unwrap();
        }
        // Touch the oldest entry, then insert one new value: the eviction
        // must hit the now-least-recently-used value 1, not the refreshed 0.
        pool.device_scalar_i32(0, mk).unwrap();
        pool.device_scalar_i32(-1, mk).unwrap();
        let before = uploads.get();
        pool.device_scalar_i32(0, mk).unwrap();
        assert_eq!(uploads.get(), before, "refreshed value must still be cached");
        pool.device_scalar_i32(1, mk).unwrap();
        assert_eq!(uploads.get(), before + 1, "stale value must have been evicted");
    }

    #[test]
    fn init_cache_builds_once_per_shape_and_seed() {
        let pool = BufferPool::new();
        let builds = std::cell::Cell::new(0usize);
        let mk = |shape: &[usize]| {
            builds.set(builds.get() + 1);
            let numel: usize = shape.iter().product();
            Ok(Value::Host(HostTensor::f32(shape, vec![1.0; numel])))
        };
        let a = pool.device_init(&[2, 4], 7, || mk(&[2, 4])).unwrap();
        let b = pool.device_init(&[2, 4], 7, || mk(&[2, 4])).unwrap();
        pool.device_init(&[2, 4], 8, || mk(&[2, 4])).unwrap();
        pool.device_init(&[3], 7, || mk(&[3])).unwrap();
        assert_eq!(builds.get(), 3, "one build per distinct (shape, seed)");
        assert_eq!(a.shape(), &[2, 4]);
        assert_eq!(b.shape(), &[2, 4]);
        assert_eq!(pool.init_cache_len(), 3);
    }

    #[test]
    fn init_cache_is_bounded_with_lru_eviction() {
        let pool = BufferPool::new();
        let builds = std::cell::Cell::new(0usize);
        let mk = || {
            builds.set(builds.get() + 1);
            Ok(Value::Host(HostTensor::f32(&[2], vec![0.0; 2])))
        };
        for seed in 0..(INIT_CACHE_CAP + 4) as u64 {
            pool.device_init(&[2], seed, mk).unwrap();
        }
        assert_eq!(builds.get(), INIT_CACHE_CAP + 4);
        assert_eq!(pool.init_cache_len(), INIT_CACHE_CAP);
        // Oldest seeds evicted — rebuilding seed 0 is a miss; the newest
        // survived — seed INIT_CACHE_CAP+3 is a hit.
        pool.device_init(&[2], 0, mk).unwrap();
        assert_eq!(builds.get(), INIT_CACHE_CAP + 5);
        pool.device_init(&[2], (INIT_CACHE_CAP + 3) as u64, mk).unwrap();
        assert_eq!(builds.get(), INIT_CACHE_CAP + 5);
        assert_eq!(pool.init_cache_len(), INIT_CACHE_CAP);
    }

    #[test]
    fn warm_cache_round_trips_and_replaces() {
        let pool = BufferPool::new();
        assert!(pool.warm_get(1, 0).is_none());
        let v = Value::Host(HostTensor::f32(&[2, 2], vec![1.0; 4]));
        pool.warm_put(1, 0, v);
        let hit = pool.warm_get(1, 0).expect("warm hit");
        assert_eq!(hit.as_host().unwrap().as_f32().unwrap(), &[1.0; 4]);
        // Same key replaces in place — no duplicate entries, updated value.
        pool.warm_put(1, 0, Value::Host(HostTensor::f32(&[2, 2], vec![2.0; 4])));
        assert_eq!(pool.warm_cache_len(), 1);
        let hit = pool.warm_get(1, 0).unwrap();
        assert_eq!(hit.as_host().unwrap().as_f32().unwrap(), &[2.0; 4]);
        assert_eq!(pool.device_cache_bytes(), 16);
        // Different position under the same seed is a distinct key.
        assert!(pool.warm_get(1, 1).is_none());
    }

    #[test]
    fn warm_cache_is_bounded_with_lru_eviction() {
        let pool = BufferPool::new();
        let v = || Value::Host(HostTensor::f32(&[2], vec![0.5; 2]));
        for seed in 0..(WARM_CACHE_CAP + 5) as u64 {
            pool.warm_put(seed, 0, v());
        }
        assert_eq!(pool.warm_cache_len(), WARM_CACHE_CAP);
        assert_eq!(pool.device_cache_bytes(), WARM_CACHE_CAP * 8);
        // Oldest evicted, newest retained.
        assert!(pool.warm_get(0, 0).is_none());
        assert!(pool.warm_get((WARM_CACHE_CAP + 4) as u64, 0).is_some());
        // A get refreshes recency: touch the current LRU entry, insert one
        // more, and the eviction must skip the refreshed key.
        let lru = 5u64; // seeds 0..=4 already evicted above
        assert!(pool.warm_get(lru, 0).is_some());
        pool.warm_put(1000, 0, v());
        assert!(pool.warm_get(lru, 0).is_some(), "refreshed entry must survive");
        assert!(pool.warm_get(6, 0).is_none(), "stale entry must be evicted");
    }

    #[test]
    fn warm_cache_respects_configured_cap() {
        let pool = BufferPool::new();
        pool.set_warm_cap(2);
        let v = || Value::Host(HostTensor::f32(&[2], vec![0.5; 2]));
        for seed in 0..5u64 {
            pool.warm_put(seed, 0, v());
        }
        assert_eq!(pool.warm_cache_len(), 2, "configured cap bounds the cache");
        assert!(pool.warm_get(2, 0).is_none());
        assert!(pool.warm_get(3, 0).is_some());
        assert!(pool.warm_get(4, 0).is_some());
        // Shrinking evicts down to the new cap on the next put.
        pool.set_warm_cap(1);
        pool.warm_put(9, 0, v());
        assert_eq!(pool.warm_cache_len(), 1);
        assert!(pool.warm_get(9, 0).is_some());
        assert_eq!(pool.device_cache_bytes(), 8);
    }

    #[test]
    fn composition_seed_depends_on_membership_and_order() {
        let a = slot_composition_seed(&[1, 2, 3]);
        assert_eq!(a, slot_composition_seed(&[1, 2, 3]), "deterministic");
        assert_ne!(a, slot_composition_seed(&[1, 2]), "membership changes the key");
        assert_ne!(a, slot_composition_seed(&[3, 2, 1]), "order changes the key");
        assert_ne!(slot_composition_seed(&[]), slot_composition_seed(&[0]));
    }

    #[test]
    fn memory_estimate_matches_paper_asymmetry() {
        // KV-cache grows with NL·Dm; Jacobi iterate with token dim D only —
        // the paper's §D observation (5.2 GB vs 7.8 GB on AFHQ).
        let e = estimate_memory(2, 8, 256, 96, 12);
        assert!(e.sequential_kv_bytes > e.jacobi_iterate_bytes);
        assert_eq!(e.sequential_kv_bytes, 2 * 2 * 8 * 256 * 96 * 4);
        assert_eq!(e.jacobi_iterate_bytes, 2 * 8 * 256 * 12 * 4);
        // GS-Jacobi adds only the two scalar window pins.
        assert_eq!(e.gs_jacobi_bytes, e.jacobi_iterate_bytes + 8);
        assert!(e.gs_jacobi_bytes < e.sequential_kv_bytes);
    }
}
