//! Sample-sheet compositor: arrange images in a padded grid (the paper's
//! Fig 2/3/A3-style visual comparisons).

use super::Image;

/// Compose images into a `cols`-wide grid with `pad` px of dark separator.
pub fn compose_grid(images: &[Image], cols: usize, pad: usize) -> Image {
    assert!(!images.is_empty());
    let cols = cols.max(1);
    let rows = images.len().div_ceil(cols);
    let tile_w = images.iter().map(|i| i.width).max().unwrap();
    let tile_h = images.iter().map(|i| i.height).max().unwrap();
    let out_w = cols * tile_w + (cols + 1) * pad;
    let out_h = rows * tile_h + (rows + 1) * pad;
    let mut out = Image::new(out_w, out_h);
    // Dark gray background.
    for p in out.pixels.iter_mut() {
        *p = 24;
    }
    for (idx, img) in images.iter().enumerate() {
        let (r, c) = (idx / cols, idx % cols);
        let x0 = pad + c * (tile_w + pad);
        let y0 = pad + r * (tile_h + pad);
        for y in 0..img.height {
            for x in 0..img.width {
                out.set(x0 + x, y0 + y, img.get(x, y));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_dimensions() {
        let imgs = vec![Image::new(4, 4); 5];
        let g = compose_grid(&imgs, 3, 1);
        assert_eq!(g.width, 3 * 4 + 4 * 1);
        assert_eq!(g.height, 2 * 4 + 3 * 1);
    }

    #[test]
    fn pixels_placed() {
        let mut a = Image::new(2, 2);
        a.set(0, 0, [255, 0, 0]);
        let g = compose_grid(&[a], 1, 1);
        assert_eq!(g.get(1, 1), [255, 0, 0]); // offset by pad
        assert_eq!(g.get(0, 0), [24, 24, 24]); // background
    }
}
