//! Minimal PNG encoder: 8-bit RGB, one IDAT chunk, zlib via flate2.

use super::Image;
use anyhow::Result;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::Write;
use std::path::Path;

const PNG_SIG: [u8; 8] = [0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1A, b'\n'];

/// CRC-32 (IEEE) for PNG chunks.
fn crc32(data: &[u8]) -> u32 {
    // Table-less bitwise implementation; PNG files here are small.
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn chunk(out: &mut Vec<u8>, kind: &[u8; 4], body: &[u8]) {
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    let mut tagged = Vec::with_capacity(4 + body.len());
    tagged.extend_from_slice(kind);
    tagged.extend_from_slice(body);
    out.extend_from_slice(&tagged);
    out.extend_from_slice(&crc32(&tagged).to_be_bytes());
}

/// Encode an [`Image`] as PNG bytes.
pub fn encode_png(img: &Image) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&PNG_SIG);

    // IHDR: width, height, bit depth 8, color type 2 (RGB), defaults.
    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&(img.width as u32).to_be_bytes());
    ihdr.extend_from_slice(&(img.height as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, 2, 0, 0, 0]);
    chunk(&mut out, b"IHDR", &ihdr);

    // Raw scanlines with filter byte 0 (None).
    let stride = img.width * 3;
    let mut raw = Vec::with_capacity((stride + 1) * img.height);
    for y in 0..img.height {
        raw.push(0);
        raw.extend_from_slice(&img.pixels[y * stride..(y + 1) * stride]);
    }
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(&raw)?;
    let compressed = enc.finish()?;
    chunk(&mut out, b"IDAT", &compressed);
    chunk(&mut out, b"IEND", &[]);
    Ok(out)
}

/// Write an [`Image`] to a `.png` file.
pub fn write_png(img: &Image, path: impl AsRef<Path>) -> Result<()> {
    let bytes = encode_png(img)?;
    std::fs::write(path.as_ref(), bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signature_and_chunks() {
        let mut img = Image::new(3, 2);
        img.set(0, 0, [255, 0, 0]);
        img.set(2, 1, [0, 0, 255]);
        let bytes = encode_png(&img).unwrap();
        assert_eq!(&bytes[..8], &PNG_SIG);
        // IHDR must be first chunk with the right dims.
        assert_eq!(&bytes[12..16], b"IHDR");
        assert_eq!(u32::from_be_bytes(bytes[16..20].try_into().unwrap()), 3);
        assert_eq!(u32::from_be_bytes(bytes[20..24].try_into().unwrap()), 2);
        // IEND terminates.
        assert_eq!(&bytes[bytes.len() - 8..bytes.len() - 4], b"IEND");
    }

    #[test]
    fn crc_known_vector() {
        // CRC32("123456789") = 0xCBF43926 (standard check value)
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn idat_decompresses_to_scanlines() {
        use std::io::Read;
        let mut img = Image::new(2, 2);
        img.set(1, 1, [1, 2, 3]);
        let bytes = encode_png(&img).unwrap();
        // Locate IDAT.
        let pos = bytes.windows(4).position(|w| w == b"IDAT").unwrap();
        let len = u32::from_be_bytes(bytes[pos - 4..pos].try_into().unwrap()) as usize;
        let body = &bytes[pos + 4..pos + 4 + len];
        let mut dec = flate2::read::ZlibDecoder::new(body);
        let mut raw = Vec::new();
        dec.read_to_end(&mut raw).unwrap();
        assert_eq!(raw.len(), (2 * 3 + 1) * 2);
        assert_eq!(raw[0], 0); // filter byte
        assert_eq!(&raw[raw.len() - 3..], &[1, 2, 3]);
    }

    #[test]
    fn file_write() {
        let img = Image::new(4, 4);
        let p = std::env::temp_dir().join("sjd_png_test.png");
        write_png(&img, &p).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert_eq!(&data[..8], &PNG_SIG);
    }
}
