//! A batch-generic analytic mock of the TarFlow artifact ABI, shared by the
//! hermetic coordinator tests (`rust/tests/mock_backend.rs`), the serving
//! integration tests (`rust/tests/serving.rs`) and the mock-backend load
//! bench (`benches/serve_load.rs`).
//!
//! The flow is analytically invertible and triangular (so Jacobi decoding
//! applies). Per block `k` with coupling strength `a_k`, in AR domain:
//!
//! ```text
//! forward: v_0 = u_0;  v_l = u_l − a_k · mean(u_{<l})
//! inverse: u_l = v_l + a_k · mean(u_{<l})
//! ```
//!
//! [`MockFlow`] is pure math over `&[f32]` buffers with the batch size
//! derived per call — the same weights serve every lowered bucket, exactly
//! like the real per-batch artifact families. [`MockServeBackend`] wraps it
//! as a [`Backend`] suitable for the router/server stack: host-only values,
//! a thread-shareable call ledger, an optional per-slot decode delay that
//! scales with the batch dimension (so padded slots cost real time, the
//! effect the bucketed serving engine exists to remove), and bucket-gated
//! `has_artifact` so only configured batch sizes appear lowered.

use crate::runtime::{Backend, HostTensor, ModelMeta, Value};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The analytic flow: per-block coupling strengths + geometry.
pub struct MockFlow {
    /// Per-block coupling strengths (index = block `k`); `len()` = K.
    pub a: Vec<f32>,
    /// Sequence length L.
    pub l: usize,
    /// Token dim D.
    pub d: usize,
    /// Model (KV cache) dim Dm.
    pub dm: usize,
    /// Residual-history length of the fused multi-step artifacts (the
    /// lowered `S_max` — mirrors `aot.JSTEP_FUSE_STEPS`). Kept below L so
    /// τ=0 decodes need multiple chunks, which is the case the host-sync
    /// ledger tests pin.
    pub fuse_s_max: usize,
}

impl MockFlow {
    /// The canonical test geometry: K=4, L=8, D=3, Dm=4, non-square 2×4
    /// image grid at patch 1, fused history S_max=4.
    pub fn standard() -> Self {
        MockFlow { a: vec![0.9, 0.2, 0.15, 0.6], l: 8, d: 3, dm: 4, fuse_s_max: 4 }
    }

    /// s,g conditioner: g_l = a_k · mean over tokens < l (per-dim), s = 0.
    fn g_at(&self, k: usize, z: &[f32], b: usize, l_idx: usize) -> Vec<f32> {
        let (l, d) = (self.l, self.d);
        let a = self.a[k];
        let mut g = vec![0.0f32; d];
        if l_idx == 0 {
            return g;
        }
        for li in 0..l_idx {
            for di in 0..d {
                g[di] += z[(b * l + li) * d + di];
            }
        }
        for gi in g.iter_mut() {
            *gi = a * *gi / l_idx as f32;
        }
        g
    }

    fn g_at_masked(&self, k: usize, z: &[f32], b: usize, l_idx: usize, bound: usize) -> Vec<f32> {
        let (l, d) = (self.l, self.d);
        let a = self.a[k];
        let mut g = vec![0.0f32; d];
        let n = bound.max(1);
        for li in 0..bound.max(1).min(l_idx) {
            for di in 0..d {
                g[di] += z[(b * l + li) * d + di];
            }
        }
        for gi in g.iter_mut() {
            *gi = a * *gi / n as f32;
        }
        g
    }

    /// Forward `v = A_k(u)` over `batch` samples.
    pub fn fwd(&self, k: usize, u: &[f32], batch: usize) -> Vec<f32> {
        let (l, d) = (self.l, self.d);
        let mut v = vec![0.0f32; u.len()];
        for b in 0..batch {
            for li in 0..l {
                let g = self.g_at(k, u, b, li);
                for di in 0..d {
                    let idx = (b * l + li) * d + di;
                    v[idx] = u[idx] - g[di];
                }
            }
        }
        v
    }

    /// One Jacobi update of the inverse system (masked variant shifts the
    /// prefix bound like eq 6). Returns `(z', resid[batch])`.
    pub fn jstep(
        &self,
        k: usize,
        z: &[f32],
        y: &[f32],
        o: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (l, d) = (self.l, self.d);
        let mut z_next = vec![0.0f32; z.len()];
        let mut resid = vec![0.0f32; batch];
        for b in 0..batch {
            for li in 0..l {
                let bound = li.saturating_sub(o);
                let g = if li == 0 { vec![0.0; d] } else { self.g_at_masked(k, z, b, li, bound) };
                for di in 0..d {
                    let idx = (b * l + li) * d + di;
                    z_next[idx] = if li == 0 { y[idx] } else { y[idx] + g[di] };
                    resid[b] = resid[b].max((z_next[idx] - z[idx]).abs());
                }
            }
        }
        (z_next, resid)
    }

    /// Windowed GS-Jacobi inner step: positions outside `[off, off+len)` are
    /// copied through; the residual covers the window only (it equals the
    /// full max since frozen positions contribute |z' − z| = 0). Uses the
    /// same `g_at` arithmetic as `jstep`/`seq_step`, so a full GS sweep is
    /// bit-exact with sequential decoding.
    pub fn jstep_win(
        &self,
        k: usize,
        z: &[f32],
        y: &[f32],
        off: usize,
        wlen: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (l, d) = (self.l, self.d);
        let mut z_next = z.to_vec();
        let mut resid = vec![0.0f32; batch];
        for b in 0..batch {
            for li in off..(off + wlen).min(l) {
                let g = self.g_at(k, z, b, li);
                for di in 0..d {
                    let idx = (b * l + li) * d + di;
                    z_next[idx] = if li == 0 { y[idx] } else { y[idx] + g[di] };
                    resid[b] = resid[b].max((z_next[idx] - z[idx]).abs());
                }
            }
        }
        (z_next, resid)
    }

    /// Fused multi-step Jacobi: up to `steps` [`MockFlow::jstep`] updates
    /// (clamped to [`MockFlow::fuse_s_max`], exact `o = 0` arithmetic —
    /// bit-identical to the per-step path) plus the `[S_max, batch]`
    /// residual history; rows past the steps actually run keep the −1
    /// "not run" sentinel, mirroring the lowered artifact.
    pub fn jstep_fuse(
        &self,
        k: usize,
        z: &[f32],
        y: &[f32],
        steps: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let s_max = self.fuse_s_max;
        let mut hist = vec![-1.0f32; s_max * batch];
        let mut z = z.to_vec();
        for i in 0..steps.min(s_max) {
            let (zn, r) = self.jstep(k, &z, y, 0, batch);
            z = zn;
            hist[i * batch..(i + 1) * batch].copy_from_slice(&r);
        }
        (z, hist)
    }

    /// Fused multi-step windowed Jacobi: up to `steps`
    /// [`MockFlow::jstep_win`] updates with the same history contract as
    /// [`MockFlow::jstep_fuse`].
    #[allow(clippy::too_many_arguments)]
    pub fn jstep_win_fuse(
        &self,
        k: usize,
        z: &[f32],
        y: &[f32],
        steps: usize,
        off: usize,
        wlen: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let s_max = self.fuse_s_max;
        let mut hist = vec![-1.0f32; s_max * batch];
        let mut z = z.to_vec();
        for i in 0..steps.min(s_max) {
            let (zn, r) = self.jstep_win(k, &z, y, off, wlen, batch);
            z = zn;
            hist[i * batch..(i + 1) * batch].copy_from_slice(&r);
        }
        (z, hist)
    }

    /// One sequential token step: the decoded prefix lives in the kv_k cache
    /// (slot `[0, b, pos, 0..D]`), mirroring the real cache contract.
    /// Returns `(u_tok[batch, D], kv_k', kv_v')`.
    #[allow(clippy::too_many_arguments)]
    pub fn seq_step(
        &self,
        k: usize,
        u_prev: &[f32],
        v_tok: &[f32],
        pos: usize,
        kv_k: &[f32],
        kv_v: &[f32],
        batch: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (l, d, dm) = (self.l, self.d, self.dm);
        let mut kv_k = kv_k.to_vec();
        let kv_v = kv_v.to_vec();
        // Write u_prev (token at net position pos, i.e. u_{pos-1}) into the
        // cache at pos-1.
        if pos > 0 {
            for b in 0..batch {
                for di in 0..d {
                    kv_k[(b * l + (pos - 1)) * dm + di] = u_prev[b * d + di];
                }
            }
        }
        // u_pos = v_pos + g(prefix) with prefix read from the cache.
        let mut u_tok = vec![0.0f32; batch * d];
        for b in 0..batch {
            if pos == 0 {
                u_tok[b * d..(b + 1) * d].copy_from_slice(&v_tok[b * d..(b + 1) * d]);
            } else {
                let a = self.a[k];
                for di in 0..d {
                    let mut g = 0.0;
                    for li in 0..pos {
                        g += kv_k[(b * l + li) * dm + di];
                    }
                    u_tok[b * d + di] = v_tok[b * d + di] + a * g / pos as f32;
                }
            }
        }
        (u_tok, kv_k, kv_v)
    }

    /// Speculative z⁰ projection (the `{m}_init_proj_b{B}` analog): one
    /// exact Jacobi update evaluated at `z = y` — Alg 1's body with the
    /// iterate pinned to the right-hand side, no residual output. From this
    /// seed positions 0 *and* 1 are already exact, so a τ=0 refine needs
    /// strictly fewer iterations than a Zeros-init decode.
    pub fn init_proj(&self, k: usize, y: &[f32], batch: usize) -> Vec<f32> {
        self.jstep(k, y, y, 0, batch).0
    }

    /// Slot remap along the batch axis (the device-side
    /// `{m}_slot_gather_b{B}` analog): `out[b] = t[idx[b]]`. The continuous
    /// batcher uses it to compact surviving slots to the front of a wave
    /// after a cancellation sweep; pad rows re-point at row 0.
    pub fn gather_slots(&self, t: &[f32], idx: &[i32], batch: usize) -> Result<Vec<f32>> {
        let row = self.l * self.d;
        let mut out = vec![0.0f32; t.len()];
        for (b, &src) in idx.iter().enumerate().take(batch) {
            let src = src as usize;
            if src >= batch {
                bail!("slot gather index {src} out of bucket {batch}");
            }
            out[b * row..(b + 1) * row].copy_from_slice(&t[src * row..(src + 1) * row]);
        }
        Ok(out)
    }

    /// Token reversal along the sequence axis (the device-side `P_k` gather).
    pub fn reverse(&self, t: &[f32], batch: usize) -> Vec<f32> {
        let (l, d) = (self.l, self.d);
        let mut out = vec![0.0f32; t.len()];
        for b in 0..batch {
            for li in 0..l {
                let s = (b * l + li) * d;
                let dst = (b * l + (l - 1 - li)) * d;
                out[dst..dst + d].copy_from_slice(&t[s..s + d]);
            }
        }
        out
    }

    /// Execute an artifact by name on host tensors, with the batch size
    /// derived from the input shapes — the single dispatch every mock
    /// backend entry path shares.
    pub fn exec(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        // Fused roles first: their names contain the per-step role names.
        if name.contains("jstep_win_fuse") {
            let batch = inputs[1].shape()[0];
            let k = inputs[0].as_i32()?[0] as usize;
            let z = inputs[1].as_f32()?;
            let y = inputs[2].as_f32()?;
            let steps = inputs[3].as_i32()?[0] as usize;
            let off = inputs[4].as_i32()?[0] as usize;
            let wlen = inputs[5].as_i32()?[0] as usize;
            let (zn, hist) = self.jstep_win_fuse(k, z, y, steps, off, wlen, batch);
            Ok(vec![
                HostTensor::f32(inputs[1].shape(), zn),
                HostTensor::f32(&[self.fuse_s_max, batch], hist),
            ])
        } else if name.contains("jstep_fuse") {
            let batch = inputs[1].shape()[0];
            let k = inputs[0].as_i32()?[0] as usize;
            let z = inputs[1].as_f32()?;
            let y = inputs[2].as_f32()?;
            let steps = inputs[3].as_i32()?[0] as usize;
            let (zn, hist) = self.jstep_fuse(k, z, y, steps, batch);
            Ok(vec![
                HostTensor::f32(inputs[1].shape(), zn),
                HostTensor::f32(&[self.fuse_s_max, batch], hist),
            ])
        } else if name.contains("jstep_win") {
            let batch = inputs[1].shape()[0];
            let k = inputs[0].as_i32()?[0] as usize;
            let z = inputs[1].as_f32()?;
            let y = inputs[2].as_f32()?;
            let off = inputs[3].as_i32()?[0] as usize;
            let wlen = inputs[4].as_i32()?[0] as usize;
            let (zn, r) = self.jstep_win(k, z, y, off, wlen, batch);
            Ok(vec![HostTensor::f32(inputs[1].shape(), zn), HostTensor::f32(&[batch], r)])
        } else if name.contains("block_jstep") {
            let batch = inputs[1].shape()[0];
            let k = inputs[0].as_i32()?[0] as usize;
            let z = inputs[1].as_f32()?;
            let y = inputs[2].as_f32()?;
            let o = inputs[3].as_i32()?[0] as usize;
            let (zn, r) = self.jstep(k, z, y, o, batch);
            Ok(vec![HostTensor::f32(inputs[1].shape(), zn), HostTensor::f32(&[batch], r)])
        } else if name.contains("init_proj") {
            // Single output, like the untupled lowering: a chainable leaf.
            let batch = inputs[1].shape()[0];
            let k = inputs[0].as_i32()?[0] as usize;
            let y = inputs[1].as_f32()?;
            Ok(vec![HostTensor::f32(inputs[1].shape(), self.init_proj(k, y, batch))])
        } else if name.contains("block_fwd") {
            let batch = inputs[1].shape()[0];
            let k = inputs[0].as_i32()?[0] as usize;
            let u = inputs[1].as_f32()?;
            Ok(vec![HostTensor::f32(inputs[1].shape(), self.fwd(k, u, batch))])
        } else if name.contains("_slot_gather_") {
            // Untupled single output, like `_reverse_`: chainable device-side.
            let batch = inputs[0].shape()[0];
            let t = inputs[0].as_f32()?;
            let idx = inputs[1].as_i32()?;
            Ok(vec![HostTensor::f32(inputs[0].shape(), self.gather_slots(t, idx, batch)?)])
        } else if name.contains("_reverse_") {
            let batch = inputs[0].shape()[0];
            let t = inputs[0].as_f32()?;
            Ok(vec![HostTensor::f32(inputs[0].shape(), self.reverse(t, batch))])
        } else if name.contains("block_seqstep") {
            let batch = inputs[1].shape()[0];
            let k = inputs[0].as_i32()?[0] as usize;
            let u_prev = inputs[1].as_f32()?;
            let v_tok = inputs[2].as_f32()?;
            let pos = inputs[3].as_i32()?[0] as usize;
            let (u_tok, kv_k, kv_v) = self.seq_step(
                k,
                u_prev,
                v_tok,
                pos,
                inputs[4].as_f32()?,
                inputs[5].as_f32()?,
                batch,
            );
            Ok(vec![
                HostTensor::f32(&[batch, self.d], u_tok),
                HostTensor::f32(inputs[4].shape(), kv_k),
                HostTensor::f32(inputs[5].shape(), kv_v),
            ])
        } else {
            bail!("mock flow: unknown artifact '{name}'")
        }
    }
}

/// Thread-shareable call ledger: router workers run the backend on their own
/// threads, so tests observe calls through this `Arc` instead of poking the
/// backend directly.
#[derive(Default)]
pub struct MockLedger {
    calls: Mutex<BTreeMap<String, usize>>,
}

impl MockLedger {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    fn bump(&self, name: &str) {
        *self.calls.lock().unwrap().entry(name.to_string()).or_default() += 1;
    }

    /// Calls recorded for one exact artifact name.
    pub fn count(&self, name: &str) -> usize {
        self.calls.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Calls summed over every artifact whose name contains `sub`.
    pub fn count_containing(&self, sub: &str) -> usize {
        self.calls
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.contains(sub))
            .map(|(_, v)| v)
            .sum()
    }
}

/// [`Backend`] over [`MockFlow`] for the serving stack (router workers,
/// HTTP server, load bench). Host-only values; the batch size of every call
/// comes from the input shapes, so one backend serves all buckets.
pub struct MockServeBackend {
    pub flow: MockFlow,
    /// Batch sizes this mock claims artifacts for ([`Backend::has_artifact`]
    /// gates on the `_b{B}` name suffix, like a real bucketed manifest).
    pub buckets: Vec<usize>,
    /// Artificial decode cost: every jstep/seqstep call sleeps
    /// `slot_delay × B` (batch-proportional kernel time), so a padded slot
    /// wastes exactly as much wall time as a real one. A fused multi-step
    /// call sleeps `slot_delay × B × steps` — fusing removes round-trips,
    /// never compute, and the mock keeps that honest.
    pub slot_delay: Duration,
    /// Artificial per-call dispatch/sync overhead, charged to EVERY
    /// jstep/seqstep call regardless of how many updates it fuses — the
    /// launch + blocking-sync latency the chunked decode exists to
    /// amortize (`benches/jstep_fusion.rs` sets it; serving tests leave it
    /// zero).
    pub call_overhead: Duration,
    /// Roles hidden from [`Backend::has_artifact`] — `(role, bucket)` with
    /// `bucket = None` meaning every bucket. Models *partially* lowered
    /// artifact dirs (e.g. a bucket whose fused windowed step predates the
    /// lowering) so tests can pin the per-block degradation chain. Roles
    /// match exactly on the `_{role}_b` segment, so hiding
    /// `block_jstep_win` leaves `block_jstep_win_fuse` visible.
    pub missing: Vec<(String, Option<usize>)>,
    /// The device ordinal this backend claims its values live on
    /// ([`Backend::device_ordinal`]). Multi-device placement tests give the
    /// factory one ledger *per ordinal* and pin which ordinal's backend
    /// executed which calls; the values themselves stay host-only.
    pub ordinal: usize,
    pub ledger: Arc<MockLedger>,
}

impl MockServeBackend {
    pub fn new(buckets: &[usize], slot_delay: Duration, ledger: Arc<MockLedger>) -> Self {
        MockServeBackend {
            flow: MockFlow::standard(),
            buckets: buckets.to_vec(),
            slot_delay,
            call_overhead: Duration::ZERO,
            missing: Vec::new(),
            ordinal: 0,
            ledger,
        }
    }

    /// Builder: claim this backend's values live on device `ordinal` (the
    /// mock analog of `Engine::new_on`). Placement tests pair it with a
    /// per-ordinal ledger.
    pub fn on_ordinal(mut self, ordinal: usize) -> Self {
        self.ordinal = ordinal;
        self
    }

    /// Builder: set the per-call dispatch/sync overhead.
    pub fn with_call_overhead(mut self, overhead: Duration) -> Self {
        self.call_overhead = overhead;
        self
    }

    /// Builder: hide one artifact role (`block_jstep_win_fuse`, …) in every
    /// bucket.
    pub fn without_role(mut self, role: &str) -> Self {
        self.missing.push((role.to_string(), None));
        self
    }

    /// Builder: hide one artifact role in a single bucket — the partial
    /// manifest case the degradation-chain tests pin.
    pub fn without_role_in_bucket(mut self, role: &str, bucket: usize) -> Self {
        self.missing.push((role.to_string(), Some(bucket)));
        self
    }

    fn host(v: &Value) -> Result<HostTensor> {
        match v {
            Value::Host(t) => Ok(t.clone()),
            Value::Device(_) => bail!("MockServeBackend mints no device values"),
        }
    }
}

impl Backend for MockServeBackend {
    fn call_v(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        // Calling an artifact the manifest does not claim is a routing bug
        // (the degradation chain should have steered around it): fail loud.
        if !self.has_artifact(name) {
            bail!("mock: artifact '{name}' is not lowered");
        }
        self.ledger.bump(name);
        let host: Vec<HostTensor> = inputs.iter().map(Self::host).collect::<Result<_>>()?;
        let decode_call =
            name.contains("jstep") || name.contains("seqstep") || name.contains("init_proj");
        if decode_call && !self.call_overhead.is_zero() {
            std::thread::sleep(self.call_overhead);
        }
        if decode_call && !self.slot_delay.is_zero() {
            let batch = host[1].shape()[0];
            // Fused calls run `steps` updates' worth of kernel time.
            let steps = if name.contains("jstep_fuse") || name.contains("jstep_win_fuse") {
                (host[3].as_i32()?[0] as usize).clamp(1, self.flow.fuse_s_max)
            } else {
                1
            };
            std::thread::sleep(self.slot_delay * (batch * steps) as u32);
        }
        Ok(self.flow.exec(name, &host)?.into_iter().map(Value::Host).collect())
    }

    fn device_ordinal(&self) -> usize {
        self.ordinal
    }

    fn to_host(&self, v: Value) -> Result<HostTensor> {
        let t = Self::host(&v)?;
        // Record latent-tensor syncs per ordinal: a stage span ends in
        // exactly one rank-3 ([B, L, D]) host sync — the cross-span handoff
        // — so placement tests can see which ordinal paid it. Rank-1/2
        // syncs (residuals, histories, per-token rows) are decode-internal
        // and not interesting here.
        if t.shape().len() == 3 {
            self.ledger.bump(&format!("host_sync_latent_ord{}", self.ordinal));
        }
        Ok(t)
    }

    fn has_artifact(&self, name: &str) -> bool {
        // Only the configured buckets are "lowered": `{m}_<role>_b{B}` —
        // minus any roles the builder explicitly hid (partial manifests).
        let Some(bucket) =
            name.rsplit_once("_b").and_then(|(_, b)| b.parse::<usize>().ok())
        else {
            return false;
        };
        if !self.buckets.contains(&bucket) {
            return false;
        }
        !self.missing.iter().any(|(role, in_bucket)| {
            name.contains(&format!("_{role}_b")) && in_bucket.is_none_or(|b| b == bucket)
        })
    }

    fn model_meta(&self, model: &str) -> Result<ModelMeta> {
        Ok(ModelMeta {
            name: model.to_string(),
            kind: "tarflow".into(),
            seq_len: self.flow.l,
            blocks: self.flow.a.len(),
            token_dim: self.flow.d,
            model_dim: self.flow.dm,
            layers_per_block: 1,
            // Non-square 2×4 grid with patch 1: L = 2·4 = 8, D = 1·1·3 = 3.
            image_hwc: Some([2, 4, 3]),
            patch: 1,
            noise_std: 0.0,
            batch_sizes: self.buckets.clone(),
            extra: BTreeMap::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwd_inverse_roundtrip_any_batch() {
        // The same weights serve every batch size (bucket invariance): the
        // forward/Jacobi-fixed-point pair must close at B = 1 and B = 4.
        let f = MockFlow::standard();
        for batch in [1usize, 4] {
            let n = batch * f.l * f.d;
            let u: Vec<f32> = (0..n).map(|i| ((i * 37 + 11) % 17) as f32 / 17.0 - 0.5).collect();
            let v = f.fwd(1, &u, batch);
            let mut z = vec![0.0f32; n];
            for _ in 0..f.l {
                z = f.jstep(1, &z, &v, 0, batch).0;
            }
            let err = u.iter().zip(&z).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(err < 1e-4, "batch {batch}: inverse error {err}");
        }
    }

    #[test]
    fn fused_steps_match_repeated_single_steps() {
        let f = MockFlow::standard();
        let (batch, n) = (2usize, 2 * f.l * f.d);
        let u: Vec<f32> = (0..n).map(|i| ((i * 29 + 5) % 13) as f32 / 13.0 - 0.5).collect();
        let y = f.fwd(0, &u, batch);
        let z0 = vec![0.0f32; n];
        let (z_f, hist) = f.jstep_fuse(0, &z0, &y, 3, batch);
        let mut z = z0.clone();
        for i in 0..3 {
            let (zn, r) = f.jstep(0, &z, &y, 0, batch);
            z = zn;
            assert_eq!(&hist[i * batch..(i + 1) * batch], &r[..], "history row {i}");
        }
        assert_eq!(z_f, z, "fused must be bit-identical to repeated steps");
        // Rows past `steps` keep the −1 sentinel; steps clamp to S_max.
        assert!(hist[3 * batch..].iter().all(|&v| v == -1.0));
        let (z_a, _) = f.jstep_fuse(0, &z0, &y, 99, batch);
        let (z_b, _) = f.jstep_fuse(0, &z0, &y, f.fuse_s_max, batch);
        assert_eq!(z_a, z_b);
        // Windowed fused agrees with repeated windowed steps likewise.
        let (zw_f, whist) = f.jstep_win_fuse(0, &z0, &y, 2, 1, 4, batch);
        let mut zw = z0.clone();
        for i in 0..2 {
            let (zn, r) = f.jstep_win(0, &zw, &y, 1, 4, batch);
            zw = zn;
            assert_eq!(&whist[i * batch..(i + 1) * batch], &r[..]);
        }
        assert_eq!(zw_f, zw);
    }

    #[test]
    fn init_proj_seed_beats_zeros_on_iterations() {
        let f = MockFlow::standard();
        let (batch, n) = (2usize, 2 * f.l * f.d);
        let u: Vec<f32> = (0..n).map(|i| ((i * 31 + 7) % 19) as f32 / 19.0 - 0.5).collect();
        let y = f.fwd(2, &u, batch);
        let seed = f.init_proj(2, &y, batch);
        // Positions 0 and 1 are already exact from the projected seed.
        for b in 0..batch {
            for li in 0..2 {
                for di in 0..f.d {
                    let idx = (b * f.l + li) * f.d + di;
                    assert!((seed[idx] - u[idx]).abs() < 1e-5, "pos {li} must be exact");
                }
            }
        }
        // τ=0 refine iterations until the bit-exact fixed point verifies
        // (residual exactly 0): the projected seed must need strictly fewer.
        let iters = |mut z: Vec<f32>| {
            for it in 1..=f.l + 2 {
                let (zn, r) = f.jstep(2, &z, &y, 0, batch);
                z = zn;
                if r.iter().all(|&x| x == 0.0) {
                    return it;
                }
            }
            panic!("must converge within L+2 iterations")
        };
        let from_proj = iters(seed);
        let from_zeros = iters(vec![0.0f32; n]);
        assert!(from_proj < from_zeros, "proj {from_proj} vs zeros {from_zeros}");
    }

    #[test]
    fn slot_gather_permutes_batch_rows() {
        let f = MockFlow::standard();
        let (batch, row) = (4usize, f.l * f.d);
        let t: Vec<f32> = (0..batch * row).map(|i| (i / row) as f32).collect();
        // Compact rows {2, 3} to the front; pad rows re-point at row 0.
        let out = f.gather_slots(&t, &[2, 3, 0, 0], batch).unwrap();
        assert!(out[..row].iter().all(|&v| v == 2.0));
        assert!(out[row..2 * row].iter().all(|&v| v == 3.0));
        assert!(out[2 * row..].iter().all(|&v| v == 0.0));
        assert!(f.gather_slots(&t, &[4, 0, 0, 0], batch).is_err());
        // Exec dispatch: single untupled output with the input shape.
        let ht = HostTensor::f32(&[batch, f.l, f.d], t);
        let idx = HostTensor::i32(&[batch], vec![1, 0, 2, 3]);
        let outs = f.exec("mock_slot_gather_b4", &[ht, idx]).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape(), &[batch, f.l, f.d]);
    }

    #[test]
    fn bucket_gated_artifacts() {
        let be = MockServeBackend::new(&[1, 4], Duration::ZERO, MockLedger::new());
        assert!(be.has_artifact("mock_block_jstep_b1"));
        assert!(be.has_artifact("mock_reverse_b4"));
        assert!(!be.has_artifact("mock_block_jstep_b2"));
        assert!(!be.has_artifact("no_suffix"));
        assert_eq!(be.model_meta("mock").unwrap().batch_sizes, vec![1, 4]);
    }

    #[test]
    fn ledger_counts_across_threads() {
        let ledger = MockLedger::new();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let ledger = ledger.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    ledger.bump("m_block_jstep_b2");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ledger.count("m_block_jstep_b2"), 100);
        assert_eq!(ledger.count_containing("jstep"), 100);
    }
}
