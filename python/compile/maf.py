"""L2: Masked Autoregressive Flow (Papamakarios et al. 2017) in JAX.

Used for the paper's §E.3 experiments (Boltzmann approximation + binary image
generation). MLP-based MADE conditioners — no KV cache applies, which is why
the paper (and this repo) runs Jacobi decoding on *all* layers for MAF.

Conventions mirror `tarflow.py`:
* dim 0 of every layer passes through (identity), dims ≥ 1 are affine with
  (s, g) depending strictly on lower dims (MADE masks);
* layer stacking with order reversal between layers, applied OUTSIDE these
  functions (h_{k+1} = A_k(P_k h_k), P_k = reversal for odd k);
* per-layer params stacked on a leading K axis, gathered by a traced index.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MafConfig(NamedTuple):
    name: str
    dim: int             # d — number of sub-variables
    layers: int          # K
    hidden: int          # MADE hidden width
    dataset: str
    train_steps: int
    train_batch: int
    lr: float


def made_masks(dim: int, hidden: int):
    """Strictly-autoregressive MADE masks.

    Input degrees 1..d; hidden degrees cycle 1..d-1; output degree for dim l
    is l (so output l sees only inputs with degree < l — dim 0 (degree 1)
    sees nothing and is handled as an identity pass-through).
    """
    deg_in = jnp.arange(1, dim + 1)
    deg_h = (jnp.arange(hidden) % max(dim - 1, 1)) + 1
    deg_out = jnp.arange(1, dim + 1)
    m1 = (deg_h[None, :] >= deg_in[:, None]).astype(jnp.float32)      # (d, H)
    m2 = (deg_h[None, :] >= deg_h[:, None]).astype(jnp.float32)       # (H, H)
    m3 = (deg_out[:, None] > deg_h[None, :]).astype(jnp.float32).T    # (H, d)
    return m1, m2, m3


def init_layer_params(key, cfg: MafConfig):
    d, h = cfg.dim, cfg.hidden
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (d, h)) / jnp.sqrt(d),
        "b1": jnp.zeros((h,)),
        "w2": jax.random.normal(k2, (h, h)) / jnp.sqrt(h),
        "b2": jnp.zeros((h,)),
        # Two masked heads (s and g), zero-init → identity flow at start.
        "w3s": jnp.zeros((h, d)),
        "b3s": jnp.zeros((d,)),
        "w3g": jnp.zeros((h, d)),
        "b3g": jnp.zeros((d,)),
    }


def init_params(key, cfg: MafConfig):
    keys = jax.random.split(key, cfg.layers)
    layers = [init_layer_params(k, cfg) for k in keys]
    return {name: jnp.stack([l[name] for l in layers]) for name in layers[0]}


def layer_params(params, k):
    return {name: v[k] for name, v in params.items()}


def made_net(lp, cfg: MafConfig, x):
    """(s, g) each (B, d); output dim l depends only on x[:, :l]."""
    m1, m2, m3 = made_masks(cfg.dim, cfg.hidden)
    h = jnp.tanh(x @ (lp["w1"] * m1) + lp["b1"])
    h = jnp.tanh(h @ (lp["w2"] * m2) + lp["b2"])
    s_raw = h @ (lp["w3s"] * m3) + lp["b3s"]
    g = h @ (lp["w3g"] * m3) + lp["b3g"]
    s = 2.0 * jnp.tanh(s_raw / 2.0)
    # Dim 0 is identity: force s = g = 0 there (bias could move it).
    s = s.at[:, 0].set(0.0)
    g = g.at[:, 0].set(0.0)
    return s, g


def layer_forward(params, cfg: MafConfig, k, u):
    """v = A_k(u) (encode direction) + logdet. u: (B, d)."""
    lp = layer_params(params, k)
    s, g = made_net(lp, cfg, u)
    v = (u - g) * jnp.exp(s)
    logdet = jnp.sum(s, axis=-1)
    return v, logdet


def layer_jacobi_step(params, cfg: MafConfig, k, z_prev, y):
    """One parallel Jacobi update of A_k(z) = y + ‖·‖∞ residual.

    Sequential inference for MAF is exactly d of these updates (each one
    fixes at least the next dimension, Prop 3.2), so this single artifact
    serves both the sequential baseline and the accelerated path.
    """
    lp = layer_params(params, k)
    s, g = made_net(lp, cfg, z_prev)
    z_next = y * jnp.exp(-s) + g
    resid = jnp.max(jnp.abs(z_next - z_prev), axis=-1)
    return z_next, resid


def layer_inverse_exact(params, cfg: MafConfig, k, y):
    """Exact inverse via d Jacobi steps (build-time / tests only)."""
    z = jnp.zeros_like(y)
    for _ in range(cfg.dim):
        z, _ = layer_jacobi_step(params, cfg, k, z, y)
    return z


def flow_forward(params, cfg: MafConfig, x):
    """Full encode x → (z, logdet) with inter-layer reversal."""
    h = x
    logdet = jnp.zeros((x.shape[0],))
    for k in range(cfg.layers):
        u = h[:, ::-1] if k % 2 == 1 else h
        h, ld = layer_forward(params, cfg, k, u)
        logdet = logdet + ld
    return h, logdet


def nll_loss(params, cfg: MafConfig, x):
    z, logdet = flow_forward(params, cfg, x)
    d = z.shape[1]
    log_prior = -0.5 * jnp.sum(z ** 2, axis=-1) - 0.5 * d * jnp.log(2 * jnp.pi)
    return -(log_prior + logdet).mean() / d


@functools.partial(jax.jit, static_argnames=("cfg",))
def nll_loss_jit(params, cfg: MafConfig, x):
    return nll_loss(params, cfg, x)
