//! BRISQUE-style no-reference quality score.
//!
//! Implements the published, training-free part of BRISQUE (Mittal et al.
//! 2012): MSCN (mean-subtracted contrast-normalized) coefficients and
//! asymmetric generalized Gaussian (AGGD) fits of the MSCN field and its four
//! pairwise products, at two scales → an 18-dim feature vector. The trained
//! SVR readout is substituted by a fixed linear model centred on natural-
//! scene statistics (see DESIGN.md §5) — we use the score only to compare
//! decoding strategies against each other.

use crate::imageio::Image;

/// Gaussian 7×7 kernel weights (σ = 7/6), separable.
fn gaussian_kernel() -> [f32; 7] {
    let sigma = 7.0f32 / 6.0;
    let mut k = [0.0f32; 7];
    let mut sum = 0.0;
    for (i, kv) in k.iter_mut().enumerate() {
        let x = i as f32 - 3.0;
        *kv = (-x * x / (2.0 * sigma * sigma)).exp();
        sum += *kv;
    }
    for kv in k.iter_mut() {
        *kv /= sum;
    }
    k
}

/// Separable Gaussian blur with edge clamping.
fn blur(src: &[f32], w: usize, h: usize) -> Vec<f32> {
    let k = gaussian_kernel();
    let mut tmp = vec![0.0f32; w * h];
    let mut out = vec![0.0f32; w * h];
    for y in 0..h {
        for x in 0..w {
            let mut s = 0.0;
            for (i, &kv) in k.iter().enumerate() {
                let xi = (x as isize + i as isize - 3).clamp(0, w as isize - 1) as usize;
                s += kv * src[y * w + xi];
            }
            tmp[y * w + x] = s;
        }
    }
    for y in 0..h {
        for x in 0..w {
            let mut s = 0.0;
            for (i, &kv) in k.iter().enumerate() {
                let yi = (y as isize + i as isize - 3).clamp(0, h as isize - 1) as usize;
                s += kv * tmp[yi * w + x];
            }
            out[y * w + x] = s;
        }
    }
    out
}

/// MSCN field: (I − μ) / (σ + 1).
fn mscn(lum: &[f32], w: usize, h: usize) -> Vec<f32> {
    let mu = blur(lum, w, h);
    let sq: Vec<f32> = lum.iter().map(|&v| v * v).collect();
    let musq = blur(&sq, w, h);
    lum.iter()
        .zip(mu.iter().zip(musq.iter()))
        .map(|(&v, (&m, &m2))| {
            let sigma = (m2 - m * m).max(0.0).sqrt();
            (v - m) / (sigma + 1.0)
        })
        .collect()
}

/// Fit a (symmetric) generalized Gaussian to samples: returns (alpha, sigma²).
/// Moment-matching estimator via the ratio σ²/E|x|².
fn ggd_fit(x: &[f32]) -> (f32, f32) {
    let n = x.len().max(1) as f64;
    let mean_abs = x.iter().map(|&v| v.abs() as f64).sum::<f64>() / n;
    let var = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n;
    if var < 1e-12 || mean_abs < 1e-12 {
        return (2.0, var as f32);
    }
    let rho = var / (mean_abs * mean_abs);
    (inv_gamma_ratio(rho), var as f32)
}

/// AGGD fit of asymmetric samples: (alpha, mean, sigma_l², sigma_r²).
fn aggd_fit(x: &[f32]) -> (f32, f32, f32, f32) {
    let mut nl = 0usize;
    let mut nr = 0usize;
    let mut sl = 0.0f64;
    let mut sr = 0.0f64;
    let mut mean_abs = 0.0f64;
    for &v in x {
        let v = v as f64;
        mean_abs += v.abs();
        if v < 0.0 {
            nl += 1;
            sl += v * v;
        } else {
            nr += 1;
            sr += v * v;
        }
    }
    let n = x.len().max(1) as f64;
    mean_abs /= n;
    let sigma_l2 = if nl > 0 { sl / nl as f64 } else { 1e-12 };
    let sigma_r2 = if nr > 0 { sr / nr as f64 } else { 1e-12 };
    let gamma_hat = (sigma_l2.sqrt() / sigma_r2.sqrt()).max(1e-6);
    let total_var = (sl + sr) / n;
    let r_hat = if total_var > 1e-12 { mean_abs * mean_abs / total_var } else { 0.5 };
    let rhat_norm = r_hat * (gamma_hat.powi(3) + 1.0) * (gamma_hat + 1.0)
        / (gamma_hat.powi(2) + 1.0).powi(2);
    let alpha = inv_gamma_ratio(1.0 / rhat_norm.max(1e-6));
    // AGGD mean term (η in the paper).
    let eta = (sigma_r2.sqrt() - sigma_l2.sqrt())
        * (gamma_fn(2.0 / alpha as f64) / gamma_fn(1.0 / alpha as f64));
    (alpha, eta as f32, sigma_l2 as f32, sigma_r2 as f32)
}

/// Solve Γ(1/α)Γ(3/α)/Γ(2/α)² = rho for α by bisection on [0.2, 10].
fn inv_gamma_ratio(rho: f64) -> f32 {
    let f = |a: f64| gamma_fn(1.0 / a) * gamma_fn(3.0 / a) / gamma_fn(2.0 / a).powi(2);
    // f is decreasing in α; f(2) = Γ(.5)Γ(1.5)/Γ(1)² = π/2·(1/√π·√π/2)… just bisect.
    let (mut lo, mut hi) = (0.2f64, 10.0f64);
    if rho >= f(lo) {
        return lo as f32;
    }
    if rho <= f(hi) {
        return hi as f32;
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if f(mid) > rho {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (0.5 * (lo + hi)) as f32
}

/// Lanczos approximation of Γ(x) for x > 0.
fn gamma_fn(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma_fn(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

/// 18-dim BRISQUE feature vector (2 scales × (2 GGD + 4×4 AGGD → collapsed)).
///
/// Per scale: GGD (α, σ²) of MSCN + for each of 4 orientations the AGGD
/// (α, η) — 2 + 8 = 10... we keep the classic 18: per scale 2 + 4·4 = 18/2 = 9?
/// We follow the original: per scale 2 (GGD) + 4 orientations × 4 params = 18
/// per scale is 18; two scales → 36. For the comparative role here we keep
/// scale-1 features plus downsampled-scale GGD: 18 + 2 = 20 dims.
pub fn brisque_features(img: &Image) -> Vec<f32> {
    let mut feats = Vec::with_capacity(20);
    let lum = img.luminance();
    push_scale_features(&mut feats, &lum, img.width, img.height);
    // Second scale: 2× downsample (box filter).
    let (w2, h2) = (img.width / 2, img.height / 2);
    if w2 >= 8 && h2 >= 8 {
        let mut small = vec![0.0f32; w2 * h2];
        for y in 0..h2 {
            for x in 0..w2 {
                let s = lum[(2 * y) * img.width + 2 * x]
                    + lum[(2 * y) * img.width + 2 * x + 1]
                    + lum[(2 * y + 1) * img.width + 2 * x]
                    + lum[(2 * y + 1) * img.width + 2 * x + 1];
                small[y * w2 + x] = s / 4.0;
            }
        }
        let m = mscn(&small, w2, h2);
        let (a, v) = ggd_fit(&m);
        feats.push(a);
        feats.push(v);
    } else {
        feats.push(2.0);
        feats.push(0.0);
    }
    feats
}

fn push_scale_features(feats: &mut Vec<f32>, lum: &[f32], w: usize, h: usize) {
    let m = mscn(lum, w, h);
    let (alpha, var) = ggd_fit(&m);
    feats.push(alpha);
    feats.push(var);
    // Pairwise products along 4 orientations: H, V, D1, D2.
    let pairs: [(isize, isize); 4] = [(0, 1), (1, 0), (1, 1), (1, -1)];
    for (dy, dx) in pairs {
        let mut prod = Vec::with_capacity(w * h);
        for y in 0..h {
            for x in 0..w {
                let y2 = y as isize + dy;
                let x2 = x as isize + dx;
                if y2 >= 0 && (y2 as usize) < h && x2 >= 0 && (x2 as usize) < w {
                    prod.push(m[y * w + x] * m[y2 as usize * w + x2 as usize]);
                }
            }
        }
        let (a, eta, sl, sr) = aggd_fit(&prod);
        feats.push(a);
        feats.push(eta);
        feats.push(sl);
        feats.push(sr);
    }
}

/// Scalar BRISQUE-style score (higher = closer to natural-scene statistics,
/// matching the paper's "BRISQUE ↑" table orientation).
///
/// Natural images have MSCN α ≈ 2 (Gaussian-ish) with moderate variance;
/// distortions push α and the AGGD asymmetries away. The fixed readout
/// penalizes deviation from those anchors.
pub fn brisque(img: &Image) -> f32 {
    let f = brisque_features(img);
    let mut penalty = 0.0f32;
    // GGD alpha anchors (features 0 and 18), natural ≈ 2.0.
    penalty += (f[0] - 2.0).abs();
    penalty += (f[18] - 2.0).abs();
    // Variance anchors: natural MSCN variance ≈ 0.5–1.5.
    penalty += (f[1] - 1.0).abs() * 0.5;
    // AGGD asymmetry: |σl − σr| should be small for natural images.
    for k in 0..4 {
        let sl = f[2 + 4 * k + 2];
        let sr = f[2 + 4 * k + 3];
        penalty += (sl - sr).abs();
    }
    // Map to a 0–100-ish scale, higher = better.
    100.0 / (1.0 + penalty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn natural_ish(seed: u64) -> Image {
        // Smooth gradient + mild noise ≈ locally-correlated "natural" patch.
        let mut rng = Pcg64::seed(seed);
        let mut img = Image::new(32, 32);
        for y in 0..32 {
            for x in 0..32 {
                let base = 80.0 + 3.0 * x as f32 + 1.5 * y as f32;
                let v = (base + 10.0 * rng.next_gaussian()).clamp(0.0, 255.0) as u8;
                img.set(x, y, [v, v, v]);
            }
        }
        img
    }

    fn saturated(seed: u64) -> Image {
        // Harsh binary blocks: heavily distorted statistics.
        let mut rng = Pcg64::seed(seed);
        let mut img = Image::new(32, 32);
        for y in 0..32 {
            for x in 0..32 {
                let v = if rng.next_f32() > 0.5 { 255 } else { 0 };
                img.set(x, y, [v, v, v]);
            }
        }
        img
    }

    #[test]
    fn gamma_known_values() {
        assert!((gamma_fn(1.0) - 1.0).abs() < 1e-9);
        assert!((gamma_fn(5.0) - 24.0).abs() < 1e-6);
        assert!((gamma_fn(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn ggd_fit_gaussian_gives_alpha_2() {
        let mut rng = Pcg64::seed(77);
        let x: Vec<f32> = (0..20_000).map(|_| rng.next_gaussian()).collect();
        let (alpha, var) = ggd_fit(&x);
        assert!((alpha - 2.0).abs() < 0.15, "alpha {alpha}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn ggd_fit_laplacian_gives_alpha_1() {
        // Laplace via difference of exponentials.
        let mut rng = Pcg64::seed(78);
        let x: Vec<f32> = (0..20_000)
            .map(|_| (rng.next_exp() - rng.next_exp()) as f32 / std::f32::consts::SQRT_2)
            .collect();
        let (alpha, _) = ggd_fit(&x);
        assert!((alpha - 1.0).abs() < 0.15, "alpha {alpha}");
    }

    #[test]
    fn feature_vector_dims() {
        let img = natural_ish(1);
        assert_eq!(brisque_features(&img).len(), 20);
    }

    #[test]
    fn natural_beats_distorted() {
        let nat = brisque(&natural_ish(2));
        let dis = brisque(&saturated(3));
        assert!(nat > dis, "natural {nat} must score above distorted {dis}");
    }

    #[test]
    fn deterministic() {
        let img = natural_ish(4);
        assert_eq!(brisque(&img), brisque(&img));
    }

    #[test]
    fn mscn_roughly_standardized() {
        let img = natural_ish(5);
        let m = mscn(&img.luminance(), 32, 32);
        let mean = m.iter().sum::<f32>() / m.len() as f32;
        assert!(mean.abs() < 0.2, "MSCN mean {mean}");
    }
}
