//! Adaptive policy calibration: measure per-block sequential vs Jacobi cost,
//! derive a per-block policy (including GS-Jacobi window counts), and
//! compare it against the paper's static SJD.
//!
//! Demonstrates the `DecodePolicy::Custom` and `DecodePolicy::PerBlock`
//! paths — on models whose redundancy profile differs from "first block
//! only", calibration can beat static SJD, and window-aware calibration cuts
//! position-updates further on strongly coupled blocks.
//!
//! ```bash
//! cargo run --release --example calibrate_policy [artifacts] [model]
//! ```

use anyhow::Result;
use sjd::coordinator::jacobi::JacobiConfig;
use sjd::coordinator::policy::{calibrate, calibrate_windows, DecodePolicy};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::runtime::Engine;
use sjd::tensor::Pcg64;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = std::env::args().nth(2).unwrap_or_else(|| "tf10".into());
    let engine = Engine::new(&artifacts)?;
    let batch = engine.manifest().model(&model)?.batch_sizes.iter().copied().max().unwrap_or(1);
    let sampler = Sampler::new(&engine, &model, batch)?;
    let kk = sampler.meta.blocks;

    // --- calibration pass: decode one prior batch, measuring both paths ---
    let mut rng = Pcg64::seed(7);
    let mut h = sampler.sample_prior(&mut rng);
    let mut seq_walls = Vec::new();
    let mut jstats = Vec::new();
    println!("calibrating {} ({} blocks)...", model, kk);
    for pos in 0..kk {
        let k = kk - 1 - pos;
        let t0 = std::time::Instant::now();
        let (u, _) = sampler.sequential_decode_block(k, &h)?;
        seq_walls.push(t0.elapsed());
        let (_, stats) = sampler.jacobi_decode(k, &h, &JacobiConfig::default(), 0)?;
        println!(
            "  pos {pos}: seq {:>6.1} ms | jacobi {:>2} iters {:>6.1} ms{}",
            seq_walls[pos].as_secs_f64() * 1e3,
            stats.iterations,
            stats.wall.as_secs_f64() * 1e3,
            if stats.converged { "" } else { " (cap hit)" }
        );
        jstats.push(stats);
        h = if k % 2 == 1 { sampler.reverse_tokens(&u)? } else { u };
    }
    let adaptive = calibrate(&jstats, &seq_walls);
    println!("calibrated (binary): {adaptive:?}");
    let adaptive_gs = calibrate_windows(&jstats, &seq_walls, sampler.meta.seq_len, 8);
    println!("calibrated (windowed): {adaptive_gs:?}");

    // --- compare policies end to end ---
    for policy in [
        DecodePolicy::Sequential,
        DecodePolicy::UniformJacobi,
        DecodePolicy::Selective { seq_blocks: 1 },
        DecodePolicy::GsJacobi { windows: 4 },
        adaptive,
        adaptive_gs,
    ] {
        let label = policy.label();
        let opts = SampleOptions { policy, ..Default::default() };
        let mut rng = Pcg64::seed(42);
        // Warmup + timed run.
        let _ = sampler.sample_images(&opts, &mut rng)?;
        let mut rng = Pcg64::seed(43);
        let (_, out) = sampler.sample_images(&opts, &mut rng)?;
        println!(
            "{label:>16}: {:.3}s per batch of {batch}, {} position-updates",
            out.total_wall.as_secs_f64(),
            out.total_position_updates()
        );
    }
    Ok(())
}
