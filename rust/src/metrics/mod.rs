//! Serving metrics: counters, gauges, log-bucketed latency histograms with
//! percentile snapshots, and a Prometheus-style text exposition.
//!
//! All types are `Send + Sync` (atomics / mutex-protected) so worker threads
//! and the HTTP `/metrics` endpoint share one [`Registry`].

mod histogram;
mod registry;

pub use histogram::{Histogram, Snapshot};
pub use registry::{Counter, Gauge, Registry};
