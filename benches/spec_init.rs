//! **Speculative initialization**: the `--init` z⁰ providers vs the Zeros
//! baseline, over the **mock backend** — no artifacts needed, so it runs
//! everywhere (including the CI smoke step).
//!
//! Exact-decode regime: a vanishing τ plus an `L+1` iteration budget makes
//! every strategy run to the mock's bit-exact fixed point (Prop 3.2: the
//! τ→0 fixed point is independent of z⁰), so the providers can only differ
//! in *how fast* they get there. The honest cost metric is
//! `total_updates_with_spec()` — refine updates **plus** the speculation's
//! own updates (the projection call, the draft pass) — and blocking host
//! syncs. The acceptance gate mirrors the mock-ledger tests in
//! `rust/tests/mock_backend.rs`: every provider must produce bit-identical
//! tokens, and at least one speculative provider must beat Zeros on **both**
//! total position-updates and host syncs. Exits non-zero otherwise.
//!
//! The warm-start row stays cold here by design: the serve mock mints no
//! device values, and the warm cache stores converged *device* iterates
//! only (the ISSUE's residency rule) — its payoff is pinned by the
//! device-simulating mock in `rust/tests/mock_backend.rs`.
//!
//! ```bash
//! cargo bench --bench spec_init            # full run
//! cargo bench --bench spec_init -- --quick # CI smoke
//! ```

use anyhow::Result;
use sjd::benchkit::Report;
use sjd::coordinator::jacobi::InitStrategy;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::tensor::Pcg64;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::time::Duration;

/// Per-step kernel time (× batch — compute is never faked away).
const SLOT_DELAY: Duration = Duration::from_micros(30);
/// Per-call dispatch + blocking-sync overhead.
const CALL_OVERHEAD: Duration = Duration::from_micros(200);

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

struct Run {
    label: &'static str,
    speculative: bool,
    tokens: Vec<sjd::runtime::HostTensor>,
    updates: usize,
    refine_updates: usize,
    syncs: usize,
    hits: usize,
    wall: f64,
}

/// Decode the repeat-seed traffic `seeds` under one init strategy on a
/// fresh backend + sampler (per-run ledgers, per-run warm cache).
fn run(init: InitStrategy, seeds: &[u64]) -> Result<Run> {
    let be = MockServeBackend::new(&[2], SLOT_DELAY, MockLedger::new())
        .with_call_overhead(CALL_OVERHEAD);
    let sampler = Sampler::new(&be, "mock", 2)?;
    let seq_len = sampler.meta.seq_len;
    let mut opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    // Exact decode: the mock's residual is exactly 0 at the fixed point and
    // positive everywhere else, so a vanishing τ converges precisely on the
    // verify iteration; +1 budget lets the from-zeros solve reach it.
    opts.jacobi.tau = 1e-9;
    opts.jacobi.max_iters = Some(seq_len + 1);
    opts.jacobi.init = init;

    let mut out_tokens = Vec::with_capacity(seeds.len());
    let (mut updates, mut refine_updates, mut syncs, mut hits) = (0usize, 0usize, 0usize, 0usize);
    let mut wall = 0.0f64;
    for &seed in seeds {
        opts.seed = seed;
        let mut rng = Pcg64::seed(seed);
        let z = sampler.sample_prior(&mut rng);
        let out = sampler.decode_tokens(z, &opts)?;
        updates += out.total_updates_with_spec();
        refine_updates += out.total_position_updates();
        syncs += out.total_host_syncs();
        hits += out.spec_hits();
        wall += out.total_wall.as_secs_f64();
        out_tokens.push(out.tokens);
    }
    Ok(Run {
        label: init.label(),
        speculative: init.is_speculative(),
        tokens: out_tokens,
        updates,
        refine_updates,
        syncs,
        hits,
        wall,
    })
}

fn main() -> Result<()> {
    // Repeat-seed traffic (every request decoded twice in a row) — the
    // regime the warm-start provider exists for; the extrapolation and
    // draft providers are traffic-independent.
    let uniques = if quick() { 2 } else { 8 };
    let seeds: Vec<u64> = (0..uniques as u64).flat_map(|s| [42 + s, 42 + s]).collect();
    println!(
        "=== spec_init: z⁰ providers vs Zeros ({} exact decodes, repeat-seed \
         traffic, mock backend) ===",
        seeds.len()
    );
    let mut report =
        Report::new("Speculative initialization — position updates / host syncs vs Zeros");

    let zeros = run(InitStrategy::Zeros, &seeds)?;
    let providers: Vec<Run> = [
        InitStrategy::Normal,
        InitStrategy::PrevLayer,
        InitStrategy::Proj,
        InitStrategy::Draft,
        InitStrategy::Warm,
    ]
    .into_iter()
    .map(|init| run(init, &seeds))
    .collect::<Result<_>>()?;

    let mut rows = Vec::new();
    let mut equal_output = true;
    let mut winner = None;
    for r in std::iter::once(&zeros).chain(&providers) {
        let bit_equal = r.tokens == zeros.tokens;
        equal_output &= bit_equal;
        let wins = r.speculative && r.updates < zeros.updates && r.syncs < zeros.syncs;
        if wins && winner.is_none() {
            winner = Some(r.label);
        }
        println!(
            "{:>7}: {:>5} updates (+spec), {:>5} refine-only, {:>4} syncs, \
             {:>3} spec hits, {:.3}s{}{}",
            r.label,
            r.updates,
            r.refine_updates,
            r.syncs,
            r.hits,
            r.wall,
            if bit_equal { "" } else { "  OUTPUT DIVERGED" },
            if wins { "  < zeros" } else { "" },
        );
        rows.push(vec![
            r.label.to_string(),
            r.updates.to_string(),
            r.refine_updates.to_string(),
            r.syncs.to_string(),
            r.hits.to_string(),
            format!("{:.3}", r.wall),
            if bit_equal { "yes".into() } else { "NO".into() },
        ]);
    }
    report.table(
        &["init", "updates (+spec)", "refine updates", "host syncs", "spec hits", "wall (s)", "bit-equal"],
        &rows,
    );

    report.note(match winner {
        Some(w) => format!(
            "PASS: '{w}' beat Zeros on both total position-updates (speculation \
             cost included) and host syncs, at bit-identical exact output."
        ),
        None => "FAIL: no speculative provider paid for itself — speculation \
                 must beat Zeros on updates AND syncs at equal output."
            .into(),
    });
    report.note(
        "Draft charges its full coarse pass as speculation cost, so on the \
         mock's cheap blocks it reports an honest loss (the serving tuner's \
         fallback case); warm stays cold on this host-only mock (device-handle \
         cache) and is exercised in rust/tests/mock_backend.rs.",
    );
    report.finish();
    anyhow::ensure!(equal_output, "a provider's exact output diverged from Zeros");
    anyhow::ensure!(
        winner.is_some(),
        "no speculative provider beat Zeros on position updates + host syncs"
    );
    Ok(())
}
