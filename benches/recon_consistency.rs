//! **§E.4**: reconstruction consistency — encode real images with the exact
//! forward pass, decode with SJD (τ = 0.5), report MSE. Paper: near-zero MSE
//! (0.001–0.006), confirming the parallel iterations converge tightly to the
//! bijective inverse.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::imageio::{compose_grid, write_png, Image};
use sjd::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let mut report = Report::new("§E.4 — reconstruction consistency (fwd encode → SJD decode)");
    let mut rows = Vec::new();

    for model in ["tf10", "tf100", "tfafhq"] {
        if engine.manifest().model(model).is_err() {
            continue;
        }
        let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
        let sampler = Sampler::new(&engine, model, batch)?;
        let reference = engine.manifest().load_dataset(dataset_for(model))?;
        // Take the first `batch` real images.
        let hwc: usize = reference.shape()[1..].iter().product();
        let reals: Vec<Tensor> = (0..batch)
            .map(|i| {
                Tensor::new(
                    &reference.shape()[1..],
                    reference.data()[i * hwc..(i + 1) * hwc].to_vec(),
                )
            })
            .collect::<Result<_, _>>()?;

        let x = sampler.stack_images(&reals)?;
        let (z, _logdet) = sampler.encode(&x)?;
        let out = sampler.decode_tokens(z, &SampleOptions::default())?;
        let recon = sampler.unpatchify(&out.tokens)?;

        let mut mse = 0.0f32;
        for (a, b) in reals.iter().zip(&recon) {
            mse += a.mse(b)?;
        }
        mse /= batch as f32;
        println!("{model}: reconstruction MSE {mse:.6} over {batch} real images");
        rows.push(vec![paper_label(model).to_string(), format!("{mse:.6}")]);

        // Visual sheet: originals (top) vs reconstructions (bottom).
        let mut sheet = Vec::new();
        for t in reals.iter().take(8) {
            sheet.push(Image::from_tensor_pm1(t)?);
        }
        for t in recon.iter().take(8) {
            sheet.push(Image::from_tensor_pm1(t)?);
        }
        let grid = compose_grid(&sheet, 8, 2);
        let p = artifacts_dir().join(format!("recon_{model}.png"));
        write_png(&grid, &p)?;
        report.note(format!("{model}: sheet at {}", p.display()));
    }

    report.table(&["Dataset", "Reconstruction MSE"], &rows);
    report.note("Paper: 0.00636 / 0.00313 / 0.00122 — near-zero; ours should be the same order.");
    report.finish();
    Ok(())
}
