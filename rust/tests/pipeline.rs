//! Cross-layer integration tests over the real artifacts.
//!
//! These prove the full L1→L2→L3 composition: the rust decode (Jacobi and
//! sequential paths, permutation handling, patchify) exactly inverts the
//! python-lowered forward pass. Skipped with a message when `artifacts/`
//! hasn't been built (`make artifacts`).

use sjd::coordinator::jacobi::JacobiConfig;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::runtime::{Engine, HostTensor};
use sjd::tensor::{Pcg64, Tensor};

fn engine() -> Option<Engine> {
    let dir = std::env::var("SJD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (`make artifacts`)");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => return,
        }
    };
}

#[test]
fn rust_block_composition_matches_python_fwd() {
    // Composing block_fwd artifacts with rust-side permutations must equal
    // the python-composed full fwd artifact — proves the permutation
    // conventions match across the language boundary.
    let engine = require_engine!();
    let sampler = Sampler::new(&engine, "tf10", 1).expect("sampler");
    let meta = &sampler.meta;
    let [h, w, c] = meta.image_hwc.unwrap();
    let mut rng = Pcg64::seed(3);
    let img = Tensor::randn(&[h, w, c], &mut rng).scale(0.3);

    // Python path: full fwd.
    let x = sampler.stack_images(&[img.clone()]).unwrap();
    let (z_py, _logdet) = sampler.encode(&x).unwrap();

    // Rust path: patchify + per-block fwd with reversal for odd k.
    let mut hh = sampler.patchify(&[img]).unwrap();
    for k in 0..meta.blocks {
        let u = if k % 2 == 1 { sampler.reverse_tokens(&hh).unwrap() } else { hh };
        hh = sampler.block_forward(k, &u).unwrap();
    }
    let (a, b) = (z_py.as_f32().unwrap(), hh.as_f32().unwrap());
    let max_err = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "composition mismatch: {max_err}");
}

#[test]
fn jacobi_decode_inverts_block_forward() {
    let engine = require_engine!();
    let sampler = Sampler::new(&engine, "tf10", 1).expect("sampler");
    let meta = &sampler.meta;
    let mut rng = Pcg64::seed(4);
    let u = HostTensor::f32(
        &[1, meta.seq_len, meta.token_dim],
        Tensor::randn(&[1, meta.seq_len, meta.token_dim], &mut rng).into_data(),
    );
    for k in [0, meta.blocks - 1] {
        let v = sampler.block_forward(k, &u).unwrap();
        let cfg = JacobiConfig { tau: 1e-5, ..Default::default() };
        let (u_rec, stats) = sampler.jacobi_decode(k, &v, &cfg, 0).unwrap();
        let err = u
            .as_f32()
            .unwrap()
            .iter()
            .zip(u_rec.as_f32().unwrap())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3, "block {k}: inverse error {err}");
        assert!(stats.iterations <= meta.seq_len, "Prop 3.2 violated");
        assert!(stats.converged);
    }
}

#[test]
fn sequential_decode_matches_jacobi_exact() {
    let engine = require_engine!();
    let sampler = Sampler::new(&engine, "tf10", 1).expect("sampler");
    let meta = &sampler.meta;
    let mut rng = Pcg64::seed(5);
    let v = HostTensor::f32(
        &[1, meta.seq_len, meta.token_dim],
        Tensor::randn(&[1, meta.seq_len, meta.token_dim], &mut rng).into_data(),
    );
    let k = 1;
    let (u_seq, steps) = sampler.sequential_decode_block(k, &v).unwrap();
    assert_eq!(steps, meta.seq_len);
    let cfg = JacobiConfig { tau: 0.0, max_iters: Some(meta.seq_len), ..Default::default() };
    let (u_jac, _) = sampler.jacobi_decode(k, &v, &cfg, 0).unwrap();
    let err = u_seq
        .as_f32()
        .unwrap()
        .iter()
        .zip(u_jac.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-3, "seq vs jacobi-exact mismatch: {err}");
}

#[test]
fn jacobi_residuals_superlinear_trend() {
    // Prop 3.1: residuals should collapse fast (trained model → strong
    // contraction). Check the residual after 6 iterations is tiny relative
    // to the first.
    let engine = require_engine!();
    let sampler = Sampler::new(&engine, "tf10", 1).expect("sampler");
    let mut rng = Pcg64::seed(6);
    let z = sampler.sample_prior(&mut rng);
    // Use a later block (higher redundancy per the paper).
    let k = 0; // decoded last (pos = K-1) — refinement block
    let cfg = JacobiConfig { tau: 0.0, max_iters: Some(8), ..Default::default() };
    let (_, stats) = sampler.jacobi_decode(k, &z, &cfg, 0).unwrap();
    assert!(stats.residuals.len() >= 6);
    let first = stats.residuals[0];
    let sixth = stats.residuals[5];
    assert!(
        sixth < first * 0.25,
        "residuals not collapsing: {:?}",
        stats.residuals
    );
}

#[test]
fn full_sample_roundtrip_recon() {
    // encode(decode(z)) ≈ z: sample tokens with SJD, re-encode, compare.
    let engine = require_engine!();
    let sampler = Sampler::new(&engine, "tf10", 1).expect("sampler");
    let mut rng = Pcg64::seed(7);
    let z0 = sampler.sample_prior(&mut rng);
    let mut opts = SampleOptions::default();
    opts.jacobi.tau = 1e-4; // tight τ → near-exact inverse
    let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();
    let imgs = sampler.unpatchify(&out.tokens).unwrap();
    let x = sampler.stack_images(&imgs).unwrap();
    let (z1, _) = sampler.encode(&x).unwrap();
    let err: f32 = z0
        .as_f32()
        .unwrap()
        .iter()
        .zip(z1.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 0.05, "roundtrip error {err}");
}

#[test]
fn policies_agree_at_tight_tau() {
    // With τ → 0 every policy must produce the same images from the same z.
    let engine = require_engine!();
    let sampler = Sampler::new(&engine, "tf10", 1).expect("sampler");
    let mut rng = Pcg64::seed(8);
    let z = sampler.sample_prior(&mut rng);
    let mut outs = Vec::new();
    for policy in [
        DecodePolicy::Sequential,
        DecodePolicy::UniformJacobi,
        DecodePolicy::Selective { seq_blocks: 1 },
    ] {
        let mut opts = SampleOptions { policy, ..Default::default() };
        opts.jacobi.tau = 1e-5;
        let out = sampler.decode_tokens(z.clone(), &opts).unwrap();
        outs.push(out.tokens);
    }
    for pair in outs.windows(2) {
        let err: f32 = pair[0]
            .as_f32()
            .unwrap()
            .iter()
            .zip(pair[1].as_f32().unwrap())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-2, "policy outputs diverge: {err}");
    }
}

#[test]
fn patchify_unpatchify_inverse_property() {
    // Property-style: random images round-trip through rust patchify.
    let engine = require_engine!();
    let sampler = Sampler::new(&engine, "tf10", 8).expect("sampler");
    let [h, w, c] = sampler.meta.image_hwc.unwrap();
    use sjd::testkit::*;
    check(10, gen_usize(0, 10_000), |&seed| {
        let mut rng = Pcg64::seed(seed as u64);
        let imgs: Vec<Tensor> = (0..8).map(|_| Tensor::randn(&[h, w, c], &mut rng)).collect();
        let toks = sampler.patchify(&imgs).unwrap();
        let back = sampler.unpatchify(&toks).unwrap();
        imgs.iter()
            .zip(&back)
            .all(|(a, b)| a.mse(b).unwrap() < 1e-10)
    });
}

#[test]
fn maf_jacobi_inverts_fwd() {
    let engine = require_engine!();
    if engine.manifest().model("maf_ising").is_err() {
        eprintln!("SKIP: maf_ising not built");
        return;
    }
    use sjd::coordinator::maf::{MafMode, MafSampler};
    let batch = *engine.manifest().model("maf_ising").unwrap().batch_sizes.first().unwrap();
    let sampler = MafSampler::new(&engine, "maf_ising", batch).expect("maf sampler");
    // Sample (inverse direction), then encode (fwd) — must return the prior.
    let cfg = sjd::coordinator::maf::maf_config(1e-5);
    let mut rng = Pcg64::seed(11);
    let out = sampler.sample(MafMode::Jacobi, &cfg, &mut rng).unwrap();
    let (z, _ld) = sampler.encode(&out.samples).unwrap();
    // z should be standard-normal-ish: check moments rather than exact match
    // (prior draw isn't retained through the layer loop).
    let zs = z.as_f32().unwrap();
    let mean = zs.iter().sum::<f32>() / zs.len() as f32;
    let var = zs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / zs.len() as f32;
    assert!(mean.abs() < 0.1, "latent mean {mean}");
    assert!((var - 1.0).abs() < 0.3, "latent var {var}");
}
