//! 2-D Ising model with periodic boundaries.
//!
//! The MAF in the Boltzmann experiment (paper §E.3) is trained on a
//! *continuous relaxation*: spins are real values whose signs define the
//! lattice configuration. Observables are computed on the signed lattice,
//! matching the paper's "average energy / site" and "average absolute
//! magnetization" columns.

use crate::tensor::Pcg64;

/// L×L Ising model at temperature T (J = 1, k_B = 1).
#[derive(Clone, Debug)]
pub struct IsingModel {
    pub side: usize,
    pub temperature: f64,
}

/// Mean observables over a batch of configurations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IsingStats {
    /// ⟨E⟩ per site.
    pub energy_per_site: f64,
    /// ⟨|M|⟩ per site.
    pub abs_magnetization: f64,
}

impl IsingModel {
    pub fn new(side: usize, temperature: f64) -> Self {
        assert!(side >= 2);
        IsingModel { side, temperature }
    }

    pub fn num_sites(&self) -> usize {
        self.side * self.side
    }

    /// Energy of one configuration of ±1 spins: E = −Σ_<ij> s_i s_j
    /// (each bond counted once; periodic boundaries).
    pub fn energy(&self, spins: &[i8]) -> f64 {
        let n = self.side;
        debug_assert_eq!(spins.len(), n * n);
        let mut e = 0i64;
        for r in 0..n {
            for c in 0..n {
                let s = spins[r * n + c] as i64;
                let right = spins[r * n + (c + 1) % n] as i64;
                let down = spins[((r + 1) % n) * n + c] as i64;
                e -= s * (right + down);
            }
        }
        e as f64
    }

    /// Net magnetization Σ s_i.
    pub fn magnetization(&self, spins: &[i8]) -> f64 {
        spins.iter().map(|&s| s as f64).sum()
    }

    /// Convert continuous flow samples to spins by sign (0.0 → +1).
    pub fn spins_from_continuous(values: &[f32]) -> Vec<i8> {
        values.iter().map(|&v| if v < 0.0 { -1 } else { 1 }).collect()
    }

    /// Batch observables from continuous samples laid out (B, L·L).
    pub fn stats_from_continuous(&self, batch: &[f32]) -> IsingStats {
        let sites = self.num_sites();
        assert!(!batch.is_empty() && batch.len() % sites == 0);
        let b = batch.len() / sites;
        let mut e_sum = 0.0;
        let mut m_sum = 0.0;
        for i in 0..b {
            let spins = Self::spins_from_continuous(&batch[i * sites..(i + 1) * sites]);
            e_sum += self.energy(&spins) / sites as f64;
            m_sum += (self.magnetization(&spins) / sites as f64).abs();
        }
        IsingStats {
            energy_per_site: e_sum / b as f64,
            abs_magnetization: m_sum / b as f64,
        }
    }

    /// Unnormalized Boltzmann log-density of a spin configuration.
    pub fn log_prob(&self, spins: &[i8]) -> f64 {
        -self.energy(spins) / self.temperature
    }

    /// Metropolis single-spin-flip MCMC: `sweeps` full-lattice sweeps from a
    /// random configuration; returns the final configuration.
    pub fn metropolis_sample(&self, sweeps: usize, rng: &mut Pcg64) -> Vec<i8> {
        let n = self.side;
        let sites = n * n;
        let mut spins: Vec<i8> =
            (0..sites).map(|_| if rng.next_f64() < 0.5 { -1 } else { 1 }).collect();
        let beta = 1.0 / self.temperature;
        for _ in 0..sweeps {
            for _ in 0..sites {
                let idx = rng.next_below(sites);
                let (r, c) = (idx / n, idx % n);
                let s = spins[idx] as i64;
                let nb = spins[r * n + (c + 1) % n] as i64
                    + spins[r * n + (c + n - 1) % n] as i64
                    + spins[((r + 1) % n) * n + c] as i64
                    + spins[((r + n - 1) % n) * n + c] as i64;
                // ΔE for flipping spin idx: 2 s Σ_neighbors
                let delta_e = 2.0 * s as f64 * nb as f64;
                if delta_e <= 0.0 || rng.next_f64() < (-beta * delta_e).exp() {
                    spins[idx] = -spins[idx];
                }
            }
        }
        spins
    }

    /// Ground-truth stats from `samples` Metropolis chains.
    pub fn metropolis_stats(&self, samples: usize, sweeps: usize, rng: &mut Pcg64) -> IsingStats {
        let sites = self.num_sites();
        let mut e_sum = 0.0;
        let mut m_sum = 0.0;
        for _ in 0..samples {
            let s = self.metropolis_sample(sweeps, rng);
            e_sum += self.energy(&s) / sites as f64;
            m_sum += (self.magnetization(&s) / sites as f64).abs();
        }
        IsingStats {
            energy_per_site: e_sum / samples as f64,
            abs_magnetization: m_sum / samples as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_extremes() {
        let m = IsingModel::new(4, 3.0);
        // All-up: every bond aligned. 2 bonds per site → E = −2·N.
        let up = vec![1i8; 16];
        assert_eq!(m.energy(&up), -32.0);
        // Checkerboard on even lattice: every bond anti-aligned → E = +2·N.
        let mut cb = vec![0i8; 16];
        for r in 0..4 {
            for c in 0..4 {
                cb[r * 4 + c] = if (r + c) % 2 == 0 { 1 } else { -1 };
            }
        }
        assert_eq!(m.energy(&cb), 32.0);
    }

    #[test]
    fn magnetization_counts() {
        let m = IsingModel::new(2, 3.0);
        assert_eq!(m.magnetization(&[1, 1, -1, 1]), 2.0);
    }

    #[test]
    fn sign_conversion() {
        let spins = IsingModel::spins_from_continuous(&[-0.3, 0.0, 2.5, -7.0]);
        assert_eq!(spins, vec![-1, 1, 1, -1]);
    }

    #[test]
    fn high_temperature_disordered() {
        // At T=3.0 > T_c ≈ 2.269 the lattice is disordered: |M| small,
        // E/site modestly negative (≈ −0.55 for the infinite lattice).
        let m = IsingModel::new(8, 3.0);
        let mut rng = Pcg64::seed(1234);
        let stats = m.metropolis_stats(100, 200, &mut rng);
        // Finite-size 8×8 lattices keep a sizeable residual |M| (~0.3) even
        // in the disordered phase; the ordered-phase value is ~1.
        assert!(stats.abs_magnetization < 0.45, "|M| = {}", stats.abs_magnetization);
        assert!(
            (-0.9..=-0.3).contains(&stats.energy_per_site),
            "E/site = {}",
            stats.energy_per_site
        );
    }

    #[test]
    fn low_temperature_ordered() {
        // Far below T_c the chain should order: |M| near 1.
        let m = IsingModel::new(8, 0.5);
        let mut rng = Pcg64::seed(99);
        let stats = m.metropolis_stats(20, 400, &mut rng);
        assert!(stats.abs_magnetization > 0.8, "|M| = {}", stats.abs_magnetization);
        assert!(stats.energy_per_site < -1.7, "E/site = {}", stats.energy_per_site);
    }

    #[test]
    fn batch_stats() {
        let m = IsingModel::new(2, 3.0);
        // Two configs: all-up and all-down → both |M| = 1.
        let batch: Vec<f32> = vec![1.0, 1.0, 1.0, 1.0, -1.0, -1.0, -1.0, -1.0];
        let s = m.stats_from_continuous(&batch);
        assert!((s.abs_magnetization - 1.0).abs() < 1e-12);
        // 2x2 periodic: E = -2N = -8, per site = -2.
        assert!((s.energy_per_site - (-2.0)).abs() < 1e-12);
    }

    #[test]
    fn log_prob_monotone_in_energy() {
        let m = IsingModel::new(4, 3.0);
        let up = vec![1i8; 16];
        let mut one_flip = up.clone();
        one_flip[5] = -1;
        assert!(m.log_prob(&up) > m.log_prob(&one_flip));
    }
}
