//! **Table 1**: Sequential vs UJD vs SJD — generation time, speedup, and
//! quality (proxy-FID, CLIP-IQA proxy, BRISQUE) on the three datasets.
//!
//! Paper shape to reproduce: SJD fastest everywhere (up to 4.7×); UJD helps
//! on the small-L models but *loses* to sequential on the large-L AFHQ
//! stand-in; quality metrics statistically unchanged across methods.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;
use sjd::quality::evaluate_quality;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let quick = quick();
    let mut report = Report::new("Table 1 — Sequential vs UJD vs SJD (time + quality)");
    report.note(format!("quick mode: {quick}"));

    let mut rows = Vec::new();
    for model in ["tf10", "tf100", "tfafhq"] {
        if engine.manifest().model(model).is_err() {
            println!("skipping {model}: not in manifest");
            continue;
        }
        let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
        let sampler = Sampler::new(&engine, model, batch)?;
        // UJD on the large-L model runs its first block to the full L-cap
        // (it never converges there — that's the paper's point), costing
        // L × jstep per batch; keep the afhq sample count small.
        let n_images = match (model, quick) {
            ("tfafhq", true) => batch,
            ("tfafhq", false) => 16,
            (_, true) => batch,
            (_, false) => 128,
        };
        let reference = engine.manifest().load_dataset(dataset_for(model))?;
        let metric = metricnet_for(model);

        let mut seq_wall_per_batch = None;
        for policy in [
            DecodePolicy::Sequential,
            DecodePolicy::UniformJacobi,
            DecodePolicy::Selective { seq_blocks: 1 },
        ] {
            let label = policy.label();
            // Warmup: compile all artifacts before timing.
            let _ = generate(&sampler, policy.clone(), 0.5, batch, 7)?;
            let run = generate(&sampler, policy.clone(), 0.5, n_images, 42)?;
            let per_batch = run.wall / run.batches as f64;
            let speedup = match seq_wall_per_batch {
                None => {
                    seq_wall_per_batch = Some(per_batch);
                    1.0
                }
                Some(seq) => seq / per_batch,
            };
            let q = evaluate_quality(&engine, metric, &run.images, &reference)?;
            println!(
                "{model} {label:>10}: {per_batch:.3}s/batch ({speedup:.1}x) FID {:.2} IQA {:.3} BRISQUE {:.1}",
                q.fid, q.clip_iqa, q.brisque
            );
            rows.push(vec![
                paper_label(model).to_string(),
                label,
                format!("{per_batch:.3}"),
                format!("{speedup:.1}x"),
                format!("{:.2}", q.fid),
                format!("{:.3}", q.clip_iqa),
                format!("{:.1}", q.brisque),
            ]);
        }
    }
    report.table(
        &["Dataset", "Method", "Time/batch (s)", "Speedup", "FID*", "CLIP-IQA*", "BRISQUE*"],
        &rows,
    );
    report.note("(*) proxy metrics — see DESIGN.md §5 for the substitutions.");
    report.note("Paper shape: SJD fastest everywhere; UJD < Sequential on the large-L model only.");
    report.finish();
    Ok(())
}
