//! Stage-graph decode pipeline: inter-batch block overlap.
//!
//! SeJD's per-layer redundancy argument cuts the decode into `K`
//! independent **stages** — one flow block each, with disjoint artifacts —
//! yet the monolithic loop in `Sampler::decode_tokens` forces a serving
//! worker to run them strictly serially, one batch at a time. This module
//! restructures that loop into an explicit stage graph: a [`BlockStage`]
//! describes one stage's contract (decode position, flow block, policy
//! mode, output permutation), and a [`DecodePipeline`] walks batches
//! through the stages while keeping up to [`PipelineConfig::depth`] batches
//! in flight at *different* stages — batch B enters stage 0 while batch A
//! is in stage 1, because block `k` of A and block `k−1` of B touch
//! disjoint artifacts.
//!
//! ## Execution model
//!
//! The pipeline spawns [`PipelineConfig::stage_threads`] stage-executor
//! threads; each owns its **own backend** (device values are thread-pinned,
//! see the `runtime` docs) plus a per-bucket `SamplerSet`, and runs a
//! contiguous span of decode positions. Batches flow through bounded
//! per-stage queues (capacity 1 — a stage can hold at most one waiting
//! batch, so a slow stage backpressures its upstream immediately), and a
//! global depth gate bounds total in-flight batches, which bounds memory
//! and keeps tail latency honest.
//!
//! ## Device-value handoff
//!
//! *Within* a stage span, block outputs chain device→device exactly like
//! the monolithic loop — the span runs through `Sampler::decode_block_at`
//! over one backend, so nothing new crosses the host boundary. *Between*
//! stage threads the handoff must be host data (device handles are
//! `Rc`-pinned to the minting backend), so each span ends with one
//! documented forced sync. A single-threaded pipeline (`stage_threads = 1`)
//! therefore reproduces the monolithic residency map bit for bit: one
//! upload, K chained blocks, one final sync. With one thread per block the
//! per-stage sync cost is `K − 1` extra `[B, L, D]` round-trips per batch —
//! the price of overlap, paid only when overlap is enabled.
//!
//! Results are **bit-exact** with the monolithic path regardless of depth
//! or thread count: stages never share mutable state, every batch's prior
//! comes from its own seeded RNG stream, and host↔device crossings
//! preserve bits (`rust/tests/mock_backend.rs` pins the equivalence at
//! τ = 0; `benches/pipeline_overlap.rs` gates the throughput win in CI).
//!
//! ## Cross-stage z⁰ edge (speculative init under pipelining)
//!
//! Speculative init providers (`--init proj|warm|draft`, see
//! `coordinator::jacobi::InitStrategy`) add one more conceptual edge to the
//! stage graph: the z⁰ a block starts its fixed-point iteration from may
//! depend on state produced by an *earlier* stage. Device handles are
//! thread-pinned, so that state cannot ride the stage queue as a device
//! value — and syncing a speculative guess to host would break the
//! device-residency rule (speculation must never add host crossings). The
//! edge is therefore **receiver-materialized**:
//!
//! * **`proj`** — the projection input is exactly the handed-off tokens the
//!   receiving span uploads anyway, so the receiving stage re-derives z⁰ on
//!   its *own* backend (`Sampler::decode_block_at` resolves the provider
//!   per block). The edge carries the recipe, not the value: one upload
//!   (already paid by the handoff contract), zero extra syncs.
//! * **`warm`** — converged latents are keyed `(seed, position)` and decode
//!   positions are pinned to stages, so each stage thread's own
//!   `BufferPool` warm cache serves repeat-seed traffic for its span
//!   without anything crossing the edge. [`PipelineConfig::warm_cap`]
//!   bounds each stage's cache.
//! * **`draft`** — needs a full-sequence monolithic pass before refinement,
//!   which no single stage span can run; [`DecodePipeline::submit`] demotes
//!   it to `zeros` explicitly (documented, not silent) rather than letting
//!   the per-block resolver quietly ignore it.
//!
//! ## Metrics
//!
//! Per stage thread `t`: `sjd_stage_{t}_occupancy` (gauge, batches being
//! processed — 0/1 per pipeline, and its time-average is the stage's
//! utilization) and the shared `sjd_stage_wait` histogram (time a batch
//! sat in a stage queue before the stage picked it up — non-zero waits
//! mean the pipeline is genuinely overlapping). When several pipelines
//! share one registry (`serve --workers N --pipeline-depth ≥2` runs one
//! pipeline per worker), both metrics aggregate across them: stage `t`'s
//! occupancy reads `0..=N` and `sjd_stage_wait` pools every worker's
//! queue waits.

use super::jacobi::InitStrategy;
use super::policy::{BlockDecode, DecodePolicy};
use super::sampler::{BlockTrace, SampleOptions, SampleOutput, SamplerSet};
use crate::metrics::Registry;
use crate::runtime::{Backend, HostTensor, Value};
use crate::tensor::{Pcg64, Tensor};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One stage of the decode stage graph: a single flow block with its decode
/// mode and in/out contract. Purely descriptive — execution is
/// `Sampler::decode_block_at` — used by `sjd policy show`, the `/policy`
/// endpoint and pipeline observability.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockStage {
    /// Decode position (0 = first block applied to noise).
    pub position: usize,
    /// Flow-order block index `k = K − 1 − position` — the index the
    /// stage's artifacts are keyed by.
    pub block: usize,
    /// Policy decode mode (before the sampler's per-bucket artifact
    /// degradation chain).
    pub mode: BlockDecode,
    /// Whether the stage output is token-reversed (`P_k`, odd `k`) before
    /// handoff to the next stage.
    pub reversed: bool,
}

/// The stage graph a policy induces over a `K`-block flow, in decode order.
pub fn stage_plan(policy: &DecodePolicy, blocks: usize) -> Vec<BlockStage> {
    (0..blocks)
        .map(|pos| {
            let block = blocks - 1 - pos;
            BlockStage {
                position: pos,
                block,
                mode: policy.block_mode(pos, blocks),
                reversed: block % 2 == 1,
            }
        })
        .collect()
}

/// Pipeline shape knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Maximum batches in flight across the whole pipeline (≥ 1). Depth 1
    /// is the monolithic serial decode expressed through the pipeline;
    /// depth ≥ 2 enables inter-batch block overlap.
    pub depth: usize,
    /// Stage-executor threads, each owning a backend and a contiguous span
    /// of decode positions; clamped to `[1, K]`, and `0` means one thread
    /// per block (maximum overlap).
    pub stage_threads: usize,
    /// Warm-start cache bound applied to every stage sampler's buffer pool
    /// (`--init warm:N`); `0` keeps the pool's built-in default. Each stage
    /// thread owns its own cache, so the effective pipeline-wide bound is
    /// `stage_threads × warm_cap` entries.
    pub warm_cap: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { depth: 2, stage_threads: 0, warm_cap: 0 }
    }
}

/// What a completed batch resolves to: the per-sample images plus the same
/// [`SampleOutput`] a monolithic `sample_images` returns, or the decode
/// error message (`String`, like `batcher::SlotResult`, so every slot of a
/// failed batch can carry its own copy).
pub type PipelineResult = std::result::Result<(Vec<Tensor>, SampleOutput), String>;

/// Completion callback of one submitted batch.
pub type DoneFn = Box<dyn FnOnce(PipelineResult) + Send + 'static>;

/// One batch submitted to the pipeline.
pub struct PipelineJob {
    /// Seed of the batch RNG stream (`Pcg64::seed_stream(seed, 1)`, the
    /// router's fixed-stream convention) — stage 0 draws the prior from it.
    pub seed: u64,
    /// Real slots in the batch; stages route it to the smallest covering
    /// bucket exactly like a monolithic worker.
    pub n: usize,
    pub opts: SampleOptions,
    /// Completion callback, invoked on the final stage's thread (keep it
    /// light — it runs on the decode path).
    pub done: DoneFn,
}

/// A batch moving through the stage graph.
struct InFlight {
    seed: u64,
    n: usize,
    opts: SampleOptions,
    done: DoneFn,
    /// Host tokens between stage spans (`None` until stage 0 draws the
    /// prior). Cross-thread handoff is host data by contract.
    tokens: Option<HostTensor>,
    traces: Vec<BlockTrace>,
    decode_wall: Duration,
    /// Time spent waiting in stage queues *after* stage 0 started — the
    /// depth-≥2 interleaving cost, kept out of `other_wall` so that field
    /// retains its documented meaning.
    queued: Duration,
    /// When stage 0 started processing (anchor of `total_wall`).
    started: Option<Instant>,
    /// When the batch entered its current queue (stage-wait accounting).
    enqueued: Instant,
}

/// Bounded channel with blocking send — the per-stage queue + backpressure
/// primitive.
struct StageQueue<T> {
    inner: Mutex<StageQueueInner<T>>,
    cv: Condvar,
    cap: usize,
}

struct StageQueueInner<T> {
    q: VecDeque<T>,
    closed: bool,
}

impl<T> StageQueue<T> {
    fn new(cap: usize) -> Arc<Self> {
        Arc::new(StageQueue {
            inner: Mutex::new(StageQueueInner { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
        })
    }

    /// Blocking send; a closed queue hands the item back so the caller can
    /// complete it with an error instead of silently dropping it.
    fn send(&self, item: T) -> std::result::Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed {
            g = self.cv.wait(g).unwrap();
        }
        if g.closed {
            return Err(item);
        }
        g.q.push_back(item);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking receive; `None` once closed and drained.
    fn recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.cv.notify_all();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Counting gate bounding total in-flight batches (acquired on submit,
/// released at completion).
struct DepthGate {
    count: Mutex<usize>,
    cv: Condvar,
    depth: usize,
}

impl DepthGate {
    fn new(depth: usize) -> Arc<Self> {
        Arc::new(DepthGate { count: Mutex::new(0), cv: Condvar::new(), depth: depth.max(1) })
    }

    fn acquire(&self) {
        let mut c = self.count.lock().unwrap();
        while *c >= self.depth {
            c = self.cv.wait(c).unwrap();
        }
        *c += 1;
    }

    fn release(&self) {
        let mut c = self.count.lock().unwrap();
        *c -= 1;
        self.cv.notify_all();
    }

    fn current(&self) -> usize {
        *self.count.lock().unwrap()
    }
}

/// Running stage-graph pipeline (see the module docs).
pub struct DecodePipeline {
    entry: Arc<StageQueue<InFlight>>,
    gate: Arc<DepthGate>,
    threads: Vec<JoinHandle<()>>,
    /// Bucket sizes the stage samplers serve, ascending.
    pub buckets: Vec<usize>,
    /// Flow blocks `K` (= number of stages in the graph).
    pub blocks: usize,
}

/// Everything one stage-executor thread needs besides its backend factory.
struct StageArgs {
    idx: usize,
    /// Decode positions `[lo, hi)` this stage runs.
    span: (usize, usize),
    model: String,
    buckets: Vec<usize>,
    rx: Arc<StageQueue<InFlight>>,
    tx: Option<Arc<StageQueue<InFlight>>>,
    gate: Arc<DepthGate>,
    registry: Registry,
    /// Warm-start cache bound for this stage's samplers (0 = default).
    warm_cap: usize,
    ready: std::sync::mpsc::Sender<Result<Vec<usize>>>,
}

impl DecodePipeline {
    /// Spawn the stage-executor threads. `factory` runs inside each stage
    /// thread (backends may be thread-pinned) and is also invoked once on
    /// the calling thread to discover the model geometry; like
    /// `Router::start_with`, every stage validates its backend + samplers
    /// before this returns (fail-fast on bad artifacts).
    pub fn start<B, F>(
        model: &str,
        buckets: &[usize],
        cfg: PipelineConfig,
        registry: Registry,
        factory: F,
    ) -> Result<Self>
    where
        B: Backend,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        // Geometry probe, dropped immediately — stage threads build their
        // own thread-pinned backends. The spans and queues must be sized
        // before any stage thread exists, so K cannot ride the readiness
        // channel; the extra backend is cheap because `Engine` construction
        // only parses the manifest (artifact compilation is lazy, and the
        // probe never calls anything).
        let blocks = factory(0)?.model_meta(model)?.blocks;
        let n_threads = if cfg.stage_threads == 0 {
            blocks
        } else {
            cfg.stage_threads.clamp(1, blocks)
        };
        // Contiguous, as-even-as-possible spans of decode positions — the
        // same partition law the GS windows use.
        let spans: Vec<(usize, usize)> = super::jacobi::window_partition(blocks, n_threads)
            .into_iter()
            .map(|(off, len)| (off, off + len))
            .collect();
        let queues: Vec<Arc<StageQueue<InFlight>>> =
            spans.iter().map(|_| StageQueue::new(1)).collect();
        let gate = DepthGate::new(cfg.depth);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<Vec<usize>>>();

        let mut threads = Vec::with_capacity(spans.len());
        for (idx, &span) in spans.iter().enumerate() {
            let args = StageArgs {
                idx,
                span,
                model: model.to_string(),
                buckets: buckets.to_vec(),
                rx: queues[idx].clone(),
                tx: queues.get(idx + 1).cloned(),
                gate: gate.clone(),
                registry: registry.clone(),
                warm_cap: cfg.warm_cap,
                ready: ready_tx.clone(),
            };
            let factory = factory.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("sjd-stage-{idx}"))
                    .spawn(move || stage_main(args, factory))
                    .expect("spawn stage thread"),
            );
        }
        drop(ready_tx);
        // Collect every stage's readiness before returning: on any failure,
        // close the queues and join the healthy stages too, so a failed
        // startup never leaves threads (each pinning a backend) blocked on
        // queues nobody will feed.
        let mut bucket_set = Vec::new();
        let mut startup_err = None;
        for _ in &spans {
            match ready_rx.recv().expect("stage startup signal") {
                Ok(buckets) => bucket_set = buckets,
                Err(e) => startup_err = Some(e),
            }
        }
        if let Some(e) = startup_err {
            for q in &queues {
                q.close();
            }
            for t in threads.drain(..) {
                let _ = t.join();
            }
            return Err(e);
        }
        Ok(DecodePipeline { entry: queues[0].clone(), gate, threads, buckets: bucket_set, blocks })
    }

    /// Submit a batch, blocking while [`PipelineConfig::depth`] batches are
    /// already in flight (backpressure toward the batcher queue). A
    /// shut-down pipeline hands the job back so the caller can complete its
    /// slots.
    pub fn submit(&self, job: PipelineJob) -> std::result::Result<(), PipelineJob> {
        self.gate.acquire();
        // Draft-then-refine needs a full-sequence pass before refinement —
        // no single stage span can run it (see "Cross-stage z⁰ edge" in the
        // module docs). Demote to zeros here, explicitly, so traces report
        // what actually ran instead of the per-block resolver quietly
        // ignoring the strategy.
        let mut opts = job.opts;
        if opts.jacobi.init == InitStrategy::Draft {
            opts.jacobi.init = InitStrategy::Zeros;
        }
        let item = InFlight {
            seed: job.seed,
            n: job.n,
            opts,
            done: job.done,
            tokens: None,
            traces: Vec::new(),
            decode_wall: Duration::ZERO,
            queued: Duration::ZERO,
            started: None,
            enqueued: Instant::now(),
        };
        match self.entry.send(item) {
            Ok(()) => Ok(()),
            Err(item) => {
                self.gate.release();
                Err(PipelineJob { seed: item.seed, n: item.n, opts: item.opts, done: item.done })
            }
        }
    }

    /// Batches currently in flight (submitted, not yet completed).
    pub fn in_flight(&self) -> usize {
        self.gate.current()
    }

    /// Close the entry queue, drain every in-flight batch to completion,
    /// and join the stage threads.
    pub fn shutdown(mut self) {
        self.entry.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One stage-executor thread: own backend + samplers, a contiguous span of
/// decode positions, and the stage queue protocol.
fn stage_main<B, F>(args: StageArgs, factory: F)
where
    B: Backend,
    F: Fn(usize) -> Result<B>,
{
    let StageArgs { idx, span, model, buckets, rx, tx, gate, registry, warm_cap, ready } = args;
    let engine = match factory(idx) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let set = match SamplerSet::new(&engine, &model, &buckets) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    set.set_warm_cap(warm_cap);
    let _ = ready.send(Ok(set.buckets()));

    let occupancy = registry.gauge(&format!("sjd_stage_{idx}_occupancy"));
    let stage_wait = registry.histogram("sjd_stage_wait");

    while let Some(mut item) = rx.recv() {
        let waited = item.enqueued.elapsed();
        stage_wait.record_duration(waited);
        // Waits before stage 0 are ordinary queueing (not yet started);
        // waits between stages are the pipelining cost `finish` subtracts.
        if item.started.is_some() {
            item.queued += waited;
        }
        occupancy.add(1);
        let outcome = run_span(&set, span, &mut item);
        occupancy.add(-1);
        match outcome {
            Err(msg) => {
                // Fail the batch here; downstream stages never see it.
                (item.done)(Err(msg));
                gate.release();
            }
            Ok(()) => match &tx {
                Some(tx) => {
                    item.enqueued = Instant::now();
                    if let Err(item) = tx.send(item) {
                        // Downstream closed mid-shutdown: complete the batch
                        // so its slots cannot hang, and free its slot.
                        (item.done)(Err("pipeline shut down mid-decode".into()));
                        gate.release();
                    }
                }
                None => finish(&set, item, &gate),
            },
        }
    }
    // Cascade the close downstream so later stages drain and exit too.
    if let Some(tx) = &tx {
        tx.close();
    }
}

/// Run one span of decode positions over one batch. Stage 0 draws the
/// prior from the job's seeded stream; every span chains device-resident
/// values internally and syncs to host once at its end (the cross-thread
/// handoff contract).
fn run_span<B: Backend>(
    set: &SamplerSet<'_, B>,
    (lo, hi): (usize, usize),
    item: &mut InFlight,
) -> std::result::Result<(), String> {
    let sampler = set.select(item.n);
    if lo == 0 {
        item.started = Some(Instant::now());
        let mut rng = Pcg64::seed_stream(item.seed, 1);
        item.tokens = Some(sampler.sample_prior(&mut rng));
    }
    let mut z = Value::Host(item.tokens.take().expect("pipeline handoff carries tokens"));
    for pos in lo..hi {
        let (z_next, trace) = sampler
            .decode_block_at(pos, &z, &item.opts)
            .map_err(|e| format!("decode failed at position {pos}: {e:#}"))?;
        item.decode_wall += trace.wall;
        item.traces.push(trace);
        z = z_next;
    }
    let host = sampler
        .engine()
        .to_host(z)
        .map_err(|e| format!("stage handoff sync failed: {e:#}"))?;
    item.tokens = Some(host);
    Ok(())
}

/// Final-stage completion: assemble the [`SampleOutput`], unpatchify, and
/// resolve the job.
///
/// `total_wall` is the true in-pipeline latency (stage-0 start →
/// completion, inter-stage queue waits included — what the overlap bench's
/// p99 gate measures); `other_wall` excludes those waits so it keeps its
/// documented meaning (prior draw, permutations, handoff syncs).
fn finish<B: Backend>(set: &SamplerSet<'_, B>, mut item: InFlight, gate: &Arc<DepthGate>) {
    let sampler = set.select(item.n);
    let tokens = item.tokens.take().expect("completed batch has tokens");
    let total_wall = item.started.map(|s| s.elapsed()).unwrap_or_default();
    let busy = total_wall.saturating_sub(item.queued);
    let out = SampleOutput {
        tokens,
        traces: std::mem::take(&mut item.traces),
        total_wall,
        other_wall: busy.saturating_sub(item.decode_wall),
    };
    let done = item.done;
    match sampler.unpatchify(&out.tokens) {
        Ok(images) => done(Ok((images, out))),
        Err(e) => done(Err(format!("unpatchify failed: {e:#}"))),
    }
    gate.release();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_plan_maps_positions_modes_and_permutations() {
        let plan = stage_plan(&DecodePolicy::Selective { seq_blocks: 1 }, 4);
        assert_eq!(plan.len(), 4);
        // Position 0 decodes block K-1 = 3 (odd ⇒ reversed output).
        assert_eq!(plan[0].position, 0);
        assert_eq!(plan[0].block, 3);
        assert_eq!(plan[0].mode, BlockDecode::Sequential);
        assert!(plan[0].reversed);
        assert_eq!(plan[1].block, 2);
        assert_eq!(plan[1].mode, BlockDecode::Jacobi);
        assert!(!plan[1].reversed);
        assert_eq!(plan[3].position, 3);
        assert_eq!(plan[3].block, 0);
        assert!(!plan[3].reversed);
    }

    #[test]
    fn stage_queue_bounds_and_closes() {
        let q: Arc<StageQueue<u32>> = StageQueue::new(1);
        assert!(q.send(1).is_ok());
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.send(2));
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "send past capacity must block");
        assert_eq!(q.recv(), Some(1));
        assert!(t.join().unwrap().is_ok());
        assert_eq!(q.recv(), Some(2));
        q.close();
        // A closed queue hands the item back instead of dropping it.
        assert_eq!(q.send(3).unwrap_err(), 3);
        assert_eq!(q.recv(), None);
    }

    #[test]
    fn depth_gate_blocks_at_depth() {
        let g = DepthGate::new(2);
        g.acquire();
        g.acquire();
        assert_eq!(g.current(), 2);
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            g2.acquire();
            g2.release();
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "third acquire must block at depth 2");
        g.release();
        t.join().unwrap();
        g.release();
        assert_eq!(g.current(), 0);
    }
}
