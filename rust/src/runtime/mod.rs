//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! The python build path (`make artifacts`) lowers every JAX/Pallas program to
//! **HLO text** (see DESIGN.md §2 — text, not serialized protos, because the
//! xla_extension 0.5.1 proto parser rejects jax ≥ 0.5's 64-bit instruction
//! ids) and records each program's signature in `artifacts/manifest.json`.
//!
//! [`Engine`] owns one `PjRtClient` plus a lazy compile cache keyed by
//! artifact name; [`HostTensor`] is the host-side value type that crosses the
//! boundary.

mod engine;
mod host;
mod manifest;

pub use engine::{BufferArg, CallStats, Engine};
pub use host::HostTensor;
pub use manifest::{ArtifactMeta, DatasetMeta, Manifest, ModelMeta, TensorSpec};

/// Execution backend abstraction: the real PJRT [`Engine`] in production,
/// mock backends in coordinator unit tests (`rust/tests/mock_backend.rs`).
pub trait Backend {
    /// Execute an artifact by name.
    fn call(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>>;

    /// Model metadata lookup.
    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta>;
}

impl Backend for Engine {
    fn call(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        Engine::call(self, name, inputs)
    }

    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta> {
        self.manifest().model(model).cloned()
    }
}
