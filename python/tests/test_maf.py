"""MAF invariants: MADE mask autoregressivity, invertibility, finite Jacobi
convergence, training sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import maf


@pytest.fixture(scope="module")
def small():
    cfg = maf.MafConfig(name="m", dim=12, layers=4, hidden=32,
                        dataset="ising", train_steps=1, train_batch=8, lr=1e-3)
    params = maf.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(9)
    params["w3s"] = 0.2 * jax.random.normal(key, params["w3s"].shape)
    params["w3g"] = 0.2 * jax.random.normal(jax.random.fold_in(key, 1), params["w3g"].shape)
    return cfg, params


class TestMadeMasks:
    def test_strict_autoregressivity(self, small):
        """Output dim l of the MADE net must not depend on inputs >= l."""
        cfg, params = small
        lp = maf.layer_params(params, 0)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.dim))

        def s_of(x):
            s, g = maf.made_net(lp, cfg, x)
            return jnp.concatenate([s, g], axis=-1)

        jac = jax.jacfwd(lambda xf: s_of(xf[None, :])[0])(x[0])  # (2d, d)
        d = cfg.dim
        for l in range(d):
            # s_l and g_l depend only on x_{<l}.
            assert np.abs(np.asarray(jac)[l, l:]).max() < 1e-8, f"s_{l} leaks"
            assert np.abs(np.asarray(jac)[d + l, l:]).max() < 1e-8, f"g_{l} leaks"

    def test_dim0_identity(self, small):
        cfg, params = small
        x = jax.random.normal(jax.random.PRNGKey(2), (4, cfg.dim))
        v, _ = maf.layer_forward(params, cfg, 0, x)
        np.testing.assert_allclose(np.asarray(v)[:, 0], np.asarray(x)[:, 0], atol=1e-6)

    def test_mask_shapes_and_degrees(self):
        m1, m2, m3 = maf.made_masks(6, 16)
        assert m1.shape == (6, 16) and m2.shape == (16, 16) and m3.shape == (16, 6)
        # Output 0 (degree 1) must see no hidden units.
        assert float(m3[:, 0].sum()) == 0.0
        # Output d-1 sees at least one hidden unit.
        assert float(m3[:, 5].sum()) > 0


class TestInvertibility:
    def test_layer_roundtrip(self, small):
        cfg, params = small
        x = jax.random.normal(jax.random.PRNGKey(3), (5, cfg.dim))
        for k in range(cfg.layers):
            v, _ = maf.layer_forward(params, cfg, k, x)
            x_rec = maf.layer_inverse_exact(params, cfg, k, v)
            np.testing.assert_allclose(np.asarray(x_rec), np.asarray(x), atol=1e-4)

    def test_full_flow_roundtrip(self, small):
        cfg, params = small
        x = jax.random.normal(jax.random.PRNGKey(4), (3, cfg.dim))
        z, _ = maf.flow_forward(params, cfg, x)
        h = z
        for k in reversed(range(cfg.layers)):
            u = maf.layer_inverse_exact(params, cfg, k, h)
            h = u[:, ::-1] if k % 2 == 1 else u
        np.testing.assert_allclose(np.asarray(h), np.asarray(x), atol=1e-3)

    def test_logdet_matches_autodiff(self, small):
        cfg, params = small
        x = jax.random.normal(jax.random.PRNGKey(5), (1, cfg.dim))
        jac = jax.jacfwd(lambda xf: maf.flow_forward(params, cfg, xf[None, :])[0][0])(x[0])
        _, logdet_num = np.linalg.slogdet(np.asarray(jac))
        _, ld = maf.flow_forward(params, cfg, x)
        assert abs(float(ld[0]) - logdet_num) < 1e-3


class TestJacobi:
    def test_finite_convergence(self, small):
        cfg, params = small
        x = jax.random.normal(jax.random.PRNGKey(6), (2, cfg.dim))
        v, _ = maf.layer_forward(params, cfg, 1, x)
        z = jnp.zeros_like(v)
        for _ in range(cfg.dim):
            z, _ = maf.layer_jacobi_step(params, cfg, 1, z, v)
        np.testing.assert_allclose(np.asarray(z), np.asarray(x), atol=1e-4)

    def test_early_convergence_on_weak_coupling(self, small):
        """With small (s, g) weights the fixed point is reached in far fewer
        than d iterations — the redundancy the paper exploits."""
        cfg, params = small
        weak = dict(params)
        weak["w3s"] = params["w3s"] * 0.05
        weak["w3g"] = params["w3g"] * 0.05
        x = jax.random.normal(jax.random.PRNGKey(7), (2, cfg.dim))
        v, _ = maf.layer_forward(weak, cfg, 0, x)
        z = jnp.zeros_like(v)
        iters = 0
        for _ in range(cfg.dim):
            z, r = maf.layer_jacobi_step(weak, cfg, 0, z, v)
            iters += 1
            if float(r.max()) < 1e-4:
                break
        assert iters < cfg.dim // 2, f"took {iters} iterations"


class TestTraining:
    def test_ising_mle_improves(self):
        from compile import train as train_mod
        cfg = maf.MafConfig(name="m2", dim=16, layers=2, hidden=32,
                            dataset="ising", train_steps=60, train_batch=64, lr=2e-3)
        # dim 16 → 4×4 lattice.
        log = []
        train_mod.train_maf(cfg, loss_log=log, log_every=1000)
        assert log[-1][1] < log[0][1]
