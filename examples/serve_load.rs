//! End-to-end serving driver (the repo's E2E validation, EXPERIMENTS.md §E2E):
//! starts the full stack — router workers (each with its own PJRT engine),
//! dynamic batcher, HTTP server — then runs a Poisson-arrival load generator
//! over real HTTP and reports latency percentiles + throughput for the
//! sequential baseline vs SJD.
//!
//! ```bash
//! cargo run --release --example serve_load [artifacts] [n_requests]
//! ```

use anyhow::{Context, Result};
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::SampleOptions;
use sjd::coordinator::server::Server;
use sjd::exec::ThreadPool;
use sjd::metrics::Registry;
use sjd::tensor::Pcg64;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn http_post(addr: &str, path: &str, body: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(300)))?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: sjd\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    Ok(resp)
}

struct RunStats {
    latencies_ms: Vec<f64>,
    wall: Duration,
    ok: u64,
}

fn run_load(addr: &str, n_requests: usize, rps: f64, label: &str) -> Result<RunStats> {
    let pool = ThreadPool::new(8);
    let lat = Arc::new(Mutex::new(Vec::new()));
    let ok = Arc::new(AtomicU64::new(0));
    let mut rng = Pcg64::seed(999);
    let t0 = Instant::now();
    for i in 0..n_requests {
        // Poisson arrivals.
        let gap = rng.next_exp() / rps;
        std::thread::sleep(Duration::from_secs_f64(gap));
        let addr = addr.to_string();
        let lat = lat.clone();
        let ok = ok.clone();
        pool.spawn(move || {
            let t = Instant::now();
            let body = format!("{{\"n\": 1, \"seed\": {i}}}");
            if let Ok(resp) = http_post(&addr, "/generate", &body) {
                if resp.starts_with("HTTP/1.1 200") {
                    ok.fetch_add(1, Ordering::SeqCst);
                }
            }
            lat.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
        });
    }
    pool.wait_idle();
    let wall = t0.elapsed();
    let mut latencies = lat.lock().unwrap().clone();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "[{label}] {} ok / {} reqs in {:.1}s ({:.2} img/s) | latency ms p50 {:.0} p95 {:.0} p99 {:.0}",
        ok.load(Ordering::SeqCst),
        n_requests,
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64(),
        pct(&latencies, 0.50),
        pct(&latencies, 0.95),
        pct(&latencies, 0.99),
    );
    Ok(RunStats { latencies_ms: latencies, wall, ok: ok.load(Ordering::SeqCst) })
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 - 1.0) * q) as usize]
}

fn serve_and_measure(
    artifacts: &str,
    policy: DecodePolicy,
    addr: &str,
    n_requests: usize,
) -> Result<RunStats> {
    let label = policy.label();
    let registry = Registry::new();
    let batcher = Batcher::new(8, Duration::from_millis(30));
    let router = Router::start(
        RouterConfig {
            artifacts_dir: artifacts.into(),
            model: "tf10".into(),
            // Every lowered bucket: n=1 requests ride the b1 artifacts
            // instead of being padded to the full batch.
            buckets: Vec::new(),
            workers: 2,
            options: SampleOptions { policy, ..Default::default() },
            pipeline_depth: 1,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
    )?;
    let server = Server::new(addr, batcher.clone(), registry.clone());
    let stop = server.stop_flag();
    let addr_owned = addr.to_string();
    let server_thread = std::thread::spawn(move || server.run());

    // Wait for the listener.
    for _ in 0..100 {
        if TcpStream::connect(&addr_owned).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    // Health check.
    let health = http_post(addr, "/healthz", "")?;
    anyhow::ensure!(!health.is_empty(), "no health response");

    let stats = run_load(addr, n_requests, 4.0, &label)?;

    // Print server-side metrics.
    let metrics = registry.render_text();
    for line in metrics.lines() {
        if line.starts_with("sjd_images_generated")
            || line.starts_with("sjd_batch_fill")
            || line.starts_with("sjd_padded_slots")
            || line.starts_with("sjd_bucket_")
        {
            println!("  {line}");
        }
    }

    // Shut down: set stop flag and poke the listener.
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    let _ = server_thread.join();
    router.shutdown();
    Ok(stats)
}

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse().context("bad n_requests"))
        .transpose()?
        .unwrap_or(32);

    println!("=== end-to-end serving: sequential baseline ===");
    let seq = serve_and_measure(&artifacts, DecodePolicy::Sequential, "127.0.0.1:8473", n_requests)?;

    println!("\n=== end-to-end serving: SJD ===");
    let sjd = serve_and_measure(
        &artifacts,
        DecodePolicy::Selective { seq_blocks: 1 },
        "127.0.0.1:8474",
        n_requests,
    )?;

    println!("\n=== summary ===");
    println!(
        "throughput: seq {:.2} img/s → SJD {:.2} img/s ({:.1}x)",
        seq.ok as f64 / seq.wall.as_secs_f64(),
        sjd.ok as f64 / sjd.wall.as_secs_f64(),
        (sjd.ok as f64 / sjd.wall.as_secs_f64()) / (seq.ok as f64 / seq.wall.as_secs_f64()),
    );
    println!(
        "p50 latency: seq {:.0} ms → SJD {:.0} ms",
        pct(&seq.latencies_ms, 0.5),
        pct(&sjd.latencies_ms, 0.5)
    );
    Ok(())
}
