//! **§D (memory)**: working-set comparison of sequential-with-KV-cache vs
//! Jacobi decoding — analytical estimates from the model geometry plus the
//! measured buffer-pool high-water mark of an actual sequential decode.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;
use sjd::coordinator::state::estimate_memory;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let mut report = Report::new("§D — memory: sequential KV cache vs Jacobi iterate");
    let mut rows = Vec::new();

    for model in ["tf10", "tf100", "tfafhq"] {
        let Ok(meta) = engine.manifest().model(model) else { continue };
        let b = *meta.batch_sizes.iter().max().unwrap();
        let est = estimate_memory(meta.layers_per_block, b, meta.seq_len, meta.model_dim, meta.token_dim);
        // Measured: run one sequential batch and read the pool's peak.
        let sampler = Sampler::new(&engine, model, b)?;
        let _ = generate(&sampler, DecodePolicy::Sequential, 0.5, b, 1)?;
        println!(
            "{model}: seq KV {} KB vs jacobi iterate {} KB (est)",
            est.sequential_kv_bytes / 1024,
            est.jacobi_iterate_bytes / 1024
        );
        rows.push(vec![
            paper_label(model).to_string(),
            format!("{}", est.sequential_kv_bytes / 1024),
            format!("{}", est.jacobi_iterate_bytes / 1024),
            format!("{:.1}x", est.sequential_kv_bytes as f64 / est.jacobi_iterate_bytes as f64),
        ]);
    }

    report.table(
        &["Dataset", "Sequential KV (KB)", "Jacobi iterate (KB)", "Ratio"],
        &rows,
    );
    report.note("Paper §D: SJD used 5.2 GB vs 7.8 GB for the KV-cache baseline on AFHQ — same direction here.");
    report.finish();
    Ok(())
}
