//! The full sampling pipeline: prior noise → per-block decode (sequential or
//! Jacobi per the policy) → unpatchify → images.
//!
//! ## Artifact ABI (must match `python/compile/aot.py`)
//!
//! All per-block artifacts operate in **AR domain** — the token order the
//! block's causal transformer sees. The flow composition
//! `h_{k+1} = A_k(P_k h_k)` (encode) / `h_k = P_k(A_k^{-1}(h_{k+1}))`
//! (decode) applies the inter-block permutation `P_k` (token reversal for
//! odd `k`) **in rust**, keeping the artifacts uniform:
//!
//! * `{m}_block_fwd_b{B}`   : `(k, u[B,L,D]) → v[B,L,D]` — `v = A_k(u)`
//! * `{m}_block_jstep_b{B}` : `(k, z_t[B,L,D], y[B,L,D], o) → (z', resid[B])`
//!   — one parallel Jacobi update of `A_k(z) = y`, with the `o`-nearest
//!   dependency mask of eq 6 (`o = 0` ⇒ exact update).
//! * `{m}_block_seqstep_b{B}`: `(k, u_prev[B,D], v_tok[B,D], pos,
//!   kv_k[NL,B,L,Dm], kv_v[NL,B,L,Dm]) → (u_pos[B,D], kv_k', kv_v')`
//!   — one sequential token with KV cache.
//! * `{m}_fwd_b{B}`         : `(x[B,H,W,C]) → (z[B,L,D], logdet[B])` —
//!   full encode (python applies its own permutations; cross-checked against
//!   the rust composition in integration tests).

use super::jacobi::{jacobi_decode_block, JacobiConfig, JacobiStats};
use super::policy::DecodePolicy;
use super::state::BufferPool;
use crate::runtime::{Backend, HostTensor, ModelMeta};
use crate::tensor::{Pcg64, Tensor};
use anyhow::{bail, Context, Result};
use std::time::{Duration, Instant};

/// Options for one sampling run.
#[derive(Clone, Debug)]
pub struct SampleOptions {
    pub policy: DecodePolicy,
    pub jacobi: JacobiConfig,
    /// eq-6 dependency mask offset applied to Jacobi blocks (0 = exact).
    pub mask_o: usize,
    /// Use the scan-fused sequential artifact (`block_seqfull`) instead of
    /// per-token `block_seqstep` calls — the §Perf "XLA-fused sequential"
    /// ablation, a stronger-than-paper baseline.
    pub fused_sequential: bool,
    pub seed: u64,
}

impl Default for SampleOptions {
    fn default() -> Self {
        SampleOptions {
            policy: DecodePolicy::Selective { seq_blocks: 1 },
            jacobi: JacobiConfig::default(),
            mask_o: 0,
            fused_sequential: false,
            seed: 0,
        }
    }
}

/// Per-block trace of one sampling run.
#[derive(Clone, Debug)]
pub struct BlockTrace {
    /// Block index `k` (flow order).
    pub block: usize,
    /// Decode position (0 = first block applied to noise).
    pub position: usize,
    pub used_jacobi: bool,
    /// Sequential steps or Jacobi iterations.
    pub steps: usize,
    pub wall: Duration,
    pub jacobi: Option<JacobiStats>,
}

/// Result of one sampling run.
#[derive(Clone, Debug)]
pub struct SampleOutput {
    /// Final tokens (B, L, D) in flow domain (h_0).
    pub tokens: HostTensor,
    pub traces: Vec<BlockTrace>,
    pub total_wall: Duration,
    /// Wall time outside block decodes (noise gen, permutation, unpatchify) —
    /// the paper's Table A4 "Other" row.
    pub other_wall: Duration,
}

impl SampleOutput {
    pub fn total_jacobi_iters(&self) -> usize {
        self.traces.iter().filter(|t| t.used_jacobi).map(|t| t.steps).sum()
    }
}

/// Model sampler bound to an execution backend + a lowered batch size.
pub struct Sampler<'e, B: Backend> {
    engine: &'e B,
    pub meta: ModelMeta,
    pub batch: usize,
    art_fwd: String,
    art_block_fwd: String,
    art_jstep: String,
    art_seqstep: String,
    art_seqfull: String,
    pool: BufferPool,
}

impl<'e, B: Backend> Sampler<'e, B> {
    pub fn new(engine: &'e B, model: &str, batch: usize) -> Result<Self> {
        let meta = engine.model_meta(model)?;
        if !meta.batch_sizes.contains(&batch) {
            bail!(
                "model '{model}' has no artifacts for batch {batch} (available: {:?})",
                meta.batch_sizes
            );
        }
        Ok(Sampler {
            engine,
            meta,
            batch,
            art_fwd: format!("{model}_fwd_b{batch}"),
            art_block_fwd: format!("{model}_block_fwd_b{batch}"),
            art_jstep: format!("{model}_block_jstep_b{batch}"),
            art_seqstep: format!("{model}_block_seqstep_b{batch}"),
            art_seqfull: format!("{model}_block_seqfull_b{batch}"),
            pool: BufferPool::new(),
        })
    }

    pub fn engine(&self) -> &B {
        self.engine
    }

    pub fn jstep_artifact(&self) -> &str {
        &self.art_jstep
    }

    /// Draw the prior `z_K ~ N(0, I)` in token space.
    pub fn sample_prior(&self, rng: &mut Pcg64) -> HostTensor {
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        let t = Tensor::randn(&[b, l, d], rng);
        HostTensor::f32(&[b, l, d], t.into_data())
    }

    /// Token reversal along the sequence axis — the inter-block permutation.
    pub fn reverse_tokens(&self, t: &HostTensor) -> Result<HostTensor> {
        let shape = t.shape().to_vec();
        if shape.len() != 3 {
            bail!("reverse_tokens expects (B, L, D), got {shape:?}");
        }
        let (b, l, d) = (shape[0], shape[1], shape[2]);
        let src = t.as_f32()?;
        let mut out = vec![0.0f32; src.len()];
        for bi in 0..b {
            for li in 0..l {
                let s = (bi * l + li) * d;
                let dst = (bi * l + (l - 1 - li)) * d;
                out[dst..dst + d].copy_from_slice(&src[s..s + d]);
            }
        }
        Ok(HostTensor::f32(&shape, out))
    }

    /// Decode one block sequentially with the KV cache (paper's baseline
    /// path). Returns `u = A_k^{-1}(v)` and the number of steps (= L).
    pub fn sequential_decode_block(&self, k: usize, v: &HostTensor) -> Result<(HostTensor, usize)> {
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        let (nl, dm) = (self.meta.layers_per_block, self.meta.model_dim);
        let v_data = v.as_f32()?;

        let mut kv_k = self.pool.take_zeroed(&[nl, b, l, dm]);
        let mut kv_v = self.pool.take_zeroed(&[nl, b, l, dm]);
        let mut u_prev = HostTensor::f32(&[b, d], vec![0.0; b * d]);
        let mut u_out = vec![0.0f32; b * l * d];

        for pos in 0..l {
            // Gather v[:, pos, :].
            let mut v_tok = vec![0.0f32; b * d];
            for bi in 0..b {
                let s = (bi * l + pos) * d;
                v_tok[bi * d..(bi + 1) * d].copy_from_slice(&v_data[s..s + d]);
            }
            let outs = self
                .engine
                .call(
                    &self.art_seqstep,
                    &[
                        HostTensor::scalar_i32(k as i32),
                        u_prev,
                        HostTensor::f32(&[b, d], v_tok),
                        HostTensor::scalar_i32(pos as i32),
                        kv_k,
                        kv_v,
                    ],
                )
                .with_context(|| format!("seqstep block {k} pos {pos}"))?;
            let mut it = outs.into_iter();
            let u_tok = it.next().expect("u token");
            kv_k = it.next().expect("kv_k");
            kv_v = it.next().expect("kv_v");
            let u_data = u_tok.as_f32()?;
            for bi in 0..b {
                let dstoff = (bi * l + pos) * d;
                u_out[dstoff..dstoff + d].copy_from_slice(&u_data[bi * d..(bi + 1) * d]);
            }
            u_prev = u_tok;
        }
        self.pool.give_back(kv_k);
        self.pool.give_back(kv_v);
        Ok((HostTensor::f32(&[b, l, d], u_out), l))
    }

    /// Whole-block sequential inverse as a single scan-fused artifact call
    /// (§Perf ablation — no per-token call/marshal overhead).
    pub fn sequential_decode_block_fused(&self, k: usize, v: &HostTensor) -> Result<HostTensor> {
        let outs = self
            .engine
            .call(&self.art_seqfull, &[HostTensor::scalar_i32(k as i32), v.clone()])?;
        Ok(outs.into_iter().next().expect("seqfull output"))
    }

    /// Decode one block via the paper's eq-6 masked update iterated to its
    /// fixed point (`o > 0` ⇒ approximate masked inference; `o = 0` ⇒ exact
    /// Jacobi decode of `A_k(z) = y`).
    pub fn jacobi_decode(
        &self,
        k: usize,
        v: &HostTensor,
        cfg: &JacobiConfig,
        mask_o: usize,
    ) -> Result<(HostTensor, JacobiStats)> {
        jacobi_decode_block(self.engine, &self.art_jstep, k, v, self.meta.seq_len, cfg, mask_o)
    }

    /// Ground-truth single-block forward `v = A_k(u)` (AR domain).
    pub fn block_forward(&self, k: usize, u: &HostTensor) -> Result<HostTensor> {
        let outs = self
            .engine
            .call(&self.art_block_fwd, &[HostTensor::scalar_i32(k as i32), u.clone()])?;
        Ok(outs.into_iter().next().expect("block_fwd output"))
    }

    /// Full encode `x → (z, logdet)` via the python-composed artifact.
    pub fn encode(&self, images: &HostTensor) -> Result<(HostTensor, HostTensor)> {
        let outs = self.engine.call(&self.art_fwd, &[images.clone()])?;
        let mut it = outs.into_iter();
        let z = it.next().expect("z");
        let logdet = it.next().expect("logdet");
        Ok((z, logdet))
    }

    /// Full decode: latent tokens (B, L, D) → data tokens h_0 (B, L, D),
    /// following the configured policy. This is the serving hot path.
    pub fn decode_tokens(&self, z_latent: HostTensor, opts: &SampleOptions) -> Result<SampleOutput> {
        let t_start = Instant::now();
        let kk = self.meta.blocks;
        let mut traces = Vec::with_capacity(kk);
        let mut decode_wall = Duration::ZERO;
        let mut z = z_latent;

        for pos in 0..kk {
            let k = kk - 1 - pos; // block index in flow order
            let v = z;
            let t0 = Instant::now();
            let (u, trace) = if opts.policy.use_jacobi(pos, kk) {
                let mut cfg = opts.jacobi.clone();
                cfg.seed = opts.seed.wrapping_add(pos as u64);
                let (u, stats) = self.jacobi_decode(k, &v, &cfg, opts.mask_o)?;
                let wall = t0.elapsed();
                (
                    u,
                    BlockTrace {
                        block: k,
                        position: pos,
                        used_jacobi: true,
                        steps: stats.iterations,
                        wall,
                        jacobi: Some(stats),
                    },
                )
            } else {
                let (u, steps) = if opts.fused_sequential {
                    (self.sequential_decode_block_fused(k, &v)?, self.meta.seq_len)
                } else {
                    self.sequential_decode_block(k, &v)?
                };
                let wall = t0.elapsed();
                (
                    u,
                    BlockTrace {
                        block: k,
                        position: pos,
                        used_jacobi: false,
                        steps,
                        wall,
                        jacobi: None,
                    },
                )
            };
            decode_wall += trace.wall;
            traces.push(trace);
            // h_k = P_k(u): reversal for odd k.
            z = if k % 2 == 1 { self.reverse_tokens(&u)? } else { u };
        }

        let total_wall = t_start.elapsed();
        Ok(SampleOutput {
            tokens: z,
            traces,
            total_wall,
            other_wall: total_wall.saturating_sub(decode_wall),
        })
    }

    /// Sample a batch of images.
    pub fn sample_images(&self, opts: &SampleOptions, rng: &mut Pcg64) -> Result<(Vec<Tensor>, SampleOutput)> {
        let z = self.sample_prior(rng);
        let out = self.decode_tokens(z, opts)?;
        let images = self.unpatchify(&out.tokens)?;
        Ok((images, out))
    }

    /// Tokens (B, L, D) → per-sample (H, W, C) tensors.
    ///
    /// Inverse of python's
    /// `x.reshape(B, H/P, P, W/P, P, C).transpose(0,1,3,2,4,5).reshape(B, L, D)`.
    pub fn unpatchify(&self, tokens: &HostTensor) -> Result<Vec<Tensor>> {
        let [h, w, c] = self.meta.image_hwc.context("model has no image geometry")?;
        let p = self.meta.patch;
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        debug_assert_eq!(l, (h / p) * (w / p));
        debug_assert_eq!(d, p * p * c);
        let data = tokens.as_f32()?;
        let gw = w / p;
        let mut out = Vec::with_capacity(b);
        for bi in 0..b {
            let mut img = vec![0.0f32; h * w * c];
            for li in 0..l {
                let (py, px) = (li / gw, li % gw);
                let tok = &data[(bi * l + li) * d..(bi * l + li + 1) * d];
                for dy in 0..p {
                    for dx in 0..p {
                        for ch in 0..c {
                            let v = tok[(dy * p + dx) * c + ch];
                            img[((py * p + dy) * w + (px * p + dx)) * c + ch] = v;
                        }
                    }
                }
            }
            out.push(Tensor::new(&[h, w, c], img)?);
        }
        Ok(out)
    }

    /// Images (list of (H, W, C) tensors) → tokens (B, L, D); exact inverse
    /// of [`Self::unpatchify`].
    pub fn patchify(&self, images: &[Tensor]) -> Result<HostTensor> {
        let [h, w, c] = self.meta.image_hwc.context("model has no image geometry")?;
        let p = self.meta.patch;
        let (b, l, d) = (images.len(), self.meta.seq_len, self.meta.token_dim);
        let gw = w / p;
        let mut out = vec![0.0f32; b * l * d];
        for (bi, img) in images.iter().enumerate() {
            if img.shape() != [h, w, c] {
                bail!("image {bi} has shape {:?}, expected ({h},{w},{c})", img.shape());
            }
            for li in 0..l {
                let (py, px) = (li / gw, li % gw);
                for dy in 0..p {
                    for dx in 0..p {
                        for ch in 0..c {
                            out[(bi * l + li) * d + (dy * p + dx) * c + ch] =
                                img.at(&[py * p + dy, px * p + dx, ch]);
                        }
                    }
                }
            }
        }
        Ok(HostTensor::f32(&[b, l, d], out))
    }

    /// Images stacked as one (B, H, W, C) HostTensor (for the fwd artifact).
    pub fn stack_images(&self, images: &[Tensor]) -> Result<HostTensor> {
        let [h, w, c] = self.meta.image_hwc.context("no image geometry")?;
        let mut data = Vec::with_capacity(images.len() * h * w * c);
        for img in images {
            if img.shape() != [h, w, c] {
                bail!("bad image shape {:?}", img.shape());
            }
            data.extend_from_slice(img.data());
        }
        Ok(HostTensor::f32(&[images.len(), h, w, c], data))
    }
}

