//! Dynamic request batching.
//!
//! Artifacts are lowered for a *set* of fixed batch sizes (buckets), so the
//! batcher groups single-image slots from concurrent requests into one model
//! batch of up to `max_batch` slots — the largest lowered bucket — flushing a
//! partial batch when a deadline expires before it fills (vLLM-style
//! max-wait batching). The batcher never pads: the router worker picks the
//! smallest bucket covering the formed batch and pads only the gap to *that*
//! bucket (tracked in the `sjd_padded_slots` counter), so an `n=1` request
//! served by a `{1,2,4,8}` bucket set decodes zero throwaway slots.
//!
//! Continuous batching (`serve --refill`) adds two verbs on top: a
//! non-blocking [`Batcher::take_upto`] drain that tops a decoding wave up to
//! the largest bucket at every block boundary, and a per-slot cancellation
//! flag ([`SlotHandle::cancel`]) that lets an abandoned request leave the
//! wave at the next boundary instead of decoding to the end.
//!
//! ## Admission control & QoS
//!
//! The queue is bounded (`serve --queue-cap`, 0 = unbounded): a submit
//! against a full queue fails fast with the typed [`QueueFull`] marker
//! error, which the HTTP layer maps to 429 + `Retry-After` — overload sheds
//! at the door instead of queueing to death. Submitting after
//! [`Batcher::close`] fails with the typed [`BatcherClosed`] marker (HTTP
//! 503). Each slot may carry a QoS envelope ([`SubmitOpts`]): an absolute
//! deadline — expired slots are resolved with a
//! [`DEADLINE_EXPIRED_MSG`]-prefixed error (HTTP 504) at every drain point
//! instead of being handed to a worker — and a [`Priority`] class. The
//! drain verbs ([`Batcher::next_batch`] / [`Batcher::take_upto`]) prefer
//! high-priority slots with bounded normal starvation: after every
//! [`HIGH_PICKS_PER_NORMAL`] consecutive high picks one normal slot drains,
//! so high-priority queue wait stays short under load while normal traffic
//! keeps progressing. All-normal traffic remains strict FIFO.

use crate::exec::OneShot;
use crate::metrics::{Counter, Gauge, Registry};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What a slot's completion channel carries: the generated (H, W, C) image,
/// or the decode error message (`String` so every slot of a failed batch
/// gets its own copy) — the HTTP layer turns it into a 500 instead of
/// returning a silently-black 200.
pub type SlotResult = std::result::Result<Tensor, String>;

/// Error-message prefix for a slot resolved because its deadline passed
/// (while queued, or swept out of a wave at a block boundary). The HTTP
/// layer maps results carrying this prefix to 504 Gateway Timeout; keeping
/// it a single shared constant is what makes that mapping reliable.
pub const DEADLINE_EXPIRED_MSG: &str = "deadline expired";

/// Error-message prefix for a slot resolved by the completion guard
/// ([`Slot`]'s `Drop`) because its holder failed without resolving it —
/// worker panic, discarded wave, dead stage. Surfaces as HTTP 500: the
/// request genuinely failed, but the waiter is never stranded.
pub const WORKER_FAILED_MSG: &str = "worker failed mid-decode";

/// Consecutive high-priority drains allowed before one queued normal slot
/// is picked — bounds normal-class starvation under sustained high load.
pub const HIGH_PICKS_PER_NORMAL: u32 = 3;

/// Typed marker error for a submit rejected by admission control (queue at
/// `queue_cap`). The HTTP layer checks `err.is::<QueueFull>()` and answers
/// 429 Too Many Requests with a `Retry-After` hint.
#[derive(Debug, Clone, Copy)]
pub struct QueueFull {
    pub cap: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "queue full (admission cap {} reached)", self.cap)
    }
}

impl std::error::Error for QueueFull {}

/// Typed marker error for a submit after [`Batcher::close`]. The HTTP layer
/// checks `err.is::<BatcherClosed>()` and answers 503 Service Unavailable —
/// shutdown is not an internal failure, so it must not surface as 500.
#[derive(Debug, Clone, Copy)]
pub struct BatcherClosed;

impl std::fmt::Display for BatcherClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "batcher is closed (server shutting down)")
    }
}

impl std::error::Error for BatcherClosed {}

/// Priority class of a slot (`X-SJD-Priority` header). High-priority slots
/// drain ahead of normal ones with bounded starvation (see
/// [`HIGH_PICKS_PER_NORMAL`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    #[default]
    Normal,
    High,
}

/// Per-submit QoS envelope: an absolute completion deadline and a priority
/// class. `Default` is the pre-QoS behavior (no deadline, normal priority).
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// Absolute deadline: the slot is resolved with a
    /// [`DEADLINE_EXPIRED_MSG`] error (HTTP 504) if it has not completed by
    /// this instant — enforced at every queue drain and at every block
    /// boundary of the continuous decode path.
    pub deadline: Option<Instant>,
    pub priority: Priority,
}

/// One image slot of a request.
pub struct Slot {
    pub request_id: u64,
    pub seed: u64,
    /// Completion channel: receives the image or the decode error.
    pub done: OneShot<SlotResult>,
    /// Cooperative cancellation flag (client disconnected): the continuous
    /// path sweeps cancelled slots out at the next block boundary instead
    /// of decoding them to the end; monolithic workers ignore it (the slot
    /// still completes, its result is simply discarded).
    pub cancel: Arc<AtomicBool>,
    pub enqueued: Instant,
    /// Absolute completion deadline (see [`SubmitOpts::deadline`]).
    pub deadline: Option<Instant>,
    pub priority: Priority,
}

impl Slot {
    /// Whether the submitter abandoned this slot (see [`SlotHandle::cancel`]).
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// Whether this slot's deadline has passed — it should be resolved with
    /// a [`DEADLINE_EXPIRED_MSG`] error instead of (further) decoding.
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Resolve this slot as deadline-expired (the 504 path). `where_` names
    /// the enforcement point ("queued" / "block boundary") for the client.
    /// Idempotent: a slot already resolved elsewhere keeps its first result.
    pub fn resolve_expired(&self, where_: &str) {
        self.done.put_once(Err(format!("{DEADLINE_EXPIRED_MSG} ({where_})")));
    }
}

/// Completion guard: a slot that is dropped without ever being resolved —
/// a worker panicked mid-decode, a wave was discarded, a pipeline stage
/// died — resolves `Err` here instead of stranding its waiter forever at
/// `OneShot::wait`. `put_once` makes this race-free against concurrent
/// resolvers (worker result, deadline sweep, watchdog): whoever runs first
/// wins, everyone else is a no-op, so every slot resolves exactly once.
impl Drop for Slot {
    fn drop(&mut self) {
        self.done.put_once(Err(format!(
            "{WORKER_FAILED_MSG} (slot for request {} dropped unresolved)",
            self.request_id
        )));
    }
}

/// The submitter's side of a slot: the completion channel plus the
/// cancellation flag. Cancelling is advisory — the slot still resolves
/// (with an error if it was swept before decoding), so a waiter never
/// hangs.
#[derive(Clone)]
pub struct SlotHandle {
    pub done: OneShot<SlotResult>,
    cancel: Arc<AtomicBool>,
}

impl SlotHandle {
    /// Flag the slot as abandoned; the continuous decode path drops it at
    /// the next block boundary.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// A formed batch handed to a worker: between 1 and `max_batch` real slots.
/// Bucket choice — and therefore padding — is the worker's job.
pub struct Batch {
    pub slots: Vec<Slot>,
    pub formed: Instant,
}

struct QueueInner {
    high: VecDeque<Slot>,
    normal: VecDeque<Slot>,
    closed: bool,
    /// Consecutive high-priority picks since the last normal pick — the
    /// starvation bound's state (see [`HIGH_PICKS_PER_NORMAL`]).
    high_streak: u32,
    /// Optional observability instruments (see [`Batcher::bind_metrics`]).
    depth: Option<Arc<Gauge>>,
    expired: Option<Arc<Counter>>,
}

impl QueueInner {
    fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    /// Enqueue time of the oldest queued slot across both classes — drives
    /// the partial-batch flush deadline.
    fn oldest(&self) -> Option<Instant> {
        match (self.high.front(), self.normal.front()) {
            (Some(h), Some(n)) => Some(h.enqueued.min(n.enqueued)),
            (Some(h), None) => Some(h.enqueued),
            (None, Some(n)) => Some(n.enqueued),
            (None, None) => None,
        }
    }

    /// Resolve and remove every queued slot whose deadline has passed, so
    /// dead slots neither reach a worker nor hold admission-cap space.
    fn purge_expired(&mut self) {
        for q in [&mut self.high, &mut self.normal] {
            let before = q.len();
            q.retain(|s| {
                if s.expired() {
                    s.resolve_expired("queued");
                    false
                } else {
                    true
                }
            });
            if let Some(c) = &self.expired {
                c.add((before - q.len()) as u64);
            }
        }
        self.publish_depth();
    }

    /// Weighted drain of one slot: high priority first, but after
    /// [`HIGH_PICKS_PER_NORMAL`] consecutive high picks one normal slot
    /// drains. All-normal traffic is strict FIFO.
    fn pick(&mut self) -> Option<Slot> {
        let slot = if self.high.is_empty() {
            self.normal.pop_front()
        } else if self.normal.is_empty() || self.high_streak < HIGH_PICKS_PER_NORMAL {
            self.high_streak += 1;
            return self.high.pop_front();
        } else {
            self.normal.pop_front()
        };
        if slot.is_some() {
            self.high_streak = 0;
        }
        slot
    }

    fn publish_depth(&self) {
        if let Some(g) = &self.depth {
            g.set(self.len() as i64);
        }
    }
}

/// Shared batching queue.
#[derive(Clone)]
pub struct Batcher {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    /// Largest batch a worker will be handed (= the largest decode bucket).
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission bound on the queue (`serve --queue-cap`); 0 = unbounded.
    /// A submit against a full queue fails with [`QueueFull`] (HTTP 429).
    pub queue_cap: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self::with_cap(max_batch, max_wait, 0)
    }

    /// [`Self::new`] with an admission cap on the queue (0 = unbounded).
    pub fn with_cap(max_batch: usize, max_wait: Duration, queue_cap: usize) -> Self {
        assert!(max_batch > 0);
        Batcher {
            inner: Arc::new((
                Mutex::new(QueueInner {
                    high: VecDeque::new(),
                    normal: VecDeque::new(),
                    closed: false,
                    high_streak: 0,
                    depth: None,
                    expired: None,
                }),
                Condvar::new(),
            )),
            max_batch,
            max_wait,
            queue_cap,
        }
    }

    /// Attach queue observability: `sjd_queue_depth` (live queue length),
    /// `sjd_queue_cap` (the admission bound, 0 = unbounded) and
    /// `sjd_deadline_expired` (slots resolved 504 while queued) — shed
    /// decisions become visible next to the counters they trigger.
    pub fn bind_metrics(&self, registry: &Registry) {
        registry.gauge("sjd_queue_cap").set(self.queue_cap as i64);
        let depth = registry.gauge("sjd_queue_depth");
        let expired = registry.counter("sjd_deadline_expired");
        let mut q = self.inner.0.lock().unwrap();
        depth.set(q.len() as i64);
        q.depth = Some(depth);
        q.expired = Some(expired);
    }

    /// Enqueue one slot; returns its completion handle. Fails fast once the
    /// queue is [`Self::close`]d — workers drain and exit after close, so a
    /// late slot would otherwise sit in the queue forever and its completion
    /// handle would never fire.
    pub fn submit(&self, request_id: u64, seed: u64) -> Result<OneShot<SlotResult>> {
        Ok(self.submit_slot(request_id, seed)?.done)
    }

    /// [`Self::submit`] returning the full [`SlotHandle`] (completion +
    /// cancellation); the HTTP layer cancels a request's remaining slots
    /// when the client disconnects mid-decode.
    pub fn submit_slot(&self, request_id: u64, seed: u64) -> Result<SlotHandle> {
        self.submit_slot_opts(request_id, seed, SubmitOpts::default())
    }

    /// [`Self::submit_slot`] with a QoS envelope: deadline + priority.
    /// Admission control happens here — a full queue rejects with the typed
    /// [`QueueFull`] error, a closed queue with [`BatcherClosed`].
    pub fn submit_slot_opts(
        &self,
        request_id: u64,
        seed: u64,
        opts: SubmitOpts,
    ) -> Result<SlotHandle> {
        let done = OneShot::new();
        let cancel = Arc::new(AtomicBool::new(false));
        let slot = Slot {
            request_id,
            seed,
            done: done.clone(),
            cancel: cancel.clone(),
            enqueued: Instant::now(),
            deadline: opts.deadline,
            priority: opts.priority,
        };
        let (m, cv) = &*self.inner;
        {
            let mut q = m.lock().unwrap();
            if q.closed {
                return Err(anyhow::Error::new(BatcherClosed));
            }
            // Dead slots must not hold cap space against live admissions.
            q.purge_expired();
            if self.queue_cap > 0 && q.len() >= self.queue_cap {
                return Err(anyhow::Error::new(QueueFull { cap: self.queue_cap }));
            }
            match slot.priority {
                Priority::High => q.high.push_back(slot),
                Priority::Normal => q.normal.push_back(slot),
            }
            q.publish_depth();
        }
        cv.notify_all();
        Ok(SlotHandle { done, cancel })
    }

    pub fn queued(&self) -> usize {
        self.inner.0.lock().unwrap().len()
    }

    /// Close the queue: new [`Self::submit`]s fail fast, waiting workers
    /// drain remaining slots then get `None`.
    pub fn close(&self) {
        self.inner.0.lock().unwrap().closed = true;
        self.inner.1.notify_all();
    }

    /// Worker side: block until a full `max_batch` is available or the
    /// oldest slot has waited `max_wait`, then return the batch. `None`
    /// after [`Self::close`] once the queue is drained. Slots whose
    /// deadline passed while queued are resolved 504 here instead of being
    /// handed out; high-priority slots drain first (bounded starvation).
    pub fn next_batch(&self) -> Option<Batch> {
        let (m, cv) = &*self.inner;
        let mut q = m.lock().unwrap();
        loop {
            q.purge_expired();
            if q.len() >= self.max_batch {
                break;
            }
            if q.len() > 0 {
                if q.closed {
                    break; // flush the tail immediately on shutdown
                }
                let oldest = q.oldest().unwrap();
                let waited = oldest.elapsed();
                if waited >= self.max_wait {
                    break; // flush partial batch
                }
                let (nq, _timeout) = cv.wait_timeout(q, self.max_wait - waited).unwrap();
                q = nq;
                continue;
            }
            if q.closed {
                return None;
            }
            q = cv.wait(q).unwrap();
        }
        let take = q.len().min(self.max_batch);
        let slots: Vec<Slot> = (0..take).filter_map(|_| q.pick()).collect();
        q.publish_depth();
        Some(Batch { slots, formed: Instant::now() })
    }

    /// Non-blocking drain of up to `n` queued slots — the continuous-batching
    /// refill: a wave entering stage 0 tops itself up to the largest bucket
    /// from whatever is queued *right now*, without waiting out `max_wait`.
    /// Drains even after [`Self::close`] so a shutdown that lands mid-refill
    /// still flushes every accepted slot to a worker (which then completes
    /// each with an error or an image — never a hang). Applies the same
    /// expiry purge and priority weighting as [`Self::next_batch`].
    pub fn take_upto(&self, n: usize) -> Vec<Slot> {
        if n == 0 {
            return Vec::new();
        }
        let mut q = self.inner.0.lock().unwrap();
        q.purge_expired();
        let take = q.len().min(n);
        let slots: Vec<Slot> = (0..take).filter_map(|_| q.pick()).collect();
        q.publish_depth();
        slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_batch_formed_immediately() {
        let b = Batcher::new(4, Duration::from_secs(10));
        let handles: Vec<_> = (0..4).map(|i| b.submit(i, i).unwrap()).collect();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.slots.len(), 4);
        assert_eq!(b.queued(), 0);
        drop(handles);
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let b = Batcher::new(8, Duration::from_millis(30));
        let _h = b.submit(1, 0).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(batch.slots.len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(4, Duration::from_millis(5));
        let _h = b.submit(1, 0).unwrap();
        b.close();
        let batch = b.next_batch();
        assert!(batch.is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn submit_after_close_fails_fast() {
        // A slot accepted after close() could never complete (workers have
        // drained and exited): the submission itself must error — with the
        // typed marker the HTTP layer maps to 503, not 500.
        let b = Batcher::new(4, Duration::from_millis(5));
        b.close();
        let err = b.submit(1, 0).unwrap_err();
        assert!(err.is::<BatcherClosed>());
        assert!(err.to_string().contains("closed"), "{err}");
        // Nothing was enqueued and workers still see a clean end-of-queue.
        assert_eq!(b.queued(), 0);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_flushes_waiting_partial_batch_immediately() {
        // A worker parked on a partial batch must not sit out the full
        // max_wait once the queue closes.
        let b = Batcher::new(8, Duration::from_secs(30));
        let _h = b.submit(1, 0).unwrap();
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        let batch = t.join().unwrap().unwrap();
        assert_eq!(batch.slots.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let b = Batcher::new(3, Duration::from_secs(1));
        for i in 0..3 {
            b.submit(i, 0).unwrap();
        }
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.slots.iter().map(|s| s.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn oversubmission_leaves_remainder_queued() {
        let b = Batcher::new(2, Duration::from_secs(1));
        for i in 0..5 {
            b.submit(i, 0).unwrap();
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.slots.len(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn take_upto_is_nonblocking_and_bounded() {
        let b = Batcher::new(8, Duration::from_secs(30));
        assert!(b.take_upto(4).is_empty()); // empty queue: returns immediately
        for i in 0..3 {
            b.submit(i, 0).unwrap();
        }
        let got = b.take_upto(2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].request_id, 0);
        assert_eq!(b.queued(), 1);
        assert!(b.take_upto(0).is_empty());
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn take_upto_drains_after_close() {
        // Shutdown-during-refill: slots accepted before close() must still
        // reach a worker so their completion handles fire.
        let b = Batcher::new(8, Duration::from_secs(30));
        b.submit(1, 0).unwrap();
        b.close();
        assert_eq!(b.take_upto(8).len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn cancel_flag_crosses_to_worker_slot() {
        let b = Batcher::new(1, Duration::from_secs(1));
        let h = b.submit_slot(1, 0).unwrap();
        h.cancel();
        let batch = b.next_batch().unwrap();
        assert!(batch.slots[0].cancelled());
        batch.slots[0].done.put(Err("cancelled".into()));
        assert!(h.done.wait().is_err());
    }

    #[test]
    fn cross_thread_completion() {
        let b = Batcher::new(1, Duration::from_secs(1));
        let h = b.submit(1, 7).unwrap();
        let b2 = b.clone();
        std::thread::spawn(move || {
            let batch = b2.next_batch().unwrap();
            for slot in batch.slots {
                slot.done.put(Ok(Tensor::full(&[2, 2, 3], slot.seed as f32)));
            }
        });
        let img = h.wait().unwrap();
        assert_eq!(img.data()[0], 7.0);
    }

    #[test]
    fn queue_cap_rejects_with_typed_queue_full() {
        let b = Batcher::with_cap(8, Duration::from_secs(1), 2);
        b.submit(0, 0).unwrap();
        b.submit(1, 0).unwrap();
        let err = b.submit(2, 0).unwrap_err();
        assert!(err.is::<QueueFull>(), "{err}");
        assert!(err.to_string().contains("queue full"), "{err}");
        // Draining frees cap space for new admissions.
        assert_eq!(b.take_upto(2).len(), 2);
        b.submit(3, 0).unwrap();
    }

    #[test]
    fn high_priority_drains_first_with_bounded_starvation() {
        let b = Batcher::new(8, Duration::from_secs(1));
        for i in 0..4 {
            b.submit(i, 0).unwrap(); // normal class, ids 0..4
        }
        for i in 10..14 {
            b.submit_slot_opts(i, 0, SubmitOpts { priority: Priority::High, ..Default::default() })
                .unwrap();
        }
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.slots.iter().map(|s| s.request_id).collect();
        // Three high picks, then one normal (the starvation bound), then the
        // remaining high, then normals in FIFO order.
        assert_eq!(ids, vec![10, 11, 12, 0, 13, 1, 2, 3]);
    }

    #[test]
    fn expired_slot_resolves_504_at_drain_and_live_slot_survives() {
        let b = Batcher::new(8, Duration::from_millis(10));
        let dead = b
            .submit_slot_opts(
                1,
                0,
                SubmitOpts {
                    deadline: Some(Instant::now() + Duration::from_millis(2)),
                    ..Default::default()
                },
            )
            .unwrap();
        let _live = b.submit(2, 0).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.slots.len(), 1);
        assert_eq!(batch.slots[0].request_id, 2);
        let err = dead.done.wait().unwrap_err();
        assert!(err.starts_with(DEADLINE_EXPIRED_MSG), "{err}");
    }

    #[test]
    fn expired_slot_does_not_hold_cap_space() {
        let b = Batcher::with_cap(8, Duration::from_secs(1), 1);
        b.submit_slot_opts(
            1,
            0,
            SubmitOpts {
                deadline: Some(Instant::now() + Duration::from_millis(2)),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(b.submit(2, 0).unwrap_err().is::<QueueFull>());
        std::thread::sleep(Duration::from_millis(5));
        // The expired slot is purged at admission time, freeing its slot.
        b.submit(3, 0).unwrap();
    }

    #[test]
    fn dropped_slot_resolves_err_instead_of_stranding_waiter() {
        // Completion guard regression: a worker that takes a batch and dies
        // (unwinds, or simply drops the slots without resolving them) must
        // not strand the submitter at `OneShot::wait` forever.
        let b = Batcher::new(4, Duration::from_secs(1));
        let h1 = b.submit(1, 0).unwrap();
        let h2 = b.submit(2, 0).unwrap();
        let b2 = b.clone();
        std::thread::spawn(move || {
            let batch = b2.next_batch().unwrap();
            drop(batch); // worker "dies" holding the whole wave
        })
        .join()
        .unwrap();
        let e1 = h1.wait().unwrap_err();
        assert!(e1.starts_with(WORKER_FAILED_MSG), "{e1}");
        assert!(h2.wait().unwrap_err().starts_with(WORKER_FAILED_MSG));
    }

    #[test]
    fn guard_never_overwrites_a_real_resolution() {
        // A slot resolved Ok keeps its result when later dropped: the guard
        // races through put_once, so exactly the first resolution wins.
        let b = Batcher::new(1, Duration::from_secs(1));
        let h = b.submit(1, 9).unwrap();
        let batch = b.next_batch().unwrap();
        batch.slots[0].done.put(Ok(Tensor::full(&[1, 1, 3], 9.0)));
        drop(batch);
        assert_eq!(h.wait().unwrap().data()[0], 9.0);
    }

    #[test]
    fn unwinding_worker_resolves_its_chunk_via_guard() {
        // Panic-on-unwind flavor of the guard test: the slots live on the
        // panicking thread's stack and their Drop (not any catch site) is
        // what resolves the waiters.
        let b = Batcher::new(2, Duration::from_secs(1));
        let h = b.submit(7, 0).unwrap();
        let b2 = b.clone();
        let worker = std::thread::spawn(move || {
            let _batch = b2.next_batch().unwrap();
            panic!("injected worker panic");
        });
        assert!(worker.join().is_err());
        assert!(h.wait().unwrap_err().starts_with(WORKER_FAILED_MSG));
    }

    #[test]
    fn bind_metrics_tracks_depth_cap_and_expiry() {
        let b = Batcher::with_cap(8, Duration::from_millis(10), 5);
        let r = Registry::new();
        b.bind_metrics(&r);
        assert_eq!(r.gauge("sjd_queue_cap").get(), 5);
        b.submit(1, 0).unwrap();
        b.submit_slot_opts(
            2,
            0,
            SubmitOpts {
                deadline: Some(Instant::now() + Duration::from_millis(2)),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(r.gauge("sjd_queue_depth").get(), 2);
        std::thread::sleep(Duration::from_millis(5));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.slots.len(), 1);
        assert_eq!(r.gauge("sjd_queue_depth").get(), 0);
        assert_eq!(r.counter("sjd_deadline_expired").get(), 1);
    }
}
