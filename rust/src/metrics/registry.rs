//! Named metric registry with text exposition.

use super::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Shared registry of named metrics. Cloning is cheap (Arc).
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Prometheus-style text exposition (histograms export count/mean/p50/p95/p99/max in nanoseconds).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.inner.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", c.get()));
        }
        for (name, g) in self.inner.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", g.get()));
        }
        for (name, h) in self.inner.histograms.lock().unwrap().iter() {
            let s = h.snapshot();
            out.push_str(&format!("{name}_count {}\n", s.count));
            out.push_str(&format!("{name}_mean_ns {:.0}\n", s.mean()));
            out.push_str(&format!("{name}_p50_ns {}\n", s.p50()));
            out.push_str(&format!("{name}_p95_ns {}\n", s.p95()));
            out.push_str(&format!("{name}_p99_ns {}\n", s.p99()));
            out.push_str(&format!("{name}_max_ns {}\n", s.max));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let r = Registry::new();
        r.counter("reqs").inc();
        r.counter("reqs").add(2);
        assert_eq!(r.counter("reqs").get(), 3);
        r.gauge("queue").set(5);
        r.gauge("queue").add(-2);
        assert_eq!(r.gauge("queue").get(), 3);
    }

    #[test]
    fn same_name_same_instance() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn text_rendering() {
        let r = Registry::new();
        r.counter("a_total").add(7);
        r.histogram("lat").record(1000);
        let text = r.render_text();
        assert!(text.contains("a_total 7"));
        assert!(text.contains("lat_count 1"));
        assert!(text.contains("lat_p99_ns"));
    }

    #[test]
    fn shared_across_threads() {
        let r = Registry::new();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..250 {
                    r.counter("n").inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("n").get(), 1000);
    }
}
