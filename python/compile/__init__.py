"""Build-time compile path: JAX models, training, and AOT lowering.

Nothing in this package runs on the request path — `make artifacts` invokes
`python -m compile.aot` once, and the rust coordinator consumes the lowered
HLO text + manifest afterwards.
"""
