//! **Fig 5**: ablation on the stopping threshold τ — FID and inference time
//! across τ values; the speed/quality trade-off with a knee below τ ≈ 1.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;
use sjd::quality::evaluate_quality;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = "tf10";
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let reference = engine.manifest().load_dataset(dataset_for(model))?;
    let n = if quick() { batch } else { 96 };

    let taus = [0.1f32, 0.25, 0.5, 1.0, 2.0, 4.0];
    let mut report = Report::new("Fig 5 — stopping threshold τ: FID vs time");
    let mut rows = Vec::new();
    let mut fids = Vec::new();
    let mut times = Vec::new();

    // Warmup compile.
    let _ = generate(&sampler, DecodePolicy::Selective { seq_blocks: 1 }, 0.5, batch, 1)?;

    for tau in taus {
        let run = generate(&sampler, DecodePolicy::Selective { seq_blocks: 1 }, tau, n, 42)?;
        let per_batch = run.wall / run.batches as f64;
        let q = evaluate_quality(&engine, metricnet_for(model), &run.images, &reference)?;
        println!("tau={tau}: {per_batch:.3}s/batch FID* {:.2}", q.fid);
        rows.push(vec![
            format!("{tau}"),
            format!("{per_batch:.3}"),
            format!("{:.2}", q.fid),
        ]);
        fids.push(q.fid as f64);
        times.push(per_batch);
    }

    report.table(&["τ", "Time/batch (s)", "FID*"], &rows);
    report.series("fid_vs_tau", &fids);
    report.series("time_vs_tau", &times);
    report.note("Paper shape: time falls as τ grows; FID degrades gently below τ≈1, then faster. τ=0.5 is the default.");
    report.finish();
    Ok(())
}
