//! Dynamic request batching.
//!
//! Artifacts are lowered for fixed batch sizes, so the batcher groups
//! single-image slots from concurrent requests into one model batch of
//! exactly `batch_size` slots, padding with throwaway slots when a deadline
//! expires before the batch fills (vLLM-style max-wait batching).

use crate::exec::OneShot;
use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One image slot of a request.
pub struct Slot {
    pub request_id: u64,
    pub seed: u64,
    /// Completion channel: receives the generated (H, W, C) image.
    pub done: OneShot<Tensor>,
    pub enqueued: Instant,
}

/// A formed batch handed to a worker.
pub struct Batch {
    pub slots: Vec<Slot>,
    /// Number of padding slots added to reach the artifact batch size.
    pub padding: usize,
    pub formed: Instant,
}

struct QueueInner {
    slots: VecDeque<Slot>,
    closed: bool,
}

/// Shared batching queue.
#[derive(Clone)]
pub struct Batcher {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    pub batch_size: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(batch_size: usize, max_wait: Duration) -> Self {
        assert!(batch_size > 0);
        Batcher {
            inner: Arc::new((
                Mutex::new(QueueInner { slots: VecDeque::new(), closed: false }),
                Condvar::new(),
            )),
            batch_size,
            max_wait,
        }
    }

    /// Enqueue one slot; returns its completion handle.
    pub fn submit(&self, request_id: u64, seed: u64) -> OneShot<Tensor> {
        let done = OneShot::new();
        let slot = Slot { request_id, seed, done: done.clone(), enqueued: Instant::now() };
        let (m, cv) = &*self.inner;
        m.lock().unwrap().slots.push_back(slot);
        cv.notify_all();
        done
    }

    pub fn queued(&self) -> usize {
        self.inner.0.lock().unwrap().slots.len()
    }

    /// Close the queue: waiting workers drain remaining slots then get `None`.
    pub fn close(&self) {
        self.inner.0.lock().unwrap().closed = true;
        self.inner.1.notify_all();
    }

    /// Worker side: block until a full batch is available or the oldest slot
    /// has waited `max_wait`, then return a (possibly padded) batch. `None`
    /// after [`Self::close`] once the queue is drained.
    pub fn next_batch(&self) -> Option<Batch> {
        let (m, cv) = &*self.inner;
        let mut q = m.lock().unwrap();
        loop {
            if q.slots.len() >= self.batch_size {
                break;
            }
            if !q.slots.is_empty() {
                let oldest = q.slots.front().unwrap().enqueued;
                let waited = oldest.elapsed();
                if waited >= self.max_wait {
                    break; // flush partial batch
                }
                let (nq, _timeout) = cv.wait_timeout(q, self.max_wait - waited).unwrap();
                q = nq;
                continue;
            }
            if q.closed {
                return None;
            }
            q = cv.wait(q).unwrap();
        }
        let take = q.slots.len().min(self.batch_size);
        let slots: Vec<Slot> = q.slots.drain(..take).collect();
        let padding = self.batch_size - slots.len();
        Some(Batch { slots, padding, formed: Instant::now() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_batch_formed_immediately() {
        let b = Batcher::new(4, Duration::from_secs(10));
        let handles: Vec<_> = (0..4).map(|i| b.submit(i, i)).collect();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.slots.len(), 4);
        assert_eq!(batch.padding, 0);
        assert_eq!(b.queued(), 0);
        drop(handles);
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let b = Batcher::new(8, Duration::from_millis(30));
        let _h = b.submit(1, 0);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(batch.slots.len(), 1);
        assert_eq!(batch.padding, 7);
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(4, Duration::from_millis(5));
        let _h = b.submit(1, 0);
        b.close();
        let batch = b.next_batch();
        assert!(batch.is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn fifo_order_preserved() {
        let b = Batcher::new(3, Duration::from_secs(1));
        for i in 0..3 {
            b.submit(i, 0);
        }
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.slots.iter().map(|s| s.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn oversubmission_leaves_remainder_queued() {
        let b = Batcher::new(2, Duration::from_secs(1));
        for i in 0..5 {
            b.submit(i, 0);
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.slots.len(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn cross_thread_completion() {
        let b = Batcher::new(1, Duration::from_secs(1));
        let h = b.submit(1, 7);
        let b2 = b.clone();
        std::thread::spawn(move || {
            let batch = b2.next_batch().unwrap();
            for slot in batch.slots {
                slot.done.put(Tensor::full(&[2, 2, 3], slot.seed as f32));
            }
        });
        let img = h.wait();
        assert_eq!(img.data()[0], 7.0);
    }
}
