"""L2: TarFlow-style discrete autoregressive normalizing flow in JAX.

Architecture (per Zhai et al. 2025, scaled down — see DESIGN.md §5):

* The image is patchified into L tokens of dim D = P·P·C.
* K *blocks*; block k applies a masked-autoregressive affine transform
  ``A_k`` over the token sequence (eq 4), whose (s, g) are produced by a
  small causal ViT: in-proj → +pos-emb → NL pre-LN transformer layers
  (causal attention + MLP) → LN → zero-init out-proj to (s, g).
* The net input is the sequence *shifted right by one* (zero pad at
  position 0) so the output at position l depends only on tokens < l.
* Between blocks the token order is reversed (the paper's permutation) so
  every position is eventually transformed. The reversal `P_k` (applied for
  odd k) lives OUTSIDE these functions: `h_{k+1} = A_k(P_k h_k)` — the rust
  coordinator and `flow_forward` below both apply it.

Parameters for all K blocks are stacked on a leading K axis so a single
lowered artifact serves every block via a traced ``block_idx`` gather.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import affine_update, attention, ref


class TarFlowConfig(NamedTuple):
    name: str
    img_hw: int          # square image side
    channels: int
    patch: int
    blocks: int          # K
    layers_per_block: int  # NL
    model_dim: int       # Dm
    heads: int
    noise_std: float     # training dequantization noise
    dataset: str
    train_steps: int
    train_batch: int
    lr: float

    @property
    def seq_len(self) -> int:
        return (self.img_hw // self.patch) ** 2

    @property
    def token_dim(self) -> int:
        return self.patch * self.patch * self.channels


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_block_params(key, cfg: TarFlowConfig):
    """Parameters of one block's causal ViT. Returned as a flat dict."""
    d, dm, nl = cfg.token_dim, cfg.model_dim, cfg.layers_per_block
    keys = jax.random.split(key, 4 + 6 * nl)
    scale_in = 1.0 / jnp.sqrt(d)
    params = {
        "in_w": jax.random.normal(keys[0], (d, dm)) * scale_in,
        "in_b": jnp.zeros((dm,)),
        "pos": jax.random.normal(keys[1], (cfg.seq_len, dm)) * 0.02,
        # Zero-init output projection → the flow starts as the identity.
        "out_w": jnp.zeros((dm, 2 * d)),
        "out_b": jnp.zeros((2 * d,)),
        "lnf_g": jnp.ones((dm,)),
        "lnf_b": jnp.zeros((dm,)),
    }
    scale = 1.0 / jnp.sqrt(dm)
    for i in range(nl):
        k0 = keys[4 + 6 * i:4 + 6 * (i + 1)]
        params[f"l{i}_ln1_g"] = jnp.ones((dm,))
        params[f"l{i}_ln1_b"] = jnp.zeros((dm,))
        params[f"l{i}_wq"] = jax.random.normal(k0[0], (dm, dm)) * scale
        params[f"l{i}_wk"] = jax.random.normal(k0[1], (dm, dm)) * scale
        params[f"l{i}_wv"] = jax.random.normal(k0[2], (dm, dm)) * scale
        params[f"l{i}_wo"] = jax.random.normal(k0[3], (dm, dm)) * scale
        params[f"l{i}_ln2_g"] = jnp.ones((dm,))
        params[f"l{i}_ln2_b"] = jnp.zeros((dm,))
        params[f"l{i}_w1"] = jax.random.normal(k0[4], (dm, 4 * dm)) * scale
        params[f"l{i}_b1"] = jnp.zeros((4 * dm,))
        params[f"l{i}_w2"] = jax.random.normal(k0[5], (4 * dm, dm)) * (scale / 2)
        params[f"l{i}_b2"] = jnp.zeros((dm,))
    return params


def init_params(key, cfg: TarFlowConfig):
    """All-block parameters stacked on a leading K axis."""
    block_keys = jax.random.split(key, cfg.blocks)
    blocks = [init_block_params(k, cfg) for k in block_keys]
    return {name: jnp.stack([b[name] for b in blocks]) for name in blocks[0]}


def block_params(params, k):
    """Select block k's parameters (works with traced k via gather)."""
    return {name: v[k] for name, v in params.items()}


def param_count(params) -> int:
    return int(sum(v.size for v in params.values()))


# ---------------------------------------------------------------------------
# Network
# ---------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _split_heads(x, heads):
    b, l, dm = x.shape
    return x.reshape(b, l, heads, dm // heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * dh)


def sg_net(bp, cfg: TarFlowConfig, u, o=0, use_pallas=False):
    """The causal ViT producing (s, g) from the token sequence ``u``.

    Input is shifted right by one internally; output position l depends only
    on u[:, :l] (minus the ``o`` nearest when ``o > 0``, eq 6).

    Returns (s, g), each (B, L, D).
    """
    b, l, d = u.shape
    shifted = jnp.concatenate([jnp.zeros((b, 1, d), u.dtype), u[:, :-1, :]], axis=1)
    x = shifted @ bp["in_w"] + bp["in_b"] + bp["pos"][None, :, :]
    attn_fn = attention.causal_attention if use_pallas else ref.causal_attention_ref
    for i in range(cfg.layers_per_block):
        h = _layernorm(x, bp[f"l{i}_ln1_g"], bp[f"l{i}_ln1_b"])
        q = _split_heads(h @ bp[f"l{i}_wq"], cfg.heads)
        k = _split_heads(h @ bp[f"l{i}_wk"], cfg.heads)
        v = _split_heads(h @ bp[f"l{i}_wv"], cfg.heads)
        a = _merge_heads(attn_fn(q, k, v, o))
        x = x + a @ bp[f"l{i}_wo"]
        h = _layernorm(x, bp[f"l{i}_ln2_g"], bp[f"l{i}_ln2_b"])
        h = jax.nn.gelu(h @ bp[f"l{i}_w1"] + bp[f"l{i}_b1"]) @ bp[f"l{i}_w2"] + bp[f"l{i}_b2"]
        x = x + h
    x = _layernorm(x, bp["lnf_g"], bp["lnf_b"])
    out = x @ bp["out_w"] + bp["out_b"]
    s_raw, g = out[..., :d], out[..., d:]
    # Bounded log-scale for stability (TarFlow clamps similarly).
    s = 2.0 * jnp.tanh(s_raw / 2.0)
    return s, g


def sg_net_trunc(bp, cfg: TarFlowConfig, u):
    """Truncated (s, g) conditioner for speculative initialization: the
    causal ViT of :func:`sg_net` with every transformer layer skipped —
    in-proj + pos-emb → final LN → out-proj only.

    Costs O(L·D·Dm) with no attention, so it is cheap enough to run once
    per block as a z⁰ *predictor*; because it shares the in/out projections
    and final LN with the exact net, its (s, g) track the exact net's
    low-order response. Still strictly causal (the shift makes position l
    depend on u[:, l-1] only), though causality is not load-bearing here —
    the output only seeds the Jacobi iteration, which converges to the
    exact inverse from any z⁰ (Prop 3.2).

    Returns (s, g), each (B, L, D).
    """
    b, l, d = u.shape
    shifted = jnp.concatenate([jnp.zeros((b, 1, d), u.dtype), u[:, :-1, :]], axis=1)
    x = shifted @ bp["in_w"] + bp["in_b"] + bp["pos"][None, :, :]
    x = _layernorm(x, bp["lnf_g"], bp["lnf_b"])
    out = x @ bp["out_w"] + bp["out_b"]
    s_raw, g = out[..., :d], out[..., d:]
    s = 2.0 * jnp.tanh(s_raw / 2.0)
    return s, g


# ---------------------------------------------------------------------------
# Block-level fwd / inverse pieces (AR domain — no permutation here)
# ---------------------------------------------------------------------------

def block_forward(params, cfg: TarFlowConfig, k, u, use_pallas=False):
    """v = A_k(u): encode-direction transform of one block + logdet."""
    bp = block_params(params, k)
    s, g = sg_net(bp, cfg, u, o=0, use_pallas=use_pallas)
    return ref.affine_forward_ref(u, s, g)


def block_jacobi_step(params, cfg: TarFlowConfig, k, z_prev, y, o, use_pallas=True):
    """One parallel Jacobi update of A_k(z) = y (Alg 1 body) + residual.

    This is the serving hot path: the (s, g) net runs on the *previous
    iterate* and the fused L1 kernel applies the inverse update and computes
    the ‖·‖∞ stopping residual.
    """
    bp = block_params(params, k)
    s, g = sg_net(bp, cfg, z_prev, o=o, use_pallas=use_pallas)
    if use_pallas:
        z_next, resid = affine_update.affine_inverse_update(z_prev, y, s, g)
    else:
        z_next, resid = ref.affine_inverse_update_ref(z_prev, y, s, g)
    return z_next, resid


def block_jacobi_step_window(params, cfg: TarFlowConfig, k, z_prev, y, off, wlen,
                             use_pallas=True):
    """One windowed Jacobi update of A_k(z) = y — the GS-Jacobi inner step.

    Identical to :func:`block_jacobi_step` (with ``o = 0``, the exact update)
    except that only positions in ``[off, off+wlen)`` move: positions left of
    ``off`` are the frozen converged prefix (they still condition the (s, g)
    net), positions right of the window are copied through untouched, and the
    residual is taken over the active window only. Sweeping windows left to
    right (Gauss–Seidel) while iterating this step inside each window is
    exact after ``wlen`` iterations per window (Prop 3.2 applied to the
    window, given an exact prefix).
    """
    bp = block_params(params, k)
    s, g = sg_net(bp, cfg, z_prev, o=0, use_pallas=use_pallas)
    if use_pallas:
        z_next, resid = affine_update.affine_inverse_update_window(
            z_prev, y, s, g, off, wlen)
    else:
        z_next, resid = ref.affine_inverse_update_window_ref(z_prev, y, s, g, off, wlen)
    return z_next, resid


def block_init_proj(params, cfg: TarFlowConfig, k, y, use_pallas=True):
    """Speculative z⁰ prediction for the Jacobi solve of ``A_k(z) = y``.

    Runs the truncated conditioner (:func:`sg_net_trunc`) on the block input
    and applies one affine inverse extrapolation — effectively a single
    cut-rate Jacobi step from ``z = y``. The result is only a *seed*: the
    exact drivers iterate from it and remain bit-exact at τ = 0 (Prop 3.2
    holds from any starting iterate), so a bad prediction costs iterations,
    never correctness. Single output → lowered ``untupled`` so the rust
    side can chain it device-side into the decode with zero host traffic.
    """
    bp = block_params(params, k)
    s, g = sg_net_trunc(bp, cfg, y)
    if use_pallas:
        return affine_update.init_extrapolate(y, s, g)
    return ref.init_extrapolate_ref(y, s, g)


def block_jacobi_multi_step(params, cfg: TarFlowConfig, k, z_prev, y, steps,
                            s_max, use_pallas=True):
    """Up to ``steps`` fused Jacobi updates of ``A_k(z) = y`` in ONE lowered
    program (``lax.fori_loop`` around :func:`block_jacobi_step`), recording
    the per-iteration residual history.

    This is the chunked serving hot path: instead of one artifact dispatch +
    one ``[B]`` residual sync per iteration, the rust driver requests a whole
    *chunk* of iterations and syncs one ``[s_max, B]`` residual history per
    chunk, then scans it host-side to recover exact per-iteration τ-stopping
    semantics (see ``rust/src/coordinator/jacobi.rs``). Always the exact
    (``o = 0``) update — masked eq-6 decodes fall back to the per-step
    artifact, like the windowed step.

    Args:
      z_prev, y: (B, L, D)
      steps: i32 scalar (traced) — iterations to run, clamped to ``s_max``
      s_max: python int — static history length baked into the artifact

    Returns:
      (z (B, L, D) after ``min(steps, s_max)`` updates,
       resid_hist (s_max, B) — row ``i`` is the residual after update
       ``i + 1``; rows ≥ ``steps`` keep the −1 "not run" sentinel)
    """
    b = z_prev.shape[0]
    hist0 = jnp.full((s_max, b), -1.0, jnp.float32)
    steps = jnp.clip(jnp.asarray(steps, jnp.int32), 0, s_max)

    def body(i, carry):
        z, hist = carry
        z_next, resid = block_jacobi_step(params, cfg, k, z, y, 0,
                                          use_pallas=use_pallas)
        hist = jax.lax.dynamic_update_slice(hist, resid[None, :], (i, 0))
        return z_next, hist

    return jax.lax.fori_loop(0, steps, body, (z_prev, hist0))


def block_jacobi_multi_step_window(params, cfg: TarFlowConfig, k, z_prev, y,
                                   steps, off, wlen, s_max, use_pallas=True):
    """Windowed counterpart of :func:`block_jacobi_multi_step`: up to
    ``steps`` fused GS-Jacobi inner updates (:func:`block_jacobi_step_window`)
    with the window pinned at ``[off, off+wlen)``, plus the per-iteration
    windowed-residual history. Same contract as the plain fused step
    otherwise (``steps`` clamped to ``s_max``, −1 sentinel rows)."""
    b = z_prev.shape[0]
    hist0 = jnp.full((s_max, b), -1.0, jnp.float32)
    steps = jnp.clip(jnp.asarray(steps, jnp.int32), 0, s_max)

    def body(i, carry):
        z, hist = carry
        z_next, resid = block_jacobi_step_window(params, cfg, k, z, y, off,
                                                 wlen, use_pallas=use_pallas)
        hist = jax.lax.dynamic_update_slice(hist, resid[None, :], (i, 0))
        return z_next, hist

    return jax.lax.fori_loop(0, steps, body, (z_prev, hist0))


def block_inverse_exact(params, cfg: TarFlowConfig, k, y, use_pallas=False):
    """Exact sequential inverse u = A_k^{-1}(y) via L Jacobi steps
    (Prop 3.2: the iteration is exact after L steps). Build-time only —
    used by tests and by the encode/decode consistency checks."""
    z = jnp.zeros_like(y)
    for _ in range(cfg.seq_len):
        z, _ = block_jacobi_step(params, cfg, k, z, y, 0, use_pallas=use_pallas)
    return z


# ---------------------------------------------------------------------------
# Sequential decode step with KV cache
# ---------------------------------------------------------------------------

def block_seq_step(params, cfg: TarFlowConfig, k, u_prev, v_tok, pos, kv_k, kv_v):
    """One token of the sequential (KV-cached) inverse of block k.

    Net position ``pos`` holds token u_{pos-1} (``u_prev``; zeros for
    pos = 0). Writes this position's per-layer K/V into the caches, attends
    over cache[0..pos], and produces u_pos = v_pos·exp(−s)+g (v_pos for
    pos = 0).

    Args:
      u_prev: (B, D)   token u_{pos-1}
      v_tok:  (B, D)   block input y at position pos
      pos:    i32 scalar
      kv_k, kv_v: (NL, B, L, Dm) caches

    Returns:
      (u_tok (B, D), kv_k', kv_v')
    """
    bp = block_params(params, k)
    b, d = u_prev.shape
    nl, _, l, dm = kv_k.shape
    heads = cfg.heads
    dh = dm // heads

    x = u_prev @ bp["in_w"] + bp["in_b"] + bp["pos"][pos][None, :]  # (B, Dm)
    positions = jnp.arange(l)
    attend = (positions <= pos)[None, None, :]  # (1, 1, L)

    for i in range(cfg.layers_per_block):
        h = _layernorm(x, bp[f"l{i}_ln1_g"], bp[f"l{i}_ln1_b"])
        q = (h @ bp[f"l{i}_wq"]).reshape(b, heads, dh)
        k_new = h @ bp[f"l{i}_wk"]  # (B, Dm)
        v_new = h @ bp[f"l{i}_wv"]
        kv_k = jax.lax.dynamic_update_slice(kv_k, k_new[None, :, None, :], (i, 0, pos, 0))
        kv_v = jax.lax.dynamic_update_slice(kv_v, v_new[None, :, None, :], (i, 0, pos, 0))
        keys = kv_k[i].reshape(b, l, heads, dh).transpose(0, 2, 1, 3)   # (B, H, L, Dh)
        vals = kv_v[i].reshape(b, l, heads, dh).transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhd,bhld->bhl", q, keys) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        scores = jnp.where(attend, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        a = jnp.einsum("bhl,bhld->bhd", w, vals).reshape(b, dm)
        x = x + a @ bp[f"l{i}_wo"]
        h = _layernorm(x, bp[f"l{i}_ln2_g"], bp[f"l{i}_ln2_b"])
        h = jax.nn.gelu(h @ bp[f"l{i}_w1"] + bp[f"l{i}_b1"]) @ bp[f"l{i}_w2"] + bp[f"l{i}_b2"]
        x = x + h
    x = _layernorm(x, bp["lnf_g"], bp["lnf_b"])
    out = x @ bp["out_w"] + bp["out_b"]
    s_raw, g = out[..., :d], out[..., d:]
    s = 2.0 * jnp.tanh(s_raw / 2.0)
    u_tok = v_tok * jnp.exp(-s) + g
    u_tok = jnp.where(pos == 0, v_tok, u_tok)
    return u_tok, kv_k, kv_v


def block_seq_full(params, cfg: TarFlowConfig, k, v):
    """Whole-block sequential inverse as ONE lowered program (lax.scan over
    positions, KV cache carried in the loop state).

    §Perf ablation: this removes all per-token call/marshal overhead from the
    sequential path — a *stronger* baseline than the paper's per-step eager
    implementation (and than `block_seq_step` driven from rust). On serial
    hardware it bounds what any sequential implementation could achieve.

    Args:
      v: (B, L, D) block input y.

    Returns:
      u: (B, L, D) = A_k^{-1}(v).
    """
    bp = block_params(params, k)
    b, l, d = v.shape
    nl, dm = cfg.layers_per_block, cfg.model_dim

    kv_k0 = jnp.zeros((nl, b, l, dm))
    kv_v0 = jnp.zeros((nl, b, l, dm))
    u0 = jnp.zeros((b, d))

    def step(carry, pos):
        u_prev, kv_k, kv_v = carry
        v_tok = jax.lax.dynamic_slice(v, (0, pos, 0), (b, 1, d))[:, 0, :]
        u_tok, kv_k, kv_v = block_seq_step(params, cfg, k, u_prev, v_tok, pos, kv_k, kv_v)
        return (u_tok, kv_k, kv_v), u_tok

    (_, _, _), toks = jax.lax.scan(step, (u0, kv_k0, kv_v0), jnp.arange(l))
    return toks.transpose(1, 0, 2)  # (L, B, D) → (B, L, D)


# ---------------------------------------------------------------------------
# Patchify + full-flow composition (encode direction)
# ---------------------------------------------------------------------------

def patchify(x, cfg: TarFlowConfig):
    """(B, H, W, C) → (B, L, D); must match `Sampler::patchify` in rust."""
    b = x.shape[0]
    hp = cfg.img_hw // cfg.patch
    x = x.reshape(b, hp, cfg.patch, hp, cfg.patch, cfg.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, hp * hp, cfg.token_dim)


def unpatchify(t, cfg: TarFlowConfig):
    """(B, L, D) → (B, H, W, C)."""
    b = t.shape[0]
    hp = cfg.img_hw // cfg.patch
    t = t.reshape(b, hp, hp, cfg.patch, cfg.patch, cfg.channels)
    t = t.transpose(0, 1, 3, 2, 4, 5)
    return t.reshape(b, cfg.img_hw, cfg.img_hw, cfg.channels)


def flow_forward(params, cfg: TarFlowConfig, x, use_pallas=False):
    """Full encode: images → (z tokens, total logdet).

    h_{k+1} = A_k(P_k h_k), P_k = token reversal for odd k (matches the rust
    decode composition exactly; cross-checked in integration tests).
    """
    h = patchify(x, cfg)
    logdet = jnp.zeros((x.shape[0],))
    for k in range(cfg.blocks):
        u = h[:, ::-1, :] if k % 2 == 1 else h
        h, ld = block_forward(params, cfg, k, u, use_pallas=use_pallas)
        logdet = logdet + ld
    return h, logdet


def nll_loss(params, cfg: TarFlowConfig, x):
    """Negative log-likelihood (nats/dim) under the standard-normal base."""
    z, logdet = flow_forward(params, cfg, x)
    dims = z.shape[1] * z.shape[2]
    log_prior = -0.5 * jnp.sum(z ** 2, axis=(1, 2)) - 0.5 * dims * jnp.log(2 * jnp.pi)
    return -(log_prior + logdet).mean() / dims


@functools.partial(jax.jit, static_argnames=("cfg",))
def nll_loss_jit(params, cfg: TarFlowConfig, x):
    return nll_loss(params, cfg, x)
