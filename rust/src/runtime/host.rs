//! Host-side tensor values that cross the rust ⇄ PJRT boundary.

use anyhow::{bail, Context, Result};

/// A host tensor: either `f32` or `i32` data plus a shape.
///
/// This is deliberately minimal — the richer [`crate::tensor::Tensor`] type is
/// used for coordinator-side math; `HostTensor` only packs/unpacks literals.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    /// Scalar i32 (used for block indices, positions, mask offsets).
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    /// Scalar f32.
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Single f32 element of a scalar/1-element tensor.
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("item_f32 on tensor with {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Convert to an xla literal (single copy straight into the literal's
    /// storage — the naive `vec1(..).reshape(..)` path copies twice, which
    /// showed up in the §Perf marshal profile).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32 { shape, data } => {
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )
                .context("create f32 literal")
            }
            HostTensor::I32 { shape, data } => {
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    bytes,
                )
                .context("create i32 literal")
            }
        }
    }

    /// Convert back from an xla literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => bail!("unsupported artifact output element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32_literal() {
        let t = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i32_scalar() {
        let t = HostTensor::scalar_i32(7);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7]);
        assert!(back.shape().is_empty());
    }

    #[test]
    fn type_mismatch_errors() {
        let t = HostTensor::scalar_i32(1);
        assert!(t.as_f32().is_err());
        let t = HostTensor::scalar_f32(1.0);
        assert!(t.as_i32().is_err());
    }
}
