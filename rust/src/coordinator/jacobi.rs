//! Jacobi decoding driver (paper Alg 1) and its windowed GS-Jacobi variant.
//!
//! One Jacobi *step* is an AOT artifact call `(k, z_t, y) → (z_{t+1}, resid)`
//! that updates every position of the sequence in parallel from the previous
//! iterate (the L1 Pallas hot path). This driver owns the L3 concerns: the
//! initialization strategy, the τ stopping rule on ‖z^t − z^{t−1}‖∞, the
//! worst-case `L` iteration guard (Prop 3.2 guarantees exactness at `t = L`),
//! and per-layer statistics for the selective policy / paper tables.
//!
//! All drivers are **device-resident** (see `docs/ARCHITECTURE.md` for the
//! full residency map): the block input `y` and the loop scalars are uploaded
//! once, the iterate `z` chains device→device across iterations, and the only
//! per-iteration host sync is the `[B]` residual needed for the τ test.
//! [`jacobi_decode_block`] is the host-tensor convenience wrapper.
//!
//! ## Fused multi-step chunking ([`jacobi_decode_block_fused_v`])
//!
//! The paper's superlinear convergence (Thm 3.3) collapses iteration counts,
//! which makes the per-iteration host round-trip — one artifact dispatch plus
//! one blocking `[B]` residual sync per step — the dominant non-compute cost
//! of the loops above. The fused path removes it: the
//! `{m}_block_jstep_fuse_b{B}` artifact runs up to `steps` Jacobi updates in
//! ONE lowered program (a `lax.fori_loop` around the jstep body) and returns
//! the iterate plus a `[S_max, B]` **residual history**, one row per update.
//! The driver's [`ChunkScheduler`] requests whole chunks of iterations —
//! first chunk seeded from a calibrated per-block hint, later chunks sized
//! from the observed contraction rate, dropping to single steps near τ — and
//! scans each returned history host-side, so the reported per-iteration
//! semantics (`iterations`, `residuals`, τ stopping, Prop 3.2 caps) are
//! identical to the per-iteration driver while host syncs fall from
//! `iterations` to `⌈iterations/S⌉` ([`JacobiStats::host_syncs`]).
//!
//! Exactness: τ = 0 decodes are **bit-exact** with the per-iteration driver
//! (no early stop exists, so the chunks partition the very same update
//! sequence). A τ > 0 stop that lands mid-chunk leaves the returned iterate
//! up to `S − 1` cheap on-device updates *past* the τ crossing — extra
//! contraction toward the same fixed point, never counted in `iterations`.
//! The windowed counterpart ([`gs_jacobi_decode_block_fused_v`]) chunks the
//! GS-Jacobi inner loop the same way via `{m}_block_jstep_win_fuse_b{B}`.
//!
//! ## Windowed GS-Jacobi ([`gs_jacobi_decode_block_v`])
//!
//! Full-sequence Jacobi keeps re-updating positions that converged many
//! iterations ago (early positions are exact after Prop 3.2's induction
//! reaches them). The GS-Jacobi variant (after "Accelerate TarFlow Sampling
//! with GS-Jacobi Iteration", arXiv 2505.12849) partitions the `L` positions
//! into `W` contiguous windows, sweeps the windows **in order**
//! (Gauss–Seidel: window `w` conditions on the already-converged windows
//! `< w`) and iterates Jacobi only **inside** the active window via the
//! `{m}_block_jstep_win_b{B}` artifact, which freezes every position outside
//! `[off, off+len)` and reports the residual over the window only. The
//! per-window iteration cap is the window length — Prop 3.2 applied to the
//! window given an exact prefix — so the sweep with τ = 0 is *exact*, and
//! `W = 1` degrades to plain Jacobi while `W = L` degrades to sequential
//! decoding (one exact iteration per position). Total work is measured in
//! **position-updates** (Σ over windows of `iterations × len`), with two
//! savings regimes: strongly coupled blocks (iterations ≈ `L`) cut from
//! `O(L²)` toward `O(L²/W)` at any window count, while weakly coupled
//! blocks (`t ≪ L` iterations) save only once the window length drops
//! below `t` — the per-window cap then bounds updates by `len·L < t·L`, at
//! the price of more artifact calls. [`calibrate_windows`] picks per-block
//! window counts along exactly this trade-off.
//!
//! [`calibrate_windows`]: super::policy::calibrate_windows

use super::state::BufferPool;
use crate::runtime::{Backend, HostTensor, Value};
use crate::tensor::Pcg64;
use anyhow::{ensure, Context, Result};
use std::time::{Duration, Instant};

/// How `z⁰` is initialized: the paper's Fig 6 ablation strategies plus the
/// speculative *init providers* (predicted z⁰, per PJD's observation that
/// iteration counts are mostly an initialization-quality effect).
///
/// Prop 3.2 holds from **any** starting iterate, so every variant decodes
/// bit-exactly at τ = 0 — a bad prediction costs iterations, never
/// correctness. The speculative variants' predictions are produced by the
/// `Sampler` (which owns the artifacts and warm cache) and threaded into
/// the drivers through the `z0: Option<Value>` hook; when no prediction is
/// available the drivers fall back to Zeros.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitStrategy {
    /// `z⁰ = 0` (paper default, Alg 1).
    Zeros,
    /// `z⁰ ~ N(0, I)`.
    Normal,
    /// `z⁰ = z_{k+1}` (previous layer's output — the Jacobi input itself).
    PrevLayer,
    /// Cross-block extrapolation: `z⁰` predicted from the block input by the
    /// lowered `{m}_init_proj_b{B}` projection artifact (truncated
    /// conditioner + one affine extrapolation, device-resident end to end).
    Proj,
    /// Draft-then-refine: a coarse-τ fused draft pass produces a
    /// full-sequence guess whose per-block states seed the exact refine
    /// pass.
    Draft,
    /// Warm-start: `z⁰` from the per-bucket LRU cache of converged latents
    /// keyed by (seed family, decode position); miss ⇒ Zeros.
    Warm,
}

impl InitStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "zeros" => Some(InitStrategy::Zeros),
            "normal" => Some(InitStrategy::Normal),
            "prev" | "prev_layer" => Some(InitStrategy::PrevLayer),
            "proj" | "extrapolate" => Some(InitStrategy::Proj),
            "draft" => Some(InitStrategy::Draft),
            "warm" | "cache" => Some(InitStrategy::Warm),
            _ => None,
        }
    }

    /// Canonical spelling — the inverse of [`InitStrategy::parse`], used by
    /// the policy JSON round trip and the metrics/CLI surfaces.
    pub fn label(&self) -> &'static str {
        match self {
            InitStrategy::Zeros => "zeros",
            InitStrategy::Normal => "normal",
            InitStrategy::PrevLayer => "prev",
            InitStrategy::Proj => "proj",
            InitStrategy::Draft => "draft",
            InitStrategy::Warm => "warm",
        }
    }

    /// Whether this strategy predicts z⁰ from prior decode state (and is
    /// therefore subject to the tuner's payoff gating), as opposed to the
    /// Fig 6 constant initializations.
    pub fn is_speculative(&self) -> bool {
        matches!(self, InitStrategy::Proj | InitStrategy::Draft | InitStrategy::Warm)
    }
}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct JacobiConfig {
    /// Stopping threshold τ on ‖z^t − z^{t−1}‖∞ (paper default 0.5).
    pub tau: f32,
    /// Hard iteration cap for the whole block; `None` ⇒ the sequence length
    /// `L` (Prop 3.2 bound). GS-Jacobi treats it as the same *total* budget,
    /// shared across all windows (each window is additionally capped at its
    /// own length).
    pub max_iters: Option<usize>,
    pub init: InitStrategy,
    /// Seed for `InitStrategy::Normal`.
    pub seed: u64,
}

impl Default for JacobiConfig {
    fn default() -> Self {
        JacobiConfig { tau: 0.5, max_iters: None, init: InitStrategy::Zeros, seed: 0 }
    }
}

/// Statistics of one Jacobi decode of one block.
#[derive(Clone, Debug)]
pub struct JacobiStats {
    pub block: usize,
    pub iterations: usize,
    pub wall: Duration,
    /// Residual ‖z^t − z^{t−1}‖∞ after each iteration.
    pub residuals: Vec<f32>,
    /// Whether the τ criterion was reached (vs hitting the iteration cap).
    pub converged: bool,
    /// Blocking host syncs the decode performed for its τ tests: one per
    /// iteration on the per-iteration driver, one per *chunk*
    /// (`⌈iterations/S⌉` at a fixed chunk size `S`) on the fused driver —
    /// the quantity [`jacobi_decode_block_fused_v`] exists to shrink. The
    /// final iterate fetch is the caller's sync and is not counted here.
    pub host_syncs: usize,
}

/// Decode block `k` by Jacobi iteration, keeping the iterate device-resident.
///
/// `y` is the block input `z_{k+1}` with shape (B, L, D) — host values are
/// uploaded exactly once, device values are used in place (the block-chaining
/// path of `Sampler::decode_tokens`). The artifact
/// `{model}_block_jstep_b{B}` computes one parallel update plus the residual
/// max over the batch; per iteration only that `[B]` residual crosses to the
/// host. The final iterate is returned still device-resident. `mask_o > 0`
/// applies the paper's eq-6 dependency mask (used for the Fig 1/2 redundancy
/// experiments); `mask_o = 0` is the exact update of Alg 1.
pub fn jacobi_decode_block_v<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
) -> Result<(Value, JacobiStats)> {
    jacobi_decode_block_v_init(engine, artifact, block, y, seq_len, cfg, mask_o, None, None)
}

/// [`jacobi_decode_block_v`] with an optional pre-built initial iterate and
/// an optional [`BufferPool`] for pinned loop constants.
///
/// When `z0` is provided it is used as `z⁰` verbatim — the caller must make
/// it consistent with `cfg.init` (the `Sampler` passes its pool's cached
/// device zeros for `InitStrategy::Zeros`, turning the per-block z⁰ upload
/// into one upload per process lifetime). When `pool` is provided, the
/// scalar loop constants (`k`, `mask_o`) come from its
/// [`BufferPool::device_scalar_i32`] cache instead of fresh per-block
/// uploads.
#[allow(clippy::too_many_arguments)]
pub fn jacobi_decode_block_v_init<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
    z0: Option<Value>,
    pool: Option<&BufferPool>,
) -> Result<(Value, JacobiStats)> {
    let t0 = Instant::now();
    let (y_dev, k_scalar, mut z) = pin_decode_inputs(engine, pool, block, y, cfg, z0)?;
    let o_scalar = pin_scalar_i32(engine, pool, mask_o as i32)?;

    let cap = cfg.max_iters.unwrap_or(seq_len);
    let mut residuals = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    while iterations < cap {
        let outs = engine.call_v(
            artifact,
            &[k_scalar.clone(), z, y_dev.clone(), o_scalar.clone()],
        )?;
        let mut it = outs.into_iter();
        let z_next = it.next().context("jstep returns z'")?;
        let resid_v = it.next().context("jstep returns residual")?;
        // The τ test is the only per-iteration sync: a [B] residual vector.
        let resid =
            engine.to_host(resid_v)?.as_f32()?.iter().copied().fold(0.0f32, f32::max);
        residuals.push(resid);
        z = z_next;
        iterations += 1;
        if resid < cfg.tau {
            converged = true;
            break;
        }
    }

    Ok((
        z,
        JacobiStats {
            block,
            iterations,
            wall: t0.elapsed(),
            residuals,
            converged,
            host_syncs: iterations,
        },
    ))
}

/// Host-tensor convenience wrapper over [`jacobi_decode_block_v`]: uploads
/// `y`, decodes, and syncs the final iterate back.
pub fn jacobi_decode_block<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &HostTensor,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
) -> Result<(HostTensor, JacobiStats)> {
    let (z, stats) = jacobi_decode_block_v(
        engine,
        artifact,
        block,
        &Value::Host(y.clone()),
        seq_len,
        cfg,
        mask_o,
    )?;
    Ok((engine.to_host(z)?, stats))
}

/// Pin an i32 scalar loop constant on device: through the pool's
/// once-per-value cache when a [`BufferPool`] is supplied (the `Sampler`
/// path — `k`, `mask_o`, window offsets/lengths and chunk sizes repeat
/// across blocks and requests), else a fresh upload (standalone driver
/// calls in tests/benches).
fn pin_scalar_i32<B: Backend>(
    engine: &B,
    pool: Option<&BufferPool>,
    v: i32,
) -> Result<Value> {
    match pool {
        Some(p) => p.device_scalar_i32(v, |t| engine.to_device(t)),
        None => engine.to_device(&HostTensor::scalar_i32(v)),
    }
}

/// Pin a block decode's loop constants on device and build its initial
/// iterate — shared by all four drivers so their init contracts cannot
/// drift. `y` uploads at most once (device values pass through); `z0`,
/// when supplied, is used verbatim (the `Sampler` passes pooled zeros,
/// speculative predictions, or warm-cache hits here); otherwise
/// `PrevLayer` aliases `y`'s device handle (no upload at all) and
/// Zeros/Normal build z⁰ host-side via the shared [`init_iterate`] (one
/// source of truth). With a [`BufferPool`] the built z⁰ pins through the
/// pool's per-shape zero cache / per-(shape, seed) init cache, so repeated
/// block decodes cost one upload instead of one per decode; speculative
/// strategies with no prediction fall back to the Zeros init. Returns
/// `(y_dev, k_scalar, z)`.
fn pin_decode_inputs<B: Backend>(
    engine: &B,
    pool: Option<&BufferPool>,
    block: usize,
    y: &Value,
    cfg: &JacobiConfig,
    z0: Option<Value>,
) -> Result<(Value, Value, Value)> {
    let y_dev = match y {
        Value::Host(t) => engine.to_device(t)?,
        Value::Device(_) => y.clone(),
    };
    let k_scalar = pin_scalar_i32(engine, pool, block as i32)?;
    let z = match (z0, cfg.init) {
        (Some(z0), _) => z0,
        (None, InitStrategy::PrevLayer) => y_dev.clone(),
        (None, InitStrategy::Normal) => {
            let build = || {
                let proto = HostTensor::f32(y_dev.shape(), vec![0.0; y_dev.numel()]);
                engine.to_device(&init_iterate(&proto, cfg))
            };
            match pool {
                Some(p) => p.device_init(y_dev.shape(), cfg.seed, build)?,
                None => build()?,
            }
        }
        // Zeros, and the speculative strategies' documented fallback when
        // the caller produced no prediction.
        (None, _) => match pool {
            Some(p) => p.device_zeroed(y_dev.shape(), |t| engine.to_device(t))?,
            None => {
                engine.to_device(&HostTensor::f32(y_dev.shape(), vec![0.0; y_dev.numel()]))?
            }
        },
    };
    Ok((y_dev, k_scalar, z))
}

// ---------------------------------------------------------------------------
// Fused multi-step chunking
// ---------------------------------------------------------------------------

/// Adaptive chunk sizer for the fused multi-step drivers (module docs).
///
/// Decides how many on-device Jacobi updates the next
/// `{m}_block_jstep[_win]_fuse_b{B}` call should run. Inputs to the
/// decision: the calibrated first-chunk `hint` (a measured per-block
/// iteration count lands the very first chunk exactly on the τ crossing),
/// the device-side history cap `S_max` (discovered from the first returned
/// `[S, B]` history — never assumed), and the residual trajectory so far.
/// With τ = 0 no early stop exists, so chunks are maximal; with τ > 0 the
/// observed contraction rate ρ = r_t/r_{t−1} predicts the iterations left
/// to τ and the scheduler approaches the crossing conservatively
/// (prediction − 1, then single steps) so an accurate trajectory stops on
/// the exact τ-crossing iterate; an overshoot costs at most `S − 1` cheap
/// on-device updates but never a host round-trip.
#[derive(Clone, Debug)]
pub struct ChunkScheduler {
    tau: f32,
    hint: usize,
    /// Device history cap; `usize::MAX` until the first history is seen.
    s_max: usize,
    /// Last issued chunk (geometric-ramp state).
    last: usize,
}

impl ChunkScheduler {
    pub fn new(first_chunk_hint: usize, tau: f32) -> Self {
        ChunkScheduler { tau, hint: first_chunk_hint.max(1), s_max: usize::MAX, last: 0 }
    }

    /// Record the device history cap observed on a returned `[S, B]` history.
    pub fn observe_cap(&mut self, s_max: usize) {
        self.s_max = s_max.max(1);
    }

    /// Size of the next chunk, never exceeding `remaining` (the τ/Prop 3.2
    /// budget left) or the device cap; 0 only when `remaining` is 0.
    /// `residuals` is the per-iteration trajectory observed so far.
    pub fn next_chunk(&mut self, remaining: usize, residuals: &[f32]) -> usize {
        let cap = remaining.min(self.s_max);
        if cap == 0 {
            return 0;
        }
        let want = if residuals.is_empty() {
            self.hint
        } else if self.tau <= 0.0 {
            // τ = 0 can never stop early: run maximal chunks.
            cap
        } else if let Some(need) = self.predict_remaining(residuals) {
            // 1-step refinement near τ; otherwise stay one short of the
            // prediction so an accurate trajectory finishes with an exact
            // single-step stop instead of an overshoot.
            if need <= 2 {
                1
            } else {
                need - 1
            }
        } else {
            // No contraction signal (residual flat or growing): ramp
            // geometrically toward the cap.
            self.last.max(1).saturating_mul(2)
        };
        self.last = want.clamp(1, cap);
        self.last
    }

    /// Predicted iterations still needed to cross τ, from the last two
    /// residuals under a geometric-contraction model; `None` when the
    /// trajectory gives no usable signal.
    fn predict_remaining(&self, residuals: &[f32]) -> Option<usize> {
        let n = residuals.len();
        if n < 2 {
            return None;
        }
        let (r_prev, r_last) = (residuals[n - 2] as f64, residuals[n - 1] as f64);
        if !(r_last > 0.0 && r_last < r_prev) {
            return None;
        }
        let rho = r_last / r_prev;
        let need = ((self.tau as f64).ln() - r_last.ln()) / rho.ln();
        if !need.is_finite() {
            return None;
        }
        Some(need.ceil().max(1.0) as usize)
    }
}

/// Dimensions of a fused-step `[S_max, B]` residual history.
fn hist_dims(hist: &HostTensor) -> Result<(usize, usize)> {
    let shape = hist.shape();
    ensure!(
        shape.len() == 2 && shape[0] > 0 && shape[1] > 0,
        "fused resid_hist must be [S, B] with S, B >= 1, got {shape:?}"
    );
    Ok((shape[0], shape[1]))
}

/// Decode block `k` via the fused multi-step artifact
/// `{m}_block_jstep_fuse_b{B}`: `(k, z_t, y, steps) → (z', resid_hist)`
/// (always the exact `o = 0` update — masked decodes use the per-step
/// driver).
///
/// Chunked per-iteration-equivalent decode (module docs): per chunk, one
/// dispatch and one `[S_max, B]` history sync replace up to `S_max`
/// dispatch+sync round-trips; the history is scanned host-side so
/// `iterations`/`residuals`/`converged` match [`jacobi_decode_block_v_init`]
/// exactly, while [`JacobiStats::host_syncs`] counts chunks. `first_chunk`
/// seeds the [`ChunkScheduler`] (a calibrated per-block iteration count
/// makes single-chunk decodes the common case). Residency contract is
/// unchanged: `y` and scalars pin once, the iterate chains device→device.
#[allow(clippy::too_many_arguments)]
pub fn jacobi_decode_block_fused_v<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    cfg: &JacobiConfig,
    z0: Option<Value>,
    pool: Option<&BufferPool>,
    first_chunk: usize,
) -> Result<(Value, JacobiStats)> {
    let t0 = Instant::now();
    let (y_dev, k_scalar, mut z) = pin_decode_inputs(engine, pool, block, y, cfg, z0)?;

    let cap = cfg.max_iters.unwrap_or(seq_len);
    let mut sched = ChunkScheduler::new(first_chunk, cfg.tau);
    let mut residuals = Vec::new();
    let mut converged = false;
    let mut host_syncs = 0;
    let mut done = 0;
    while !converged && done < cap {
        let chunk = sched.next_chunk(cap - done, &residuals);
        let steps_scalar = pin_scalar_i32(engine, pool, chunk as i32)?;
        let outs = engine.call_v(
            artifact,
            &[k_scalar.clone(), z, y_dev.clone(), steps_scalar],
        )?;
        let mut it = outs.into_iter();
        z = it.next().context("jstep_fuse returns z'")?;
        let hist_v = it.next().context("jstep_fuse returns resid_hist")?;
        // One [S_max, B] history sync per chunk — the only blocking host
        // traffic of the whole decode.
        let hist = engine.to_host(hist_v)?;
        host_syncs += 1;
        let (s_max, b) = hist_dims(&hist)?;
        sched.observe_cap(s_max);
        // The artifact clamps `steps` to its lowered history length; only
        // rows the chunk actually ran carry residuals.
        let ran = chunk.min(s_max);
        ensure!(ran > 0, "fused chunk ran zero steps (artifact '{artifact}')");
        done += ran;
        let data = hist.as_f32()?;
        for row in 0..ran {
            let resid =
                data[row * b..(row + 1) * b].iter().copied().fold(0.0f32, f32::max);
            residuals.push(resid);
            if resid < cfg.tau {
                converged = true;
                break;
            }
        }
    }

    Ok((
        z,
        JacobiStats {
            block,
            iterations: residuals.len(),
            wall: t0.elapsed(),
            residuals,
            converged,
            host_syncs,
        },
    ))
}

/// Partition `seq_len` positions into `windows` contiguous windows, as
/// evenly as possible (the first `seq_len % windows` windows get one extra
/// position). `windows` is clamped to `1..=seq_len`, so `W = 0` behaves as
/// one full-sequence window and `W > L` as one window per position.
pub fn window_partition(seq_len: usize, windows: usize) -> Vec<(usize, usize)> {
    if seq_len == 0 {
        return Vec::new();
    }
    let w = windows.clamp(1, seq_len);
    let (base, rem) = (seq_len / w, seq_len % w);
    let mut out = Vec::with_capacity(w);
    let mut off = 0;
    for i in 0..w {
        let len = base + usize::from(i < rem);
        out.push((off, len));
        off += len;
    }
    out
}

/// Statistics of one window of a GS-Jacobi decode.
#[derive(Clone, Debug)]
pub struct WindowStats {
    /// First position of the window.
    pub offset: usize,
    /// Number of positions in the window.
    pub len: usize,
    /// Jacobi iterations spent inside the window.
    pub iterations: usize,
    /// Batch-max windowed residual after each iteration.
    pub residuals: Vec<f32>,
    /// Whether every batch element reached τ (vs hitting the `len` cap).
    pub converged: bool,
    /// Per batch element: the iteration (1-based) at which its windowed
    /// residual first fell below τ; `None` = the window relied on the
    /// exactness cap for that element.
    pub converged_at: Vec<Option<usize>>,
}

/// Statistics of one GS-Jacobi decode of one block.
#[derive(Clone, Debug)]
pub struct GsJacobiStats {
    pub block: usize,
    /// Per-window breakdown, in sweep order.
    pub windows: Vec<WindowStats>,
    pub wall: Duration,
    /// Total jstep_win artifact calls (Σ window iterations).
    pub iterations: usize,
    /// Total position-updates performed: Σ over windows of
    /// `iterations × len`. Full-sequence Jacobi costs `iterations × L`; the
    /// saving is the paper-faithful work metric (`benches/gs_windows.rs`).
    pub position_updates: usize,
    /// Whether every batch element's convergence front reached `L` — each
    /// window settled either by τ (the element's final windowed residual)
    /// or by running its full `len`-iteration exactness cap (Prop 3.2 per
    /// window). `false` only when the `max_iters` budget ran out before a
    /// window reached either (per-window τ-vs-cap detail:
    /// [`WindowStats::converged`]).
    pub converged: bool,
    /// Per batch element: the convergence front — positions `< front[b]`
    /// are frozen and final, certified per window by the element's final
    /// residual under τ or by the exactness cap
    /// ([`WindowStats::converged_at`] records first τ crossings for
    /// observability only). The windowed artifact excludes everything left
    /// of the active window from the residual, so a settled prefix never
    /// re-enters the τ test.
    pub front: Vec<usize>,
    /// Blocking host syncs across the whole sweep: one per iteration on the
    /// per-iteration driver, one per chunk on
    /// [`gs_jacobi_decode_block_fused_v`] (see [`JacobiStats::host_syncs`]).
    pub host_syncs: usize,
}

/// Decode block `k` by windowed GS-Jacobi iteration (module docs), keeping
/// the iterate device-resident throughout.
///
/// `artifact` is the windowed step `{m}_block_jstep_win_b{B}`:
/// `(k, z_t, y, off, len) → (z_{t+1}, resid[B])`, where positions outside
/// `[off, off+len)` are copied through and the residual covers the window
/// only. `y` follows the same one-upload contract as
/// [`jacobi_decode_block_v`]; `z0`, when given, is used verbatim (the
/// `Sampler` passes pooled device zeros) and `pool` pins the per-window
/// offset/length scalars through the once-per-value cache. Per iteration
/// only the `[B]` windowed residual syncs to the host.
#[allow(clippy::too_many_arguments)]
pub fn gs_jacobi_decode_block_v<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    windows: usize,
    cfg: &JacobiConfig,
    z0: Option<Value>,
    pool: Option<&BufferPool>,
) -> Result<(Value, GsJacobiStats)> {
    let t0 = Instant::now();
    let (y_dev, k_scalar, mut z) = pin_decode_inputs(engine, pool, block, y, cfg, z0)?;

    let mut stats = GsJacobiStats {
        block,
        windows: Vec::new(),
        wall: Duration::ZERO,
        iterations: 0,
        position_updates: 0,
        converged: false,
        front: Vec::new(),
        host_syncs: 0,
    };
    // `max_iters` keeps its plain-Jacobi meaning — a *total* iteration
    // budget for the block — shared across all windows.
    let mut budget = cfg.max_iters.unwrap_or(usize::MAX);
    for (off, len) in window_partition(seq_len, windows) {
        // An exhausted budget means no remaining window can run a single
        // iteration: stop sweeping (the decode reports unconverged via the
        // front check below) instead of walking the remaining windows just
        // to record empty stats.
        if budget == 0 {
            break;
        }
        // Prop 3.2 applied to the window: with the prefix frozen, `len`
        // iterations are exact — never iterate past that.
        let cap = len.min(budget);
        let mut ws = WindowStats {
            offset: off,
            len,
            iterations: 0,
            residuals: Vec::new(),
            converged: false,
            converged_at: Vec::new(),
        };
        let mut last_resid: Vec<f32> = Vec::new();
        if cap > 0 {
            let off_scalar = pin_scalar_i32(engine, pool, off as i32)?;
            let len_scalar = pin_scalar_i32(engine, pool, len as i32)?;
            while ws.iterations < cap {
                let outs = engine.call_v(
                    artifact,
                    &[
                        k_scalar.clone(),
                        z,
                        y_dev.clone(),
                        off_scalar.clone(),
                        len_scalar.clone(),
                    ],
                )?;
                let mut it = outs.into_iter();
                let z_next = it.next().context("jstep_win returns z'")?;
                let resid_v = it.next().context("jstep_win returns residual")?;
                // The τ test is the only per-iteration sync: a [B] residual.
                let resid = engine.to_host(resid_v)?.as_f32()?.to_vec();
                stats.host_syncs += 1;
                if stats.front.is_empty() {
                    stats.front = vec![0; resid.len()];
                }
                if ws.converged_at.is_empty() {
                    ws.converged_at = vec![None; resid.len()];
                }
                z = z_next;
                ws.iterations += 1;
                let mut max_r = 0.0f32;
                for (b, &r) in resid.iter().enumerate() {
                    if r < cfg.tau && ws.converged_at[b].is_none() {
                        ws.converged_at[b] = Some(ws.iterations);
                    }
                    max_r = max_r.max(r);
                }
                ws.residuals.push(max_r);
                last_resid = resid;
                if max_r < cfg.tau {
                    ws.converged = true;
                    break;
                }
            }
        }
        finish_window(&mut stats, ws, &last_resid, &mut budget, off, len, cfg.tau);
    }
    stats.converged =
        !stats.front.is_empty() && stats.front.iter().all(|&f| f == seq_len);
    stats.wall = t0.elapsed();
    Ok((z, stats))
}

/// Close out one swept window — shared by the per-iteration and fused GS
/// drivers so the certification rule cannot drift between them. Charges the
/// shared iteration budget and the work totals, then advances each batch
/// element's convergence front through windows it settled in, contiguously
/// from the left: its *final* residual under τ, or the full `len`-iteration
/// exactness cap completed (Prop 3.2 ⇒ the window is exact given its
/// settled prefix, even though the last movement exceeded τ). An
/// intermediate dip below τ certifies nothing — the residual is not
/// monotone while window positions still move.
fn finish_window(
    stats: &mut GsJacobiStats,
    ws: WindowStats,
    last_resid: &[f32],
    budget: &mut usize,
    off: usize,
    len: usize,
    tau: f32,
) {
    *budget -= ws.iterations;
    stats.iterations += ws.iterations;
    stats.position_updates += ws.iterations * len;
    let exact_stop = ws.iterations == len;
    for (b, f) in stats.front.iter_mut().enumerate() {
        let tau_ok = last_resid.get(b).is_some_and(|&r| r < tau);
        if *f == off && (tau_ok || exact_stop) {
            *f = off + len;
        }
    }
    stats.windows.push(ws);
}

/// Host-tensor convenience wrapper over [`gs_jacobi_decode_block_v`].
#[allow(clippy::too_many_arguments)]
pub fn gs_jacobi_decode_block<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &HostTensor,
    seq_len: usize,
    windows: usize,
    cfg: &JacobiConfig,
) -> Result<(HostTensor, GsJacobiStats)> {
    let (z, stats) = gs_jacobi_decode_block_v(
        engine,
        artifact,
        block,
        &Value::Host(y.clone()),
        seq_len,
        windows,
        cfg,
        None,
        None,
    )?;
    Ok((engine.to_host(z)?, stats))
}

/// Windowed GS-Jacobi decode over the fused multi-step window artifact
/// `{m}_block_jstep_win_fuse_b{B}`:
/// `(k, z_t, y, steps, off, len) → (z', resid_hist[S_max, B])`.
///
/// Identical sweep semantics to [`gs_jacobi_decode_block_v`] — same window
/// partition, per-window Prop 3.2 caps, shared `max_iters` budget, τ
/// stopping, `converged_at` bookkeeping and front advancement, all
/// recovered by scanning each chunk's residual history host-side — but the
/// inner loop runs in chunks sized by a per-window [`ChunkScheduler`]
/// seeded with `chunk_hint`, so host syncs per window drop from
/// `iterations` to `⌈iterations/S⌉` ([`GsJacobiStats::host_syncs`] counts
/// the sweep total). τ = 0 sweeps are bit-exact with the per-iteration
/// driver; a τ > 0 stop landing mid-chunk leaves the iterate extra
/// on-device updates *inside the still-active window* (frozen positions
/// cannot move), which only contracts it further toward the window's fixed
/// point and is never counted in `iterations` — budget accounting stays in
/// reported-iteration space.
#[allow(clippy::too_many_arguments)]
pub fn gs_jacobi_decode_block_fused_v<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    windows: usize,
    cfg: &JacobiConfig,
    z0: Option<Value>,
    pool: Option<&BufferPool>,
    chunk_hint: usize,
) -> Result<(Value, GsJacobiStats)> {
    let t0 = Instant::now();
    let (y_dev, k_scalar, mut z) = pin_decode_inputs(engine, pool, block, y, cfg, z0)?;

    let mut stats = GsJacobiStats {
        block,
        windows: Vec::new(),
        wall: Duration::ZERO,
        iterations: 0,
        position_updates: 0,
        converged: false,
        front: Vec::new(),
        host_syncs: 0,
    };
    let mut budget = cfg.max_iters.unwrap_or(usize::MAX);
    for (off, len) in window_partition(seq_len, windows) {
        if budget == 0 {
            break;
        }
        let cap = len.min(budget);
        let mut ws = WindowStats {
            offset: off,
            len,
            iterations: 0,
            residuals: Vec::new(),
            converged: false,
            converged_at: Vec::new(),
        };
        let mut last_resid: Vec<f32> = Vec::new();
        if cap > 0 {
            let off_scalar = pin_scalar_i32(engine, pool, off as i32)?;
            let len_scalar = pin_scalar_i32(engine, pool, len as i32)?;
            // A fresh scheduler per window: the contraction rate is a
            // per-window property (it depends on the window's coupling),
            // and the hint never exceeds the window's exactness cap.
            let mut sched = ChunkScheduler::new(chunk_hint.clamp(1, cap), cfg.tau);
            while !ws.converged && ws.iterations < cap {
                let chunk = sched.next_chunk(cap - ws.iterations, &ws.residuals);
                let steps_scalar = pin_scalar_i32(engine, pool, chunk as i32)?;
                let outs = engine.call_v(
                    artifact,
                    &[
                        k_scalar.clone(),
                        z,
                        y_dev.clone(),
                        steps_scalar,
                        off_scalar.clone(),
                        len_scalar.clone(),
                    ],
                )?;
                let mut it = outs.into_iter();
                z = it.next().context("jstep_win_fuse returns z'")?;
                let hist_v = it.next().context("jstep_win_fuse returns resid_hist")?;
                // One [S_max, B] history sync per chunk.
                let hist = engine.to_host(hist_v)?;
                stats.host_syncs += 1;
                let (s_max, b) = hist_dims(&hist)?;
                sched.observe_cap(s_max);
                let ran = chunk.min(s_max);
                ensure!(ran > 0, "fused chunk ran zero steps (artifact '{artifact}')");
                if stats.front.is_empty() {
                    stats.front = vec![0; b];
                }
                if ws.converged_at.is_empty() {
                    ws.converged_at = vec![None; b];
                }
                let data = hist.as_f32()?;
                for row in 0..ran {
                    let resid = &data[row * b..(row + 1) * b];
                    ws.iterations += 1;
                    let mut max_r = 0.0f32;
                    for (bi, &r) in resid.iter().enumerate() {
                        if r < cfg.tau && ws.converged_at[bi].is_none() {
                            ws.converged_at[bi] = Some(ws.iterations);
                        }
                        max_r = max_r.max(r);
                    }
                    ws.residuals.push(max_r);
                    last_resid = resid.to_vec();
                    if max_r < cfg.tau {
                        ws.converged = true;
                        break;
                    }
                }
            }
        }
        finish_window(&mut stats, ws, &last_resid, &mut budget, off, len, cfg.tau);
    }
    stats.converged =
        !stats.front.is_empty() && stats.front.iter().all(|&f| f == seq_len);
    stats.wall = t0.elapsed();
    Ok((z, stats))
}

/// Build the initial iterate `z⁰` per the configured strategy (host-side;
/// [`jacobi_decode_block_v`] uploads its result for the Zeros/Normal cases).
/// The speculative strategies are provider-driven — their predictions enter
/// the drivers through the `z0` hook — so host-side they build the Zeros
/// fallback.
pub fn init_iterate(y: &HostTensor, cfg: &JacobiConfig) -> HostTensor {
    match cfg.init {
        InitStrategy::Normal => {
            let mut rng = Pcg64::seed(cfg.seed);
            HostTensor::f32(y.shape(), (0..y.len()).map(|_| rng.next_gaussian()).collect())
        }
        InitStrategy::PrevLayer => y.clone(),
        _ => HostTensor::f32(y.shape(), vec![0.0; y.len()]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_strategies() {
        let y = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let zeros = init_iterate(&y, &JacobiConfig::default());
        assert_eq!(zeros.as_f32().unwrap(), &[0.0; 6]);

        let prev = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::PrevLayer, ..Default::default() },
        );
        assert_eq!(prev.as_f32().unwrap(), y.as_f32().unwrap());

        let n1 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        let n2 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        assert_eq!(n1.as_f32().unwrap(), n2.as_f32().unwrap());
        assert_ne!(n1.as_f32().unwrap(), zeros.as_f32().unwrap());
    }

    #[test]
    fn parse_init() {
        assert_eq!(InitStrategy::parse("zeros"), Some(InitStrategy::Zeros));
        assert_eq!(InitStrategy::parse("normal"), Some(InitStrategy::Normal));
        assert_eq!(InitStrategy::parse("prev"), Some(InitStrategy::PrevLayer));
        assert_eq!(InitStrategy::parse("proj"), Some(InitStrategy::Proj));
        assert_eq!(InitStrategy::parse("extrapolate"), Some(InitStrategy::Proj));
        assert_eq!(InitStrategy::parse("draft"), Some(InitStrategy::Draft));
        assert_eq!(InitStrategy::parse("warm"), Some(InitStrategy::Warm));
        assert_eq!(InitStrategy::parse("cache"), Some(InitStrategy::Warm));
        assert_eq!(InitStrategy::parse("bogus"), None);
    }

    #[test]
    fn init_labels_round_trip_through_parse() {
        for s in [
            InitStrategy::Zeros,
            InitStrategy::Normal,
            InitStrategy::PrevLayer,
            InitStrategy::Proj,
            InitStrategy::Draft,
            InitStrategy::Warm,
        ] {
            assert_eq!(InitStrategy::parse(s.label()), Some(s), "label {}", s.label());
        }
        assert!(InitStrategy::Proj.is_speculative());
        assert!(InitStrategy::Warm.is_speculative());
        assert!(InitStrategy::Draft.is_speculative());
        assert!(!InitStrategy::Zeros.is_speculative());
        assert!(!InitStrategy::PrevLayer.is_speculative());
    }

    #[test]
    fn speculative_init_iterate_falls_back_to_zeros() {
        let y = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        for init in [InitStrategy::Proj, InitStrategy::Draft, InitStrategy::Warm] {
            let z0 = init_iterate(&y, &JacobiConfig { init, ..Default::default() });
            assert_eq!(z0.as_f32().unwrap(), &[0.0; 6], "{init:?}");
        }
    }

    #[test]
    fn default_config_matches_paper() {
        let c = JacobiConfig::default();
        assert_eq!(c.tau, 0.5);
        assert_eq!(c.init, InitStrategy::Zeros);
        assert!(c.max_iters.is_none());
    }

    #[test]
    fn chunk_scheduler_tau0_runs_maximal_chunks() {
        let mut s = ChunkScheduler::new(3, 0.0);
        // First chunk = the calibrated hint.
        assert_eq!(s.next_chunk(10, &[]), 3);
        s.observe_cap(4);
        // τ = 0 can never stop early ⇒ maximal chunks, device-capped …
        assert_eq!(s.next_chunk(7, &[1.0]), 4);
        // … and bounded by the remaining iteration budget.
        assert_eq!(s.next_chunk(3, &[1.0, 0.5]), 3);
        assert_eq!(s.next_chunk(0, &[1.0]), 0);
    }

    #[test]
    fn chunk_scheduler_first_chunk_clamps_to_remaining_and_cap() {
        let mut s = ChunkScheduler::new(100, 0.5);
        s.observe_cap(8);
        assert_eq!(s.next_chunk(5, &[]), 5, "remaining bounds the hint");
        let mut s = ChunkScheduler::new(100, 0.5);
        s.observe_cap(8);
        assert_eq!(s.next_chunk(64, &[]), 8, "device cap bounds the hint");
        let mut s = ChunkScheduler::new(0, 0.5);
        assert_eq!(s.next_chunk(64, &[]), 1, "hint 0 still runs one step");
    }

    #[test]
    fn chunk_scheduler_refines_near_tau() {
        let mut s = ChunkScheduler::new(5, 0.1);
        s.observe_cap(8);
        // ρ = 0.4 at residual 0.8 → ⌈2.27⌉ = 3 more steps to τ = 0.1;
        // approach one short of the prediction so the stop lands exactly.
        assert_eq!(s.next_chunk(64, &[2.0, 0.8]), 2);
        // One predicted step left → 1-step refinement.
        assert_eq!(s.next_chunk(64, &[0.4, 0.2]), 1);
        // Flat residual gives no contraction signal → geometric ramp off
        // the last issued chunk (1 → 2).
        assert_eq!(s.next_chunk(64, &[0.5, 0.5]), 2);
        // A single residual is not a trajectory either → ramp (2 → 4).
        assert_eq!(s.next_chunk(64, &[0.5]), 4);
    }

    #[test]
    fn window_partition_covers_sequence() {
        for (l, w) in [(64, 4), (64, 1), (64, 64), (7, 3), (8, 5), (1, 1)] {
            let parts = window_partition(l, w);
            assert_eq!(parts.len(), w.min(l));
            assert_eq!(parts[0].0, 0);
            let mut expect_off = 0;
            for &(off, len) in &parts {
                assert_eq!(off, expect_off, "windows must be contiguous");
                assert!(len >= 1);
                expect_off += len;
            }
            assert_eq!(expect_off, l, "windows must cover all {l} positions");
            // Even split: lengths differ by at most one.
            let lens: Vec<usize> = parts.iter().map(|p| p.1).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "uneven partition {lens:?}");
        }
    }

    #[test]
    fn window_partition_degenerate_cases() {
        // W = 1 ⇒ one full-sequence window (plain Jacobi).
        assert_eq!(window_partition(8, 1), vec![(0, 8)]);
        // W = L ⇒ one window per position (sequential-equivalent).
        assert_eq!(window_partition(3, 3), vec![(0, 1), (1, 1), (2, 1)]);
        // W = 0 and W > L clamp rather than panic.
        assert_eq!(window_partition(8, 0), vec![(0, 8)]);
        assert_eq!(window_partition(2, 9), vec![(0, 1), (1, 1)]);
        // Non-divisible: extra positions go to the leading windows.
        assert_eq!(window_partition(7, 3), vec![(0, 3), (3, 2), (5, 2)]);
        assert!(window_partition(0, 4).is_empty());
    }
}
