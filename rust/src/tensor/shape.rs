//! Shape helpers.

/// Row-major strides for a shape.
pub fn strides_for(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides() {
        assert_eq!(strides_for(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_for(&[5]), vec![1]);
        assert_eq!(strides_for(&[]), Vec::<usize>::new());
    }
}
