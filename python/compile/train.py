"""Training loops (build-time only). Hand-written Adam — optax is not
available in this environment.

Weights are cached under ``artifacts/weights/{model}.npz``; `aot.py` skips
training when a cache exists (so `make artifacts` is idempotent).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import baselines, data, ising, maf, tarflow


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(grads, state, params, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def _save_npz(path, params):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def _load_npz(path):
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


# ---------------------------------------------------------------------------
# TarFlow
# ---------------------------------------------------------------------------

def train_tarflow(cfg: tarflow.TarFlowConfig, seed: int = 0, log_every: int = 50,
                  loss_log=None):
    ds = data.make_dataset(cfg.dataset)
    key = jax.random.PRNGKey(seed)
    params = tarflow.init_params(key, cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x):
        loss, grads = jax.value_and_grad(tarflow.nll_loss)(params, cfg, x)
        params, opt = adam_update(grads, opt, params, cfg.lr)
        return params, opt, loss

    t0 = time.time()
    for i in range(cfg.train_steps):
        x = ds.batch(cfg.train_batch, seed=1000 + i)
        x = x + cfg.noise_std * np.random.default_rng(2000 + i).standard_normal(x.shape).astype(np.float32)
        params, opt, loss = step(params, opt, jnp.asarray(x))
        if loss_log is not None and (i % 10 == 0 or i == cfg.train_steps - 1):
            loss_log.append((i, float(loss)))
        if i % log_every == 0 or i == cfg.train_steps - 1:
            print(f"[{cfg.name}] step {i:4d}/{cfg.train_steps} nll/dim {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params


# ---------------------------------------------------------------------------
# MAF
# ---------------------------------------------------------------------------

def _maf_dataset(cfg: maf.MafConfig):
    if cfg.dataset == "ising":
        return ising.IsingDataset(side=int(np.sqrt(cfg.dim)))
    if cfg.dataset == "digits":
        ds = data.make_dataset("digits")

        class _Wrap:
            def batch(self, n, seed):
                return ds.batch(n, seed, dequant=0.3)

        return _Wrap()
    raise ValueError(cfg.dataset)


def train_maf(cfg: maf.MafConfig, seed: int = 0, log_every: int = 100, loss_log=None):
    ds = _maf_dataset(cfg)
    key = jax.random.PRNGKey(seed)
    params = maf.init_params(key, cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x):
        loss, grads = jax.value_and_grad(maf.nll_loss)(params, cfg, x)
        params, opt = adam_update(grads, opt, params, cfg.lr)
        return params, opt, loss

    t0 = time.time()
    for i in range(cfg.train_steps):
        x = jnp.asarray(ds.batch(cfg.train_batch, seed=3000 + i))
        params, opt, loss = step(params, opt, x)
        if loss_log is not None and (i % 20 == 0 or i == cfg.train_steps - 1):
            loss_log.append((i, float(loss)))
        if i % log_every == 0 or i == cfg.train_steps - 1:
            print(f"[{cfg.name}] step {i:4d}/{cfg.train_steps} nll/dim {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def train_ddpm(cfg: baselines.DdpmConfig, seed: int = 0, log_every: int = 100):
    ds = data.make_dataset(cfg.dataset)
    key = jax.random.PRNGKey(seed)
    params = baselines.init_ddpm_params(key, cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, key):
        loss, grads = jax.value_and_grad(baselines.ddpm_loss)(params, cfg, x, key)
        params, opt = adam_update(grads, opt, params, cfg.lr)
        return params, opt, loss

    for i in range(cfg.train_steps):
        x = jnp.asarray(ds.batch(cfg.train_batch, seed=4000 + i))
        params, opt, loss = step(params, opt, x, jax.random.PRNGKey(5000 + i))
        if i % log_every == 0 or i == cfg.train_steps - 1:
            print(f"[{cfg.name}] step {i}/{cfg.train_steps} mse {float(loss):.4f}", flush=True)
    return params


def train_mmdgen(cfg: baselines.MmdGenConfig, seed: int = 0, log_every: int = 100):
    ds = data.make_dataset(cfg.dataset)
    key = jax.random.PRNGKey(seed)
    params = baselines.init_gen_params(key, cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, key):
        loss, grads = jax.value_and_grad(baselines.mmd_loss)(params, cfg, x, key)
        params, opt = adam_update(grads, opt, params, cfg.lr)
        return params, opt, loss

    for i in range(cfg.train_steps):
        x = jnp.asarray(ds.batch(cfg.train_batch, seed=6000 + i))
        params, opt, loss = step(params, opt, x, jax.random.PRNGKey(7000 + i))
        if i % log_every == 0 or i == cfg.train_steps - 1:
            print(f"[{cfg.name}] step {i}/{cfg.train_steps} mmd {float(loss):.5f}", flush=True)
    return params


# ---------------------------------------------------------------------------
# Cache wrapper
# ---------------------------------------------------------------------------

def train_or_load(name, weights_dir, train_fn, force=False):
    """Load ``{weights_dir}/{name}.npz`` if present, else train + save."""
    path = weights_dir / f"{name}.npz"
    if path.exists() and not force:
        print(f"[{name}] loading cached weights from {path}", flush=True)
        return _load_npz(path)
    params = train_fn()
    weights_dir.mkdir(parents=True, exist_ok=True)
    _save_npz(path, params)
    print(f"[{name}] saved weights to {path}", flush=True)
    return params
