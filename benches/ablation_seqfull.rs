//! §Perf ablation: sequential-baseline implementation strength.
//!
//! Three sequential implementations of the same block inverse:
//!   1. per-token artifact calls (the paper-equivalent serving baseline —
//!      mirrors eager per-step decoding with KV cache),
//!   2. scan-fused single artifact (`block_seqfull`) — the strongest
//!      sequential possible on this stack,
//!   3. Jacobi decode at τ = 0.5 for reference.
//!
//! On serial (single-core) hardware the fused sequential bounds everything —
//! Jacobi does strictly more FLOPs — so this table quantifies exactly how
//! much of SJD's win is per-step overhead vs genuine parallelism (which
//! returns on parallel hardware).

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::jacobi::JacobiConfig;
use sjd::coordinator::sampler::Sampler;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let mut report = Report::new("§Perf ablation — sequential implementation strength");
    let mut rows = Vec::new();

    for model in ["tf10", "tfafhq"] {
        if engine.manifest().model(model).is_err() {
            continue;
        }
        let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
        let sampler = Sampler::new(&engine, model, batch)?;
        let mut rng = sjd::tensor::Pcg64::seed(3);
        let v = sampler.sample_prior(&mut rng);
        let k = 1; // a refinement block

        // Warmups.
        let _ = sampler.sequential_decode_block(k, &v)?;
        let _ = sampler.sequential_decode_block_fused(k, &v);
        let _ = sampler.jacobi_decode(k, &v, &JacobiConfig::default(), 0)?;

        let reps = if quick() { 1 } else { 3 };
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = sampler.sequential_decode_block(k, &v)?;
        }
        let per_token = t0.elapsed().as_secs_f64() / reps as f64;

        let fused = match sampler.sequential_decode_block_fused(k, &v) {
            Ok(_) => {
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    let _ = sampler.sequential_decode_block_fused(k, &v)?;
                }
                Some(t0.elapsed().as_secs_f64() / reps as f64)
            }
            Err(_) => None, // artifact not lowered (older manifest)
        };

        let t0 = std::time::Instant::now();
        let mut iters = 0;
        for _ in 0..reps {
            let (_, s) = sampler.jacobi_decode(k, &v, &JacobiConfig::default(), 0)?;
            iters += s.iterations;
        }
        let jacobi = t0.elapsed().as_secs_f64() / reps as f64;

        println!(
            "{model}: per-token {per_token:.3}s | fused {} | jacobi {jacobi:.3}s ({} iters)",
            fused.map(|f| format!("{f:.3}s")).unwrap_or_else(|| "n/a".into()),
            iters / reps
        );
        rows.push(vec![
            model.to_string(),
            format!("{per_token:.3}"),
            fused.map(|f| format!("{f:.3}")).unwrap_or_else(|| "n/a".into()),
            format!("{jacobi:.3} ({} it)", iters / reps),
        ]);
    }

    report.table(
        &["Model", "Seq per-token (s)", "Seq scan-fused (s)", "Jacobi τ=0.5 (s)"],
        &rows,
    );
    report.note("Serial-hardware bound: fused-seq ≤ jacobi in FLOPs; SJD's win over the serving baseline = overhead amortization + early stopping.");
    report.finish();
    Ok(())
}
