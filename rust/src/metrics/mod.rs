//! Serving metrics: counters, gauges, log-bucketed latency histograms with
//! percentile snapshots, and a Prometheus-style text exposition.
//!
//! All types are `Send + Sync` (atomics / mutex-protected) so worker threads
//! and the HTTP `/metrics` endpoint share one [`Registry`].
//!
//! ## Canonical serving metric names
//!
//! The request path breaks per-request latency into three histograms so the
//! load bench (`benches/serve_load.rs`) and operators can see where time
//! goes:
//!
//! | metric                    | kind      | recorded by                          |
//! |---------------------------|-----------|--------------------------------------|
//! | `sjd_queue_wait`          | histogram | router worker, submit → decode start |
//! | `sjd_decode_time`         | histogram | router worker, per decoded batch     |
//! | `sjd_encode_time`         | histogram | server encode job, per image         |
//! | `sjd_request_latency`     | histogram | router worker, submit → image ready  |
//! | `sjd_batch_fill`          | histogram | real (non-padded) slots per batch    |
//! | `sjd_padded_slots`        | counter   | slots padded up to the chosen bucket |
//! | `sjd_bucket_{B}_batches`  | counter   | batches decoded via bucket `B`       |
//! | `sjd_http_keepalive_reuses` | counter | requests served on a reused connection |
//! | `sjd_block_iters`         | histogram | router worker, decode steps per block |
//! | `sjd_host_syncs`          | histogram | router worker, blocking host syncs per block (`⌈iters/S⌉` on the fused decode path) |
//! | `sjd_stage_{t}_occupancy` | gauge     | stage thread `t` of the decode pipeline: batches being processed (0/1 per pipeline; summed across workers when several pipelines share the registry) |
//! | `sjd_stage_wait`          | histogram | decode pipeline, time a batch waited in a stage queue before its stage picked it up (pooled across workers) |
//! | `sjd_batch_refills`       | counter   | continuous batcher: queued slots pulled into a forming wave by the stage-0 refill drain |
//! | `sjd_bucket_migrations`   | counter   | continuous batcher: waves re-gathered into a smaller covering bucket after slots left mid-flight |
//! | `sjd_straggler_merges`    | counter   | continuous batcher: straggler waves adopted by a peer wave at a block boundary instead of decoding padded |
//! | `sjd_slots_cancelled`     | counter   | continuous batcher: abandoned slots swept out of a wave at a block boundary |
//! | `sjd_padded_slot_blocks`  | counter   | continuous batcher: padded rows decoded, summed per block position — the quantity refill/migration/merge exists to minimize (`sjd_padded_slots` keeps its formation-time meaning) |
//! | `sjd_queue_depth`         | gauge     | batcher: queued slots right now (both priority classes; published under the queue lock) |
//! | `sjd_queue_cap`           | gauge     | batcher: the `--queue-cap` admission bound (0 = unbounded) |
//! | `sjd_shed_total{reason="queue_full"}` | counter | HTTP layer: `/generate` requests shed 429 at admission |
//! | `sjd_shed_total{reason="shutdown"}` | counter | HTTP layer: `/generate` requests answered 503 during drain |
//! | `sjd_deadline_expired`    | counter   | slots resolved past their deadline, at any enforcement point: queue purge, wave formation, block-boundary sweep, batch formation, handler wait |
//! | `sjd_degrade_level`       | gauge     | elastic governor: current degradation-ladder level (0 = exact configured policy) |
//! | `sjd_elastic_tau`         | gauge     | elastic governor: currently applied τ × 1e6 (0 whenever the ladder is at or below mode coarsening) |
//! | `sjd_backend_retries`     | counter   | fault-tolerant backend: dispatches re-driven after a transient fault (capped backoff, budgeted against the wave's earliest deadline) |
//! | `sjd_artifact_quarantined` | counter  | fault-tolerant backend: artifact circuit breakers tripped by consecutive poison faults (decodes reroute via the degradation chain until a probe heals the artifact) |
//! | `sjd_watchdog_fired`      | counter   | per-dispatch watchdog: hung dispatches whose slots were failed over; the worker incarnation is retired like a device loss |
//! | `sjd_worker_panics`       | counter   | router supervisor: worker bodies that panicked (in-flight slots resolve `Err` exactly once via the slot-drop completion guard) |
//! | `sjd_worker_restarts`     | counter   | router supervisor: panicked/device-lost workers respawned with a fresh engine; past `--worker-restarts` the fleet degrades and `/healthz` turns 503 |

mod histogram;
mod registry;

pub use histogram::{Histogram, Snapshot};
pub use registry::{Counter, Gauge, Registry};
