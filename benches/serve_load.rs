//! Serving-path load benchmark over the **mock backend** — no artifacts
//! needed, so it runs everywhere (including the CI smoke step).
//!
//! Compares two configurations of the full socket→batcher→router→encode
//! path under the same Poisson-ish open-loop workload of `n=1` requests:
//!
//! * **baseline** — the pre-bucketing stack shape: one decode bucket (8,
//!   every request padded up to it) and a single connection-handling
//!   thread (serial accept).
//! * **bucketed** — buckets {1, 2, 4, 8} with bucket-covering dispatch and
//!   a pooled connection handler.
//! * **replicated** — the bucketed stack with `replicas: 2`: two
//!   independent worker pipelines behind the one batcher, waves dispatched
//!   least-loaded. Reported for context (the replica scaling *gates* live
//!   in `benches/capacity.rs`); here it only has to complete cleanly.
//!
//! The mock's decode cost scales with the *bucket* batch size (each
//! jstep/seqstep call sleeps `slot_delay × B`), so padded slots burn real
//! wall time — exactly the waste the bucketed engine removes. Reported per
//! run: throughput, client p50/p99, and the server-side queue-wait /
//! decode / encode histogram breakdown. Exits non-zero if the bucketed
//! configuration fails to beat the baseline on both throughput and p99.
//!
//! ```bash
//! cargo bench --bench serve_load            # full run (256 requests)
//! cargo bench --bench serve_load -- --quick # CI smoke (64 requests)
//! ```

use anyhow::Result;
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::SampleOptions;
use sjd::coordinator::server::{Server, ServerConfig};
use sjd::exec::ThreadPool;
use sjd::metrics::Registry;
use sjd::tensor::Pcg64;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-slot artificial decode cost (per jstep/seqstep call, × batch size).
const SLOT_DELAY: Duration = Duration::from_micros(300);

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

struct RunStats {
    label: &'static str,
    wall: Duration,
    ok: u64,
    latencies_ms: Vec<f64>,
    padded_slots: u64,
    queue_p50_ms: f64,
    decode_p50_ms: f64,
    encode_p50_ms: f64,
}

impl RunStats {
    fn throughput(&self) -> f64 {
        self.ok as f64 / self.wall.as_secs_f64()
    }

    fn p50(&self) -> f64 {
        pct(&self.latencies_ms, 0.50)
    }

    fn p99(&self) -> f64 {
        pct(&self.latencies_ms, 0.99)
    }
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 - 1.0) * q) as usize]
}

/// POST one `/generate` on an open connection and read the response by
/// content-length (leaves the stream reusable for keep-alive clients).
fn generate_once(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    seed: usize,
    keep_alive: bool,
) -> Result<bool> {
    let body = format!("{{\"n\": 1, \"seed\": {seed}}}");
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        writer,
        "POST /generate HTTP/1.1\r\nHost: bench\r\nConnection: {conn}\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let (head, _body) = sjd::testkit::http::read_response(reader)?;
    Ok(head.starts_with("HTTP/1.1 200"))
}

#[allow(clippy::too_many_arguments)] // bench config knobs, not an API
fn run_config(
    label: &'static str,
    addr: &'static str,
    buckets: &[usize],
    conn_threads: usize,
    // Replica tier (≥ 2 = independent pipelines behind the one batcher,
    // least-loaded wave dispatch); 1 = the classic two-worker fleet.
    replicas: usize,
    // Baseline clients mimic the pre-bucketing stack (one request per
    // connection); bucketed clients hold keep-alive connections.
    keep_alive: bool,
    n_requests: usize,
    rps: f64,
) -> Result<RunStats> {
    let registry = Registry::new();
    let max_bucket = *buckets.iter().max().unwrap();
    let batcher = Batcher::new(max_bucket, Duration::from_millis(2));
    let bucket_vec = buckets.to_vec();
    let ledger = MockLedger::new();
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 2,
            options: SampleOptions {
                policy: DecodePolicy::Selective { seq_blocks: 1 },
                ..Default::default()
            },
            pipeline_depth: 1,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        move |_| Ok(MockServeBackend::new(&bucket_vec, SLOT_DELAY, ledger.clone())),
    )?;
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads, ..Default::default() },
    );
    let stop = server.stop_flag();
    let server_thread = std::thread::spawn(move || server.run());
    for _ in 0..100 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // Open-loop load: Poisson arrivals dispatched to a client pool. With
    // keep-alive, each client thread holds one persistent connection
    // (thread-local); otherwise every request dials fresh.
    let lat = Arc::new(Mutex::new(Vec::new()));
    let ok = Arc::new(AtomicU64::new(0));
    let mut rng = Pcg64::seed(999);
    let t0 = Instant::now();
    let wall;
    {
        let pool = ThreadPool::new(8);
        for i in 0..n_requests {
            let gap = rng.next_exp() / rps;
            std::thread::sleep(Duration::from_secs_f64(gap));
            let lat = lat.clone();
            let ok = ok.clone();
            pool.spawn(move || {
                thread_local! {
                    static CONN: std::cell::RefCell<Option<(TcpStream, BufReader<TcpStream>)>> =
                        const { std::cell::RefCell::new(None) };
                }
                let dial = || -> Option<(TcpStream, BufReader<TcpStream>)> {
                    let s = TcpStream::connect(addr).ok()?;
                    s.set_read_timeout(Some(Duration::from_secs(60))).ok()?;
                    let r = BufReader::new(s.try_clone().ok()?);
                    Some((s, r))
                };
                let t = Instant::now();
                let success = if keep_alive {
                    CONN.with(|c| {
                        let mut c = c.borrow_mut();
                        // The server legitimately reaps connections idle past
                        // its keep-alive timeout, so a send failure redials
                        // and retries once before counting a real failure.
                        for _attempt in 0..2 {
                            if c.is_none() {
                                *c = dial();
                            }
                            let (w, r) = c.as_mut()?;
                            match generate_once(w, r, i, true) {
                                Ok(okay) => return Some(okay),
                                Err(_) => *c = None,
                            }
                        }
                        None
                    })
                } else {
                    dial().and_then(|(mut w, mut r)| generate_once(&mut w, &mut r, i, false).ok())
                };
                if success == Some(true) {
                    ok.fetch_add(1, Ordering::SeqCst);
                }
                lat.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
            });
        }
        pool.wait_idle();
        wall = t0.elapsed();
        // Dropping the pool closes the keep-alive client connections, so the
        // server's handler threads see EOF and wind down promptly.
    }

    let mut latencies = lat.lock().unwrap().clone();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = RunStats {
        label,
        wall,
        ok: ok.load(Ordering::SeqCst),
        latencies_ms: latencies,
        padded_slots: registry.counter("sjd_padded_slots").get(),
        queue_p50_ms: registry.histogram("sjd_queue_wait").snapshot().p50() as f64 / 1e6,
        decode_p50_ms: registry.histogram("sjd_decode_time").snapshot().p50() as f64 / 1e6,
        encode_p50_ms: registry.histogram("sjd_encode_time").snapshot().p50() as f64 / 1e6,
    };

    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    let _ = server_thread.join();
    router.shutdown();
    Ok(stats)
}

fn report(s: &RunStats, n_requests: usize) {
    println!(
        "[{}] {} ok / {} reqs in {:.2}s → {:.1} req/s | client ms p50 {:.1} p99 {:.1} \
         | server p50 ms queue {:.1} decode {:.1} encode {:.2} | padded slots {}",
        s.label,
        s.ok,
        n_requests,
        s.wall.as_secs_f64(),
        s.throughput(),
        s.p50(),
        s.p99(),
        s.queue_p50_ms,
        s.decode_p50_ms,
        s.encode_p50_ms,
        s.padded_slots,
    );
}

fn main() -> Result<()> {
    let n_requests = if quick() { 64 } else { 256 };
    let rps = 60.0;
    println!("=== serve_load: {n_requests} × n=1 requests at ~{rps} req/s (mock backend) ===");

    let baseline = run_config(
        "baseline  single-bucket{8} serial-accept",
        "127.0.0.1:8511",
        &[8],
        1,
        1,
        false,
        n_requests,
        rps,
    )?;
    report(&baseline, n_requests);

    let bucketed = run_config(
        "bucketed  buckets{1,2,4,8} pooled-accept",
        "127.0.0.1:8512",
        &[1, 2, 4, 8],
        8,
        1,
        true,
        n_requests,
        rps,
    )?;
    report(&bucketed, n_requests);

    let replicated = run_config(
        "replicated buckets{1,2,4,8} 2-replica",
        "127.0.0.1:8513",
        &[1, 2, 4, 8],
        8,
        2,
        true,
        n_requests,
        rps,
    )?;
    report(&replicated, n_requests);

    let thr_gain = bucketed.throughput() / baseline.throughput();
    let p99_gain = baseline.p99() / bucketed.p99().max(1e-9);
    println!("\n=== summary ===");
    println!(
        "throughput {:.1} → {:.1} req/s ({thr_gain:.2}x) | p99 {:.1} → {:.1} ms ({p99_gain:.2}x) \
         | padded slots {} → {}",
        baseline.throughput(),
        bucketed.throughput(),
        baseline.p99(),
        bucketed.p99(),
        baseline.padded_slots,
        bucketed.padded_slots,
    );

    let all_ok = baseline.ok == n_requests as u64
        && bucketed.ok == n_requests as u64
        && replicated.ok == n_requests as u64;
    let faster = bucketed.throughput() > baseline.throughput() && bucketed.p99() < baseline.p99();
    if all_ok && faster {
        println!("PASS: bucketed serving beats the single-bucket serial baseline");
        Ok(())
    } else {
        println!(
            "FAIL: all_ok={all_ok} faster={faster} — the bucketed path must dominate the baseline"
        );
        std::process::exit(1);
    }
}
