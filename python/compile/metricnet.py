"""Fixed-seed random conv feature extractor — the proxy-FID backbone.

Random-projection Fréchet distances rank distribution drift monotonically
(substitute for InceptionV3 features, DESIGN.md §5). Weights come from a
fixed PRNG key so the metric is stable across runs and across the python /
rust boundary.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MetricNetConfig(NamedTuple):
    name: str
    img_hw: int
    channels: int = 3
    features: int = 64


def init_params(cfg: MetricNetConfig):
    key = jax.random.PRNGKey(1234)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c = cfg.channels
    return {
        "c1": jax.random.normal(k1, (3, 3, c, 16)) / jnp.sqrt(9 * c),
        "c2": jax.random.normal(k2, (3, 3, 16, 32)) / jnp.sqrt(9 * 16),
        "c3": jax.random.normal(k3, (3, 3, 32, 64)) / jnp.sqrt(9 * 32),
        "proj": jax.random.normal(k4, (64, cfg.features)) / 8.0,
    }


def _conv(x, w, stride):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def features(params, x):
    """(B, H, W, C) in [-1, 1] → (B, F) features."""
    h = jax.nn.leaky_relu(_conv(x, params["c1"], 2), 0.2)
    h = jax.nn.leaky_relu(_conv(h, params["c2"], 2), 0.2)
    h = jax.nn.leaky_relu(_conv(h, params["c3"], 2), 0.2)
    pooled = h.mean(axis=(1, 2))  # (B, 64)
    return pooled @ params["proj"]
