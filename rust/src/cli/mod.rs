//! Declarative CLI argument parser (clap substitute).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! defaults, required args, and generated `--help` text.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub required: bool,
    pub is_switch: bool,
}

/// A command (or subcommand) specification.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub subcommands: Vec<Command>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new(), subcommands: Vec::new() }
    }

    /// `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            required: false,
            is_switch: false,
        });
        self
    }

    /// Required `--name <value>` option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, required: true, is_switch: false });
        self
    }

    /// Boolean `--name` switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some("false".to_string()),
            required: false,
            is_switch: true,
        });
        self
    }

    pub fn sub(mut self, cmd: Command) -> Self {
        self.subcommands.push(cmd);
        self
    }

    /// Render help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} ", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            s.push_str("<SUBCOMMAND> ");
        }
        s.push_str("[OPTIONS]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for c in &self.subcommands {
                s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let meta = if o.is_switch { String::new() } else { " <value>".to_string() };
                let def = match (&o.default, o.is_switch) {
                    (Some(d), false) => format!(" [default: {d}]"),
                    _ => String::new(),
                };
                s.push_str(&format!("  --{:<18} {}{}\n", format!("{}{meta}", o.name), o.help, def));
            }
        }
        s
    }

    /// Parse argv (not including the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut i = 0;
        // Subcommand dispatch.
        if !self.subcommands.is_empty() {
            if args.is_empty() || args[0] == "--help" || args[0] == "-h" {
                bail!("{}", self.help_text());
            }
            let name = &args[0];
            let sub = self
                .subcommands
                .iter()
                .find(|c| c.name == *name)
                .ok_or_else(|| anyhow!("unknown subcommand '{name}'\n\n{}", self.help_text()))?;
            let mut parsed = sub.parse(&args[1..])?;
            // Nested subcommands compose into a space-separated path
            // ("policy show"), so dispatchers match on the full route.
            parsed.subcommand = Some(match parsed.subcommand.take() {
                Some(inner) => format!("{} {inner}", sub.name),
                None => sub.name.to_string(),
            });
            return Ok(parsed);
        }

        let mut values: BTreeMap<String, String> = BTreeMap::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut positional = Vec::new();
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.help_text());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow!("unknown option '--{key}'\n\n{}", self.help_text()))?;
                let val = if spec.is_switch {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| anyhow!("option '--{key}' requires a value"))?
                };
                values.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if o.required && !values.contains_key(o.name) {
                bail!("missing required option '--{}'\n\n{}", o.name, self.help_text());
            }
        }
        Ok(Parsed { subcommand: None, values, positional })
    }
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Parsed {
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(String::as_str).unwrap_or("")
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name)
            .parse()
            .map_err(|_| anyhow!("option '--{name}' must be an integer, got '{}'", self.str(name)))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)
            .parse()
            .map_err(|_| anyhow!("option '--{name}' must be a number, got '{}'", self.str(name)))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.str(name) == "true"
    }

    /// Comma-separated list.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    fn sample_cmd() -> Command {
        Command::new("sjd", "test").sub(
            Command::new("sample", "generate images")
                .opt("model", "tf10", "model name")
                .opt("batch", "8", "batch size")
                .opt("tau", "0.5", "stopping threshold")
                .switch("sequential", "use sequential decoding")
                .req("out", "output path"),
        )
    }

    #[test]
    fn parse_subcommand_with_options() {
        let p = sample_cmd()
            .parse(&argv("sample --model tfafhq --batch=4 --sequential --out /tmp/x"))
            .unwrap();
        assert_eq!(p.subcommand.as_deref(), Some("sample"));
        assert_eq!(p.str("model"), "tfafhq");
        assert_eq!(p.usize("batch").unwrap(), 4);
        assert!((p.f64("tau").unwrap() - 0.5).abs() < 1e-9);
        assert!(p.flag("sequential"));
        assert_eq!(p.str("out"), "/tmp/x");
    }

    #[test]
    fn nested_subcommands_compose_a_path() {
        let cmd = Command::new("sjd", "test").sub(
            Command::new("policy", "inspect policies")
                .sub(Command::new("show", "print the mode table").opt("blocks", "8", "K")),
        );
        let p = cmd.parse(&argv("policy show --blocks 4")).unwrap();
        assert_eq!(p.subcommand.as_deref(), Some("policy show"));
        assert_eq!(p.usize("blocks").unwrap(), 4);
        // The intermediate command alone surfaces its help (error path).
        let err = cmd.parse(&argv("policy")).unwrap_err().to_string();
        assert!(err.contains("show"), "{err}");
        assert!(cmd.parse(&argv("policy frobnicate")).is_err());
    }

    #[test]
    fn defaults_apply() {
        let p = sample_cmd().parse(&argv("sample --out x")).unwrap();
        assert_eq!(p.str("model"), "tf10");
        assert!(!p.flag("sequential"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(sample_cmd().parse(&argv("sample --model tf10")).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(sample_cmd().parse(&argv("sample --out x --bogus 1")).is_err());
        assert!(sample_cmd().parse(&argv("bogus")).is_err());
    }

    #[test]
    fn help_requested() {
        let err = sample_cmd().parse(&argv("sample --help")).unwrap_err().to_string();
        assert!(err.contains("OPTIONS"));
        assert!(err.contains("--model"));
    }

    #[test]
    fn numeric_errors() {
        let p = sample_cmd().parse(&argv("sample --batch abc --out x")).unwrap();
        assert!(p.usize("batch").is_err());
    }

    #[test]
    fn list_parsing() {
        let cmd = Command::new("x", "").opt("taus", "0.1,0.5,1.0", "tau list");
        let p = cmd.parse(&[]).unwrap();
        assert_eq!(p.list("taus"), vec!["0.1", "0.5", "1.0"]);
    }
}
