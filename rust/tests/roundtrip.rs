//! Integration test for the python-AOT → rust-runtime round trip.
//!
//! Uses `artifacts/smoke.hlo.txt` — a Pallas (interpret=True) kernel
//! `f(x, y) = x @ y + 2` lowered by the same path `aot.py` uses for the real
//! model artifacts. Skipped (with a loud message) if artifacts are missing;
//! `make artifacts` builds them.

use sjd::runtime::{Engine, HostTensor, Manifest};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn smoke_pallas_kernel_roundtrip() {
    let dir = artifacts_dir();
    let smoke = dir.join("smoke.hlo.txt");
    if !smoke.exists() {
        eprintln!("SKIP: {} missing — run `make artifacts`", smoke.display());
        return;
    }
    // Build a manifest in-memory via a temp file so the engine path is the
    // same one production uses.
    let tmp = std::env::temp_dir().join("sjd_smoke_manifest");
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(&smoke, tmp.join("smoke.hlo.txt")).unwrap();
    std::fs::write(
        tmp.join("manifest.json"),
        r#"{
          "artifacts": [
            {"name": "smoke", "file": "smoke.hlo.txt",
             "inputs": [
               {"name": "x", "dtype": "f32", "shape": [2, 2]},
               {"name": "y", "dtype": "f32", "shape": [2, 2]}
             ],
             "outputs": [
               {"name": "out", "dtype": "f32", "shape": [2, 2]}
             ]}
          ],
          "models": []
        }"#,
    )
    .unwrap();

    let manifest = Manifest::load(tmp.join("manifest.json")).unwrap();
    let engine = Engine::with_manifest(manifest).unwrap();
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());

    let x = HostTensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
    let y = HostTensor::f32(&[2, 2], vec![1., 1., 1., 1.]);
    let out = engine.call("smoke", &[x, y]).unwrap();
    assert_eq!(out.len(), 1);
    // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
    assert_eq!(out[0].as_f32().unwrap(), &[5., 5., 9., 9.]);

    // Stats recorded.
    let stats = engine.stats();
    assert_eq!(stats["smoke"].calls, 1);
    assert!(stats["smoke"].compile_time.as_nanos() > 0);

    // Shape validation fires.
    let bad = HostTensor::f32(&[2, 3], vec![0.; 6]);
    let y2 = HostTensor::f32(&[2, 2], vec![1.; 4]);
    assert!(engine.call("smoke", &[bad, y2]).is_err());
}
