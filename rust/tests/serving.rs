//! Serving-stack integration: batcher + router workers + HTTP server,
//! exercised over real TCP against real artifacts. Skips when artifacts are
//! missing.

use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::SampleOptions;
use sjd::coordinator::server::Server;
use sjd::metrics::Registry;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("SJD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built");
        None
    }
}

fn post(addr: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn get(addr: &str, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn serve_generate_and_metrics_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let addr = "127.0.0.1:8497";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(5));
    let router = Router::start(
        RouterConfig {
            artifacts_dir: dir,
            model: "tf10".into(),
            batch_size: 1,
            workers: 1,
            options: SampleOptions::default(),
        },
        batcher.clone(),
        registry.clone(),
    )
    .expect("router");

    let server = Server::new(addr, batcher, registry.clone());
    let stop = server.stop_flag();
    let t = std::thread::spawn(move || server.run());
    for _ in 0..100 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // Health.
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");

    // Generate 2 images.
    let resp = post(addr, "/generate", r#"{"n": 2, "seed": 5}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("json body");
    let imgs = v.req_arr("images_png_b64").unwrap();
    assert_eq!(imgs.len(), 2);
    // Base64 payloads decode to PNG magic.
    let b64 = imgs[0].as_str().unwrap();
    assert!(b64.len() > 100);
    assert!(b64.starts_with("iVBOR"), "not a PNG payload: {}", &b64[..16]);

    // Determinism: same seed → identical payloads.
    let resp2 = post(addr, "/generate", r#"{"n": 2, "seed": 5}"#);
    let body2 = resp2.split("\r\n\r\n").nth(1).unwrap();
    let v2 = sjd::jsonx::parse(body2).unwrap();
    assert_eq!(
        v.req_arr("images_png_b64").unwrap()[0],
        v2.req_arr("images_png_b64").unwrap()[0],
        "same seed must reproduce the same image"
    );

    // Metrics advanced.
    let m = get(addr, "/metrics");
    assert!(m.contains("sjd_images_generated"), "{m}");
    assert!(m.contains("sjd_http_requests"));

    // Bad request handled.
    let bad = post(addr, "/generate", "{invalid json");
    assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");
    let nf = get(addr, "/nope");
    assert!(nf.starts_with("HTTP/1.1 404"));

    // Shutdown.
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    let _ = t.join();
    router.shutdown();
}

#[test]
fn server_answers_malformed_requests_without_backend() {
    // The HTTP front end's defensive paths need no artifacts: header-cap
    // violations and bad JSON must get a 400 response (not a silent
    // connection reset), with a body that is itself valid JSON.
    let addr = "127.0.0.1:8499";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(5));
    let server = Server::new(addr, batcher, registry);
    let stop = server.stop_flag();
    let t = std::thread::spawn(move || server.run());
    for _ in 0..100 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // Header flood → answered 400.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut req = String::from("GET /healthz HTTP/1.1\r\n");
    for i in 0..200 {
        req.push_str(&format!("X-H{i}: v\r\n"));
    }
    req.push_str("\r\n");
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    assert!(out.starts_with("HTTP/1.1 400"), "{out}");

    // Bad JSON body → 400, and the error body parses as JSON.
    let resp = post(addr, "/generate", "{invalid json");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    assert!(sjd::jsonx::parse(body).is_ok(), "error body must be valid JSON: {body}");

    // Well-formed requests still served.
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");

    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    let _ = t.join();
}

#[test]
fn batcher_groups_concurrent_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let registry = Registry::new();
    // Batch of 8 with generous wait: 8 concurrent submissions form 1 batch.
    let batcher = Batcher::new(8, Duration::from_millis(500));
    let router = Router::start(
        RouterConfig {
            artifacts_dir: dir,
            model: "tf10".into(),
            batch_size: 8,
            workers: 1,
            options: SampleOptions::default(),
        },
        batcher.clone(),
        registry.clone(),
    )
    .expect("router");

    let handles: Vec<_> = (0..8).map(|i| batcher.submit(i, 9)).collect();
    for h in handles {
        let img = h.wait();
        assert_eq!(img.ndim(), 3);
    }
    // One full batch, no padding.
    let snap = registry.histogram("sjd_batch_fill").snapshot();
    assert_eq!(snap.count, 1);
    assert!(snap.max == 8, "batch fill {}", snap.max);
    router.shutdown();
}
