//! L3 coordinator — the paper's system contribution wired as a serving stack.
//!
//! See `docs/ARCHITECTURE.md` at the repo root for the full layer map
//! (Pallas kernels → AOT manifest → runtime Value/Engine → this coordinator
//! → HTTP server) and the device-residency rules the hot paths rely on.
//!
//! * [`jacobi`] — the parallel Jacobi decoding drivers: full-sequence
//!   (paper Alg 1, iterate `z ← F(z)` until `‖z^t − z^{t−1}‖∞ < τ`),
//!   windowed GS-Jacobi with convergence-front tracking
//!   ([`jacobi::gs_jacobi_decode_block_v`]), and their fused **chunked**
//!   variants ([`jacobi::jacobi_decode_block_fused_v`],
//!   [`jacobi::gs_jacobi_decode_block_fused_v`]) that sync one residual
//!   history per chunk instead of one residual per iteration.
//! * [`policy`] — where/how to use Jacobi (paper §3.5): sequential for the
//!   dependency-heavy first block, Jacobi or windowed GS-Jacobi for the
//!   rest, plus uniform / sequential / fused-chunked (`fuse[:S]`) /
//!   calibrated per-block variants with JSON persistence.
//! * [`sampler`] — full noise→image pipeline over the AOT artifacts; a
//!   [`sampler::SamplerSet`] holds one sampler per lowered batch bucket.
//! * [`pipeline`] — the decode restructured as a **stage graph**: one
//!   [`pipeline::BlockStage`] per flow block, executed by a
//!   [`pipeline::DecodePipeline`] that keeps ≥ 2 batches in flight at
//!   different stages (inter-batch block overlap with per-stage queues,
//!   backpressure and `sjd_stage_*` metrics).
//! * [`batcher`] — dynamic request batching up to the largest bucket.
//! * [`fault`] — fault-tolerant execution: transient-fault retry with
//!   capped backoff budgeted against slot deadlines, per-artifact circuit
//!   breakers whose quarantine reroutes through the degradation chain,
//!   and the hung-dispatch watchdog (worker respawn lives in [`router`]).
//! * [`router`] — multi-worker dispatch (one engine per worker thread,
//!   or one per *stage* thread under `--pipeline-depth ≥ 2`); each batch
//!   decodes via the smallest bucket covering it, padding only the gap to
//!   that bucket (`sjd_padded_slots`). With `--tune`, workers route every
//!   batch through the live [`policy::PolicyTuner`] policy and feed their
//!   decode traces back to it.
//! * [`server`] — HTTP/1.1 front end (`/generate`, `/metrics`, `/healthz`)
//!   on a connection thread pool with keep-alive; PNG encodes run as pool
//!   jobs that overlap decode.
//! * [`state`] — per-request decode state & KV-cache buffers.

pub mod batcher;
pub mod fault;
pub mod jacobi;
pub mod maf;
pub mod pipeline;
pub mod policy;
pub mod router;
pub mod sampler;
pub mod server;
pub mod state;

pub use fault::{DeadlineCell, FaultPolicy, FaultTolerantBackend, WatchGuard, Watchdog};
pub use jacobi::{
    ChunkScheduler, GsJacobiStats, InitStrategy, JacobiConfig, JacobiStats, WindowStats,
};
pub use pipeline::{device_placement, BlockStage, DecodePipeline, PipelineConfig, PipelineJob};
pub use policy::{BlockDecode, DecodePolicy, PolicyTuner, TunerConfig};
pub use sampler::{SampleOptions, Sampler, SamplerSet};
