//! `sjd` — the leader binary: serve, sample, recon, calibrate, info.
//!
//! ```text
//! sjd serve   --model tf10 --addr 127.0.0.1:8471 --workers 2 --policy selective
//! sjd serve   --model tf10 --batch-sizes 1,2,4,8 --http-threads 8
//! sjd serve   --model tf10 --tune --pipeline-depth 2
//! sjd serve   --model tf10 --refill
//! sjd serve   --model tf10 --devices auto --replicas 2 --client-rate 5
//! sjd sample  --model tf10 --batch 8 --policy gs:4 --tau 0.5 --out samples.png
//! sjd recon   --model tf10 --batch 8
//! sjd calibrate --model tf10 --batch 8 --windows 8 --out tf10_policy.json
//! sjd calibrate --model tf10 --batch 8 --chunks --out tf10_policy.json
//! sjd serve   --model tf10 --policy-file tf10_policy.json
//! sjd policy show --policy-file tf10_policy.json
//! sjd policy show --addr 127.0.0.1:8471
//! sjd info
//! ```
//!
//! Policy strings: `sequential` | `ujd` | `selective[:N]` | `gs[:W]` |
//! `fuse[:S]` | `@file.json`; `--policy-file <path>` is the explicit form of
//! `@file.json` and takes precedence over `--policy`. See the root
//! `README.md` for the full cheat-sheet.

use anyhow::{bail, Context, Result};
use sjd::cli::Command;
use sjd::configx::{CValue, Config};
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::fault::FaultPolicy;
use sjd::coordinator::jacobi::JacobiConfig;
use sjd::coordinator::policy::{
    calibrate, calibrate_chunks, calibrate_windows, DecodePolicy, GovernorConfig, InitPolicy,
    OverloadGovernor, PolicyTuner, TunerConfig,
};
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::coordinator::server::{PolicySource, Server, ServerConfig};
use sjd::imageio::{compose_grid, write_png, Image};
use sjd::metrics::Registry;
use sjd::runtime::{Engine, Manifest};
use sjd::tensor::Pcg64;
use std::sync::Arc;
use std::time::Duration;

fn cli() -> Command {
    Command::new("sjd", "Selective Jacobi Decoding serving stack")
        .sub(
            Command::new("serve", "run the HTTP serving front end")
                .opt("config", "", "optional config file (TOML subset)")
                .opt("artifacts", "artifacts", "artifacts directory")
                .opt("model", "tf10", "model name")
                .opt("addr", "127.0.0.1:8471", "listen address")
                .opt("workers", "2", "worker threads (one engine each)")
                .opt("batch-sizes", "", "decode buckets, e.g. 1,2,4,8 [default: all lowered]")
                .opt("http-threads", "8", "HTTP connection-handling threads")
                .opt("batch-wait-ms", "20", "max batching delay")
                .opt("policy", "selective", "sequential|ujd|selective[:N]|gs[:W]|fuse[:S]|@file.json")
                .opt("policy-file", "", "calibrated policy JSON (overrides --policy)")
                .opt("tau", "0.5", "Jacobi stopping threshold")
                .opt("init", "zeros", "zeros|normal|prev|proj|warm[:N]|draft")
                .opt("seed", "0", "RNG seed")
                .switch(
                    "tune",
                    "enable the online policy autotuner (per-bucket per-block \
                     windows/chunks learned from live traffic; /policy shows it)",
                )
                .opt(
                    "tune-snapshot",
                    "",
                    "where --tune persists its learned policy JSON, every 30 s and on \
                     shutdown [default: <model>_tuned_policy.json]",
                )
                .opt(
                    "pipeline-depth",
                    "1",
                    "batches each worker keeps in flight; >=2 enables stage-graph \
                     block pipelining (one engine per stage thread)",
                )
                .opt(
                    "stage-threads",
                    "0",
                    "stage threads per pipelined worker (0 = one per flow block; \
                     fewer bounds the engine count at coarser overlap)",
                )
                .switch(
                    "refill",
                    "continuous batching: refill drained slots from the queue at \
                     block boundaries, migrate shrinking batches to smaller \
                     buckets, sweep disconnected requests (overrides the \
                     depth-gated feeder; per-request outputs stay bit-identical)",
                )
                .opt(
                    "queue-cap",
                    "0",
                    "admission control: max queued requests before /generate \
                     sheds with 429 + Retry-After (0 = unbounded)",
                )
                .opt(
                    "default-deadline",
                    "0",
                    "per-request decode deadline in ms when the client sends no \
                     X-SJD-Deadline-Ms header; expired requests answer 504 and \
                     are swept mid-flight at block boundaries (0 = none)",
                )
                .switch(
                    "elastic",
                    "quality-elastic overload governor: under sustained queue/\
                     latency pressure, walk a degradation ladder (maximal fused \
                     chunks, coarser GS windows, then raised tau within \
                     --fidelity-budget) and step back to the exact configured \
                     policy when pressure clears",
                )
                .opt(
                    "fidelity-budget",
                    "0",
                    "max tau --elastic may degrade to under overload (0 = mode \
                     coarsening only, never raises tau; at tau 0 coarsening \
                     stays bit-exact)",
                )
                .opt(
                    "retry-budget",
                    "3",
                    "max redispatches of a decode step after a transient backend \
                     fault (capped exponential backoff, budgeted against the \
                     request deadline; 0 = fail fast)",
                )
                .opt(
                    "quarantine-after",
                    "3",
                    "consecutive poison faults on one artifact before it is \
                     quarantined and decodes reroute through the degradation \
                     chain (gs_fuse -> gs -> jacobi); probed for recovery",
                )
                .opt(
                    "worker-restarts",
                    "2",
                    "times a panicked or device-lost worker is respawned with a \
                     fresh engine before being retired; a degraded fleet turns \
                     /healthz non-200",
                )
                .opt(
                    "devices",
                    "1",
                    "addressable device ordinals to spread work across ('auto' = \
                     all the platform exposes): pipelined stage spans place \
                     contiguously onto ordinals; monolithic workers/replicas \
                     round-robin whole engines",
                )
                .opt(
                    "replicas",
                    "1",
                    "independent decode pipelines behind the one batcher; >=2 \
                     overrides --workers and dispatches each wave to the \
                     least-loaded replica (a replica retired past \
                     --worker-restarts drains via /healthz)",
                )
                .opt(
                    "client-rate",
                    "0",
                    "per-client admission quota in requests/second, keyed by the \
                     X-SJD-Client header (headerless requests pool together); \
                     over-quota requests shed 429 + Retry-After (0 = off)",
                ),
        )
        .sub(
            Command::new("sample", "generate a batch of images to a PNG grid")
                .opt("artifacts", "artifacts", "artifacts directory")
                .opt("model", "tf10", "model name")
                .opt("batch", "8", "batch size (must be lowered)")
                .opt("policy", "selective", "sequential|ujd|selective[:N]|gs[:W]|fuse[:S]|@file.json")
                .opt("policy-file", "", "calibrated policy JSON (overrides --policy)")
                .opt("tau", "0.5", "Jacobi stopping threshold")
                .opt("init", "zeros", "zeros|normal|prev|proj|warm[:N]|draft")
                .opt("seed", "0", "RNG seed")
                .opt("out", "samples.png", "output PNG path"),
        )
        .sub(
            Command::new("recon", "reconstruction-consistency check (paper §E.4)")
                .opt("artifacts", "artifacts", "artifacts directory")
                .opt("model", "tf10", "model name")
                .opt("batch", "8", "batch size")
                .opt("policy", "selective", "sequential|ujd|selective[:N]|gs[:W]|fuse[:S]|@file.json")
                .opt("policy-file", "", "calibrated policy JSON (overrides --policy)")
                .opt("tau", "0.5", "Jacobi stopping threshold")
                .opt("init", "zeros", "zeros|normal|prev|proj|warm[:N]|draft")
                .opt("seed", "0", "RNG seed"),
        )
        .sub(
            Command::new("calibrate", "measure per-block decode costs, pick a policy")
                .opt("artifacts", "artifacts", "artifacts directory")
                .opt("model", "tf10", "model name")
                .opt("batch", "8", "batch size")
                .opt("tau", "0.5", "Jacobi stopping threshold")
                .opt("init", "zeros", "zeros|normal|prev|proj|warm[:N]|draft")
                .opt("windows", "8", "max GS-Jacobi windows the calibration may assign")
                .switch(
                    "chunks",
                    "route learned modes through the fused multi-step artifacts \
                     with per-block chunk schedules seeded from the traces",
                )
                .opt("out", "", "policy JSON output path [default: <model>_policy.json]"),
        )
        .sub(
            Command::new("policy", "inspect decode policies").sub(
                Command::new("show", "print the resolved per-block mode table")
                    .opt("policy", "selective", "sequential|ujd|selective[:N]|gs[:W]|fuse[:S]")
                    .opt("policy-file", "", "calibrated policy JSON (overrides --policy)")
                    .opt("blocks", "8", "flow blocks K (parametric policies only)")
                    .opt("addr", "", "fetch the live policy from a serving /policy endpoint"),
            ),
        )
        .sub(
            Command::new("info", "list models and artifacts")
                .opt("artifacts", "artifacts", "artifacts directory"),
        )
}

/// The policy file a command references, if any: `--policy-file <path>`
/// wins, else the `--policy @file.json` spelling.
fn policy_file_path<'p>(p: &'p sjd::cli::Parsed) -> Option<&'p str> {
    match p.str("policy-file") {
        "" => p.str("policy").strip_prefix('@'),
        path => Some(path),
    }
}

/// Strict `--init` resolution (see [`InitPolicy::parse`]): a spelling that
/// does not parse is an **error**, never silently zeros — an operator who
/// typed `--init wurm` meant something. A non-default CLI spelling wins;
/// otherwise a calibrated policy file's embedded `init` section (written by
/// `sjd calibrate --init ...`) applies, so the whole decode recipe
/// round-trips through one JSON file.
fn init_policy(p: &sjd::cli::Parsed) -> Result<InitPolicy> {
    let spec = p.str("init");
    let cli = InitPolicy::parse(spec).ok_or_else(|| {
        anyhow::anyhow!("bad --init '{spec}' (expected zeros|normal|prev|proj|warm[:N]|draft)")
    })?;
    if cli != InitPolicy::default() {
        return Ok(cli);
    }
    if let Some(path) = policy_file_path(p) {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading policy file {path}"))?;
        if let Some(init) = sjd::jsonx::parse(&text)?.get("init") {
            return InitPolicy::from_json(init)
                .with_context(|| format!("bad init section in policy file {path}"));
        }
    }
    Ok(cli)
}

fn jacobi_config(p: &sjd::cli::Parsed, init: &InitPolicy) -> JacobiConfig {
    JacobiConfig {
        tau: p.f64("tau").unwrap_or(0.5) as f32,
        max_iters: None,
        init: init.strategy,
        seed: p.usize("seed").unwrap_or(0) as u64,
    }
}

fn policy(p: &sjd::cli::Parsed) -> Result<DecodePolicy> {
    // --policy-file <path> wins; otherwise --policy accepts
    // "sequential" | "ujd" | "selective[:N]" | "gs[:W]" | "@calibrated.json".
    let file = p.str("policy-file");
    if !file.is_empty() {
        return DecodePolicy::parse_or_load(&format!("@{file}"));
    }
    DecodePolicy::parse_or_load(p.str("policy"))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match cli().parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    match parsed.subcommand.as_deref() {
        Some("serve") => cmd_serve(&parsed),
        Some("sample") => cmd_sample(&parsed),
        Some("recon") => cmd_recon(&parsed),
        Some("calibrate") => cmd_calibrate(&parsed),
        Some("policy show") => cmd_policy_show(&parsed),
        Some("info") => cmd_info(&parsed),
        _ => bail!("no subcommand"),
    }
}

fn cmd_serve(p: &sjd::cli::Parsed) -> Result<()> {
    // Config layering: file < env < CLI flags.
    let mut cfg = if p.str("config").is_empty() {
        Config::default()
    } else {
        Config::load(p.str("config"))?
    };
    cfg.set("serve.model", CValue::Str(p.str("model").into()));
    cfg.set("serve.addr", CValue::Str(p.str("addr").into()));

    let pol = policy(p)?;
    let policy_label = pol.label();
    let init = init_policy(p)?;
    let options = SampleOptions {
        policy: pol.clone(),
        jacobi: jacobi_config(p, &init),
        mask_o: 0,
        fused_sequential: false,
        seed: 0,
    };
    // The manifest drives bucket resolution and (under --tune) the model
    // geometry + fused history length the tuner needs.
    let model = p.str("model").to_string();
    let artifacts_dir = std::path::PathBuf::from(p.str("artifacts"));
    let manifest = Manifest::load(artifacts_dir.join("manifest.json"))?;
    let buckets = match p.str("batch-sizes") {
        "" => manifest.decode_buckets(&model),
        spec => parse_buckets(spec)?,
    };
    let Some(&max_bucket) = buckets.last() else {
        bail!("model {model} has no lowered decode buckets");
    };

    // Online autotuner (--tune): bootstraps from the configured policy and
    // learns per-bucket per-block modes from live decode traces.
    let tuner = if p.flag("tune") {
        let meta = manifest.model(&model)?;
        let s_max = fused_history_len(&manifest, &model, max_bucket);
        let cfg = TunerConfig { s_max, ..Default::default() };
        // The tuner owns init gating: it serves the requested provider per
        // bucket and reverts to zeros where realized savings go negative.
        Some(Arc::new(
            PolicyTuner::new(meta.blocks, meta.seq_len, pol.clone(), cfg)
                .with_init(init.strategy),
        ))
    } else {
        None
    };

    let registry = Registry::new();
    let queue_cap = p.usize("queue-cap")?;
    let batcher = Batcher::with_cap(
        max_bucket,
        Duration::from_millis(p.usize("batch-wait-ms")? as u64),
        queue_cap,
    );
    batcher.bind_metrics(&registry);
    // Quality-elastic overload governor (--elastic): degrades the decode
    // schedule under sustained pressure and steps back to the exact
    // configured policy when it clears. The queue-pressure threshold tracks
    // admission control when a cap is set, else a multiple of the largest
    // bucket (a healthy serve drains a bucket per batch wait).
    let governor = if p.flag("elastic") {
        let blocks = manifest.model(&model)?.blocks;
        let queue_high = if queue_cap > 0 {
            (queue_cap as f64 / 2.0).max(1.0)
        } else {
            (4 * max_bucket) as f64
        };
        Some(Arc::new(OverloadGovernor::new(
            blocks,
            GovernorConfig {
                queue_high,
                base_tau: options.jacobi.tau,
                fidelity_budget: p.f64("fidelity-budget").unwrap_or(0.0) as f32,
                s_max: fused_history_len(&manifest, &model, max_bucket),
                ..Default::default()
            },
            &registry,
        )))
    } else {
        None
    };
    // Device spread (--devices N|auto): 'auto' probes the platform through a
    // throwaway ordinal-0 engine — the same client the workers will build —
    // so the resolved count is exactly what their engines will see.
    let devices = match p.str("devices") {
        "auto" => {
            let n = Engine::new(&artifacts_dir)?.device_count();
            println!("devices auto: platform exposes {n} addressable device(s)");
            n
        }
        spec => spec.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("bad --devices '{spec}' (expected a count or 'auto')")
        })?,
    };
    let replicas = p.usize("replicas")?;
    let router = Router::start(
        RouterConfig {
            artifacts_dir,
            model: model.clone(),
            buckets: buckets.clone(),
            workers: p.usize("workers")?,
            options,
            pipeline_depth: p.usize("pipeline-depth")?,
            stage_threads: p.usize("stage-threads")?,
            refill: p.flag("refill"),
            tuner: tuner.clone(),
            warm_cap: init.warm_cap,
            governor,
            fault: FaultPolicy {
                retry_budget: p.usize("retry-budget")?,
                quarantine_after: p.usize("quarantine-after")?,
                worker_restarts: p.usize("worker-restarts")?,
                ..Default::default()
            },
            replicas,
            devices,
        },
        batcher.clone(),
        registry.clone(),
    )?;
    println!(
        "serving model {model} on {} ({}, buckets {buckets:?}, {} device(s), policy \
         {policy_label}, init {}{})",
        p.str("addr"),
        if replicas >= 2 {
            format!("{replicas} replicas")
        } else {
            format!("{} workers", p.usize("workers")?)
        },
        devices.max(1),
        init.label(),
        if tuner.is_some() { ", tuned" } else { "" },
    );
    let server = Server::with_config(
        p.str("addr"),
        batcher,
        registry,
        ServerConfig {
            conn_threads: p.usize("http-threads")?,
            default_deadline: match p.usize("default-deadline")? {
                0 => None,
                ms => Some(Duration::from_millis(ms as u64)),
            },
            policy: Some(PolicySource {
                configured: {
                    // Like the calibrate output: the configured policy JSON
                    // carries the init section so `/policy` shows the whole
                    // decode recipe.
                    let mut json = pol.to_json();
                    if let sjd::jsonx::Value::Obj(o) = &mut json {
                        o.insert("init".into(), init.to_json());
                    }
                    json
                },
                tuner: tuner.clone(),
            }),
            fleet: Some(router.fleet()),
            client_rate: p.f64("client-rate")?,
            ..Default::default()
        },
    );
    // Persist what the tuner learns, in the policy-JSON format calibrate
    // writes, so the next (even untuned) serve can start from it. The
    // serve process usually dies by signal — which cannot unwind past the
    // accept loop — so a detached thread snapshots periodically and the
    // orderly-shutdown path below writes once more.
    let snapshot_path = match p.str("tune-snapshot") {
        "" => format!("{model}_tuned_policy.json"),
        s => s.to_string(),
    };
    if let Some(tuner) = &tuner {
        let tuner = tuner.clone();
        let path = snapshot_path.clone();
        std::thread::Builder::new()
            .name("sjd-tune-snapshot".into())
            .spawn(move || loop {
                std::thread::sleep(TUNE_SNAPSHOT_PERIOD);
                write_tuner_snapshot(&tuner, &path);
            })
            .expect("spawn snapshot thread");
    }
    server.run()?;
    router.shutdown();
    if let Some(tuner) = &tuner {
        if write_tuner_snapshot(tuner, &snapshot_path) {
            println!("wrote tuned policy snapshot to {snapshot_path}");
        }
    }
    Ok(())
}

/// Cadence of the background tuner-snapshot writer.
const TUNE_SNAPSHOT_PERIOD: Duration = Duration::from_secs(30);

/// Best-effort write of the tuner's learned policy (most-observed bucket)
/// in the ordinary policy-JSON format; `false` when there is nothing to
/// persist yet or the write failed. Writes go through a temp file + rename
/// so the periodic writer and the shutdown writer can never leave a torn
/// snapshot behind, whatever instant the process dies.
fn write_tuner_snapshot(tuner: &PolicyTuner, path: &str) -> bool {
    // One writer at a time: the periodic thread and the shutdown path
    // share the temp file, and a torn temp renamed into place would defeat
    // the atomicity.
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    let Some((_bucket, learned)) = tuner.snapshot_best() else {
        return false;
    };
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, sjd::jsonx::to_string_pretty(&learned.to_json())).is_ok()
        && std::fs::rename(&tmp, path).is_ok()
}

/// The fused artifacts' lowered history length for one bucket, read off the
/// manifest's `[S, B]` output shape — the python side owns S
/// (`aot.JSTEP_FUSE_STEPS`); the default only covers artifact dirs lowered
/// without the fused role, where serving falls back per-iteration anyway.
fn fused_history_len(manifest: &Manifest, model: &str, bucket: usize) -> usize {
    manifest
        .artifact(&format!("{model}_block_jstep_fuse_b{bucket}"))
        .ok()
        .and_then(|a| a.outputs.get(1).and_then(|o| o.shape.first().copied()))
        .filter(|&s| s >= 1)
        .unwrap_or(sjd::coordinator::policy::DEFAULT_FUSE_CHUNK)
}

/// `sjd policy show`: print the per-block mode table of a policy string /
/// file, or fetch the live policy JSON from a `--tune`d server.
fn cmd_policy_show(p: &sjd::cli::Parsed) -> Result<()> {
    let addr = p.str("addr");
    if !addr.is_empty() {
        println!("{}", fetch_policy(addr)?);
        return Ok(());
    }
    let pol = policy(p)?;
    // Calibrated policies carry their own length; parametric ones span
    // whatever K the operator asks about.
    let blocks = match &pol {
        DecodePolicy::PerBlock { modes } => modes.len(),
        DecodePolicy::Custom { jacobi_mask } => jacobi_mask.len(),
        _ => p.usize("blocks")?,
    };
    if blocks == 0 {
        bail!("--blocks must be >= 1");
    }
    println!("policy: {}", pol.label());
    // A calibrated file may carry an embedded init section — show it, so
    // the operator sees the whole decode recipe the file encodes.
    if let Some(path) = policy_file_path(p) {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading policy file {path}"))?;
        if let Some(init) = sjd::jsonx::parse(&text)?.get("init") {
            println!("init:   {}", InitPolicy::from_json(init)?.label());
        }
    }
    println!("{:<5} {:<6} mode", "pos", "block");
    for stage in sjd::coordinator::pipeline::stage_plan(&pol, blocks) {
        println!("{:<5} {:<6} {}", stage.position, stage.block, stage.mode.describe());
    }
    Ok(())
}

/// One-shot `GET /policy` against a running server.
fn fetch_policy(addr: &str) -> Result<String> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    s.set_read_timeout(Some(Duration::from_secs(10)))?;
    write!(s, "GET /policy HTTP/1.1\r\nHost: sjd\r\nConnection: close\r\n\r\n")?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    let (head, body) = resp.split_once("\r\n\r\n").context("malformed HTTP response")?;
    if !head.starts_with("HTTP/1.1 200") {
        bail!("server answered: {}", head.lines().next().unwrap_or(head));
    }
    Ok(body.to_string())
}

/// Parse a `--batch-sizes` list ("1,2,4,8") into sorted unique buckets.
fn parse_buckets(spec: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let b: usize = part.parse().map_err(|_| anyhow::anyhow!("bad bucket size '{part}'"))?;
        if b == 0 {
            bail!("bucket sizes must be >= 1");
        }
        out.push(b);
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

fn cmd_sample(p: &sjd::cli::Parsed) -> Result<()> {
    // Flags fail fast, before any artifact loading: a typo'd --init is a
    // usage error, not a backend error.
    let init = init_policy(p)?;
    let pol = policy(p)?;
    let engine = Engine::new(p.str("artifacts"))?;
    let sampler = Sampler::new(&engine, p.str("model"), p.usize("batch")?)?;
    sampler.set_warm_cap(init.warm_cap);
    let opts = SampleOptions {
        policy: pol,
        jacobi: jacobi_config(p, &init),
        mask_o: 0,
        fused_sequential: false,
        seed: p.usize("seed")? as u64,
    };
    let mut rng = Pcg64::seed(opts.seed);
    let (images, out) = sampler.sample_images(&opts, &mut rng)?;
    println!(
        "sampled {} images in {:.3}s ({} Jacobi iters total)",
        images.len(),
        out.total_wall.as_secs_f64(),
        out.total_jacobi_iters()
    );
    for t in &out.traces {
        println!(
            "  block {} (pos {}): {} × {}, {:.1} ms",
            t.block,
            t.position,
            if t.used_jacobi { "jacobi" } else { "seq" },
            t.steps,
            t.wall.as_secs_f64() * 1e3
        );
    }
    let imgs: Vec<Image> = images
        .iter()
        .map(Image::from_tensor_pm1)
        .collect::<Result<_>>()?;
    let grid = compose_grid(&imgs, 4, 2);
    write_png(&grid, p.str("out"))?;
    println!("wrote {}", p.str("out"));
    Ok(())
}

fn cmd_recon(p: &sjd::cli::Parsed) -> Result<()> {
    let init = init_policy(p)?;
    let pol = policy(p)?;
    let engine = Engine::new(p.str("artifacts"))?;
    let sampler = Sampler::new(&engine, p.str("model"), p.usize("batch")?)?;
    let mut rng = Pcg64::seed(p.usize("seed")? as u64);

    // "Real" images (model samples stand in for dataset images on the rust
    // side) → encode → SJD decode → MSE (paper §E.4).
    let b = p.usize("batch")?;
    sampler.set_warm_cap(init.warm_cap);
    let mut opts = SampleOptions { policy: pol, ..Default::default() };
    opts.jacobi = jacobi_config(p, &init);
    let (reals, _) = sampler.sample_images(
        &SampleOptions { policy: DecodePolicy::Sequential, ..Default::default() },
        &mut rng,
    )?;
    let x = sampler.stack_images(&reals)?;
    let (z, logdet) = sampler.encode(&x)?;
    let out = sampler.decode_tokens(z, &opts)?;
    let recon = sampler.unpatchify(&out.tokens)?;
    let mut mse = 0.0f32;
    for (a, b_img) in reals.iter().zip(&recon) {
        mse += a.mse(b_img)?;
    }
    mse /= b as f32;
    println!("reconstruction MSE over {b} images: {mse:.6}");
    println!(
        "mean logdet: {:.3}",
        logdet.as_f32()?.iter().sum::<f32>() / b as f32
    );
    Ok(())
}

fn cmd_calibrate(p: &sjd::cli::Parsed) -> Result<()> {
    let max_windows = p.usize("windows")?;
    if max_windows == 0 {
        bail!("--windows must be >= 1 (1 = plain Jacobi, more enables GS windowing)");
    }
    let init = init_policy(p)?;
    let engine = Engine::new(p.str("artifacts"))?;
    let sampler = Sampler::new(&engine, p.str("model"), p.usize("batch")?)?;
    sampler.set_warm_cap(init.warm_cap);
    let mut rng = Pcg64::seed(7);
    let kk = sampler.meta.blocks;
    let tau = p.f64("tau")? as f32;

    // Measure per decode position: sequential wall vs Jacobi wall.
    let z = sampler.sample_prior(&mut rng);
    let mut seq_walls = Vec::new();
    let mut jstats = Vec::new();
    let mut h = z;
    for pos in 0..kk {
        let k = kk - 1 - pos;
        let t0 = std::time::Instant::now();
        let (u_seq, _) = sampler.sequential_decode_block(k, &h)?;
        seq_walls.push(t0.elapsed());
        let cfg = JacobiConfig { tau, init: init.strategy, ..Default::default() };
        let (_u_j, stats) = sampler.jacobi_decode(k, &h, &cfg, 0)?;
        jstats.push(stats);
        h = if k % 2 == 1 { sampler.reverse_tokens(&u_seq)? } else { u_seq };
    }
    for (pos, (j, s)) in jstats.iter().zip(&seq_walls).enumerate() {
        println!(
            "pos {pos} (block {}): seq {:.1} ms | jacobi {} iters {:.1} ms{}",
            j.block,
            s.as_secs_f64() * 1e3,
            j.iterations,
            j.wall.as_secs_f64() * 1e3,
            if j.converged { "" } else { " (no converge)" }
        );
    }
    println!("binary policy (jacobi vs seq): {:?}", calibrate(&jstats, &seq_walls));
    // The window-aware policy is what gets persisted: it subsumes the binary
    // choice and learns per-block GS-Jacobi window counts from the traces.
    // --chunks additionally routes the learned modes through the fused
    // multi-step artifacts, seeding each block's first chunk with its
    // measured iteration count so serving decodes land on the τ crossing in
    // one host sync (chunk sizes capped at the fused history length).
    let pol = if p.flag("chunks") {
        // The device history cap is read off the lowered fused artifact's
        // [S, B] output shape (shared helper with serve --tune).
        let s_max = fused_history_len(engine.manifest(), p.str("model"), p.usize("batch")?);
        calibrate_chunks(&jstats, &seq_walls, sampler.meta.seq_len, max_windows, s_max)
    } else {
        calibrate_windows(&jstats, &seq_walls, sampler.meta.seq_len, max_windows)
    };
    println!("calibrated policy: {:?}", pol);
    let out = match p.str("out") {
        "" => format!("{}_policy.json", p.str("model")),
        path => path.to_string(),
    };
    // Embed the init policy so the whole decode recipe round-trips through
    // one file: `serve --policy-file` picks the section up unless an
    // explicit `--init` overrides it. `DecodePolicy::from_json` keys off
    // `kind` alone, so older readers ignore the extra field.
    let mut json = pol.to_json();
    if let sjd::jsonx::Value::Obj(o) = &mut json {
        o.insert("init".into(), init.to_json());
    }
    std::fs::write(&out, sjd::jsonx::to_string_pretty(&json))?;
    println!("wrote {out} (use with --policy-file {out})");
    Ok(())
}

fn cmd_info(p: &sjd::cli::Parsed) -> Result<()> {
    let engine = Engine::new(p.str("artifacts"))?;
    let m = engine.manifest();
    println!("platform: {}", engine.platform());
    println!("models:");
    for model in m.models.values() {
        println!(
            "  {} ({}): K={} L={} D={} Dm={} batches {:?}",
            model.name,
            model.kind,
            model.blocks,
            model.seq_len,
            model.token_dim,
            model.model_dim,
            model.batch_sizes
        );
    }
    println!("artifacts: {}", m.artifacts.len());
    for a in m.artifacts.values() {
        println!("  {} ({})", a.name, a.file);
    }
    Ok(())
}
