//! JSON value model.

use std::collections::BTreeMap;

/// A parsed JSON value. Objects use a `BTreeMap` so emitted JSON is
/// deterministic (handy for golden tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Convenience: required string field.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    /// Convenience: required usize field.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    /// Convenience: required array field.
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Value]> {
        self.get(key)
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Build a number value.
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    /// Build an array of numbers from usizes.
    pub fn usize_arr(v: &[usize]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect())
    }
}
