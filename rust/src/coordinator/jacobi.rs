//! Jacobi decoding driver (paper Alg 1).
//!
//! One Jacobi *step* is an AOT artifact call `(k, z_t, y) → (z_{t+1}, resid)`
//! that updates every position of the sequence in parallel from the previous
//! iterate (the L1 Pallas hot path). This driver owns the L3 concerns: the
//! initialization strategy, the τ stopping rule on ‖z^t − z^{t−1}‖∞, the
//! worst-case `L` iteration guard (Prop 3.2 guarantees exactness at `t = L`),
//! and per-layer statistics for the selective policy / paper tables.
//!
//! The driver is **device-resident** ([`jacobi_decode_block_v`]): the block
//! input `y` and the loop scalars are uploaded once, the iterate `z` chains
//! device→device across iterations, and the only per-iteration host sync is
//! the `[B]` residual needed for the τ test. [`jacobi_decode_block`] is the
//! host-tensor convenience wrapper.

use crate::runtime::{Backend, HostTensor, Value};
use crate::tensor::Pcg64;
use anyhow::{Context, Result};
use std::time::{Duration, Instant};

/// How `z⁰` is initialized (paper Fig 6 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitStrategy {
    /// `z⁰ = 0` (paper default, Alg 1).
    Zeros,
    /// `z⁰ ~ N(0, I)`.
    Normal,
    /// `z⁰ = z_{k+1}` (previous layer's output — the Jacobi input itself).
    PrevLayer,
}

impl InitStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "zeros" => Some(InitStrategy::Zeros),
            "normal" => Some(InitStrategy::Normal),
            "prev" | "prev_layer" => Some(InitStrategy::PrevLayer),
            _ => None,
        }
    }
}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct JacobiConfig {
    /// Stopping threshold τ on ‖z^t − z^{t−1}‖∞ (paper default 0.5).
    pub tau: f32,
    /// Hard iteration cap; `None` ⇒ the sequence length `L` (Prop 3.2 bound).
    pub max_iters: Option<usize>,
    pub init: InitStrategy,
    /// Seed for `InitStrategy::Normal`.
    pub seed: u64,
}

impl Default for JacobiConfig {
    fn default() -> Self {
        JacobiConfig { tau: 0.5, max_iters: None, init: InitStrategy::Zeros, seed: 0 }
    }
}

/// Statistics of one Jacobi decode of one block.
#[derive(Clone, Debug)]
pub struct JacobiStats {
    pub block: usize,
    pub iterations: usize,
    pub wall: Duration,
    /// Residual ‖z^t − z^{t−1}‖∞ after each iteration.
    pub residuals: Vec<f32>,
    /// Whether the τ criterion was reached (vs hitting the iteration cap).
    pub converged: bool,
}

/// Decode block `k` by Jacobi iteration, keeping the iterate device-resident.
///
/// `y` is the block input `z_{k+1}` with shape (B, L, D) — host values are
/// uploaded exactly once, device values are used in place (the block-chaining
/// path of `Sampler::decode_tokens`). The artifact
/// `{model}_block_jstep_b{B}` computes one parallel update plus the residual
/// max over the batch; per iteration only that `[B]` residual crosses to the
/// host. The final iterate is returned still device-resident. `mask_o > 0`
/// applies the paper's eq-6 dependency mask (used for the Fig 1/2 redundancy
/// experiments); `mask_o = 0` is the exact update of Alg 1.
pub fn jacobi_decode_block_v<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
) -> Result<(Value, JacobiStats)> {
    jacobi_decode_block_v_init(engine, artifact, block, y, seq_len, cfg, mask_o, None)
}

/// [`jacobi_decode_block_v`] with an optional pre-built initial iterate.
///
/// When `z0` is provided it is used as `z⁰` verbatim — the caller must make
/// it consistent with `cfg.init` (the `Sampler` passes its pool's cached
/// device zeros for `InitStrategy::Zeros`, turning the per-block z⁰ upload
/// into one upload per process lifetime).
#[allow(clippy::too_many_arguments)]
pub fn jacobi_decode_block_v_init<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
    z0: Option<Value>,
) -> Result<(Value, JacobiStats)> {
    let t0 = Instant::now();
    // Pin the loop constants on device once.
    let y_dev = match y {
        Value::Host(t) => engine.to_device(t)?,
        Value::Device(_) => y.clone(),
    };
    let k_scalar = engine.to_device(&HostTensor::scalar_i32(block as i32))?;
    let o_scalar = engine.to_device(&HostTensor::scalar_i32(mask_o as i32))?;
    let mut z = match (z0, cfg.init) {
        (Some(z0), _) => z0,
        // The iterate starts as another handle on y — no upload at all.
        (None, InitStrategy::PrevLayer) => y_dev.clone(),
        // Zeros/Normal only need the iterate's shape: build z⁰ host-side via
        // the shared init_iterate (one source of truth) and upload it once.
        (None, _) => {
            let proto = HostTensor::f32(y_dev.shape(), vec![0.0; y_dev.numel()]);
            engine.to_device(&init_iterate(&proto, cfg))?
        }
    };

    let cap = cfg.max_iters.unwrap_or(seq_len);
    let mut residuals = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    while iterations < cap {
        let outs = engine.call_v(
            artifact,
            &[k_scalar.clone(), z, y_dev.clone(), o_scalar.clone()],
        )?;
        let mut it = outs.into_iter();
        let z_next = it.next().context("jstep returns z'")?;
        let resid_v = it.next().context("jstep returns residual")?;
        // The τ test is the only per-iteration sync: a [B] residual vector.
        let resid =
            engine.to_host(resid_v)?.as_f32()?.iter().copied().fold(0.0f32, f32::max);
        residuals.push(resid);
        z = z_next;
        iterations += 1;
        if resid < cfg.tau {
            converged = true;
            break;
        }
    }

    Ok((
        z,
        JacobiStats { block, iterations, wall: t0.elapsed(), residuals, converged },
    ))
}

/// Host-tensor convenience wrapper over [`jacobi_decode_block_v`]: uploads
/// `y`, decodes, and syncs the final iterate back.
pub fn jacobi_decode_block<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &HostTensor,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
) -> Result<(HostTensor, JacobiStats)> {
    let (z, stats) = jacobi_decode_block_v(
        engine,
        artifact,
        block,
        &Value::Host(y.clone()),
        seq_len,
        cfg,
        mask_o,
    )?;
    Ok((engine.to_host(z)?, stats))
}

/// Build the initial iterate `z⁰` per the configured strategy (host-side;
/// [`jacobi_decode_block_v`] uploads its result for the Zeros/Normal cases).
pub fn init_iterate(y: &HostTensor, cfg: &JacobiConfig) -> HostTensor {
    match cfg.init {
        InitStrategy::Zeros => HostTensor::f32(y.shape(), vec![0.0; y.len()]),
        InitStrategy::Normal => {
            let mut rng = Pcg64::seed(cfg.seed);
            HostTensor::f32(y.shape(), (0..y.len()).map(|_| rng.next_gaussian()).collect())
        }
        InitStrategy::PrevLayer => y.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_strategies() {
        let y = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let zeros = init_iterate(&y, &JacobiConfig::default());
        assert_eq!(zeros.as_f32().unwrap(), &[0.0; 6]);

        let prev = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::PrevLayer, ..Default::default() },
        );
        assert_eq!(prev.as_f32().unwrap(), y.as_f32().unwrap());

        let n1 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        let n2 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        assert_eq!(n1.as_f32().unwrap(), n2.as_f32().unwrap());
        assert_ne!(n1.as_f32().unwrap(), zeros.as_f32().unwrap());
    }

    #[test]
    fn parse_init() {
        assert_eq!(InitStrategy::parse("zeros"), Some(InitStrategy::Zeros));
        assert_eq!(InitStrategy::parse("normal"), Some(InitStrategy::Normal));
        assert_eq!(InitStrategy::parse("prev"), Some(InitStrategy::PrevLayer));
        assert_eq!(InitStrategy::parse("bogus"), None);
    }

    #[test]
    fn default_config_matches_paper() {
        let c = JacobiConfig::default();
        assert_eq!(c.tau, 0.5);
        assert_eq!(c.init, InitStrategy::Zeros);
        assert!(c.max_iters.is_none());
    }
}
