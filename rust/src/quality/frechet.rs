//! Fréchet distance between Gaussian feature fits:
//! FID = ‖μ₁−μ₂‖² + tr(Σ₁ + Σ₂ − 2(Σ₁Σ₂)^{1/2}).
//!
//! `tr((Σ₁Σ₂)^{1/2})` is computed via the symmetric eigendecomposition of
//! `S = Σ₁^{1/2} Σ₂ Σ₁^{1/2}` (similar to Σ₁Σ₂, and symmetric PSD, so its
//! eigenvalues are real and non-negative): tr((Σ₁Σ₂)^{1/2}) = Σ √λᵢ(S).

use crate::tensor::{matmul, sym_eigen, Tensor};
use anyhow::{bail, Result};

/// Mean + covariance fit of a feature set.
#[derive(Clone, Debug)]
pub struct FeatureStats {
    pub mean: Tensor,
    pub cov: Tensor,
    pub n: usize,
}

impl FeatureStats {
    /// Fit from an (N, D) feature matrix.
    pub fn fit(features: &Tensor) -> Result<Self> {
        if features.ndim() != 2 {
            bail!("features must be (N, D), got {:?}", features.shape());
        }
        let n = features.shape()[0];
        if n < 2 {
            bail!("need at least 2 samples to fit covariance");
        }
        Ok(FeatureStats { mean: features.col_mean(), cov: features.covariance(), n })
    }
}

/// Matrix square root of a symmetric PSD matrix via eigendecomposition.
fn sqrtm_psd(a: &Tensor) -> Result<Tensor> {
    let n = a.shape()[0];
    let (vals, vecs) = sym_eigen(a, 60)?;
    // A^{1/2} = V diag(√max(λ,0)) Vᵀ
    let mut out = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0f64;
            for k in 0..n {
                let lam = vals[k].max(0.0) as f64;
                s += vecs.at(&[i, k]) as f64 * lam.sqrt() * vecs.at(&[j, k]) as f64;
            }
            out[i * n + j] = s as f32;
        }
    }
    Tensor::new(&[n, n], out)
}

/// Fréchet distance between two Gaussian fits.
pub fn frechet_distance(a: &FeatureStats, b: &FeatureStats) -> Result<f32> {
    if a.mean.shape() != b.mean.shape() {
        bail!("feature dimensionality mismatch");
    }
    let d2_mean: f64 = a
        .mean
        .data()
        .iter()
        .zip(b.mean.data())
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum();

    // S = Σa^{1/2} Σb Σa^{1/2}
    let sqrt_a = sqrtm_psd(&a.cov)?;
    let inner = matmul(&matmul(&sqrt_a, &b.cov)?, &sqrt_a)?;
    let (vals, _) = sym_eigen(&inner, 60)?;
    let tr_sqrt: f64 = vals.iter().map(|&l| (l.max(0.0) as f64).sqrt()).sum();

    let tr_a: f64 = (0..a.cov.shape()[0]).map(|i| a.cov.at(&[i, i]) as f64).sum();
    let tr_b: f64 = (0..b.cov.shape()[0]).map(|i| b.cov.at(&[i, i]) as f64).sum();

    Ok((d2_mean + tr_a + tr_b - 2.0 * tr_sqrt).max(0.0) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn gaussian_features(n: usize, d: usize, mean: f32, std: f32, seed: u64) -> Tensor {
        let mut rng = Pcg64::seed(seed);
        let data = (0..n * d).map(|_| mean + std * rng.next_gaussian()).collect();
        Tensor::new(&[n, d], data).unwrap()
    }

    #[test]
    fn identical_distributions_near_zero() {
        let x = gaussian_features(2000, 4, 0.0, 1.0, 1);
        let y = gaussian_features(2000, 4, 0.0, 1.0, 2);
        let fa = FeatureStats::fit(&x).unwrap();
        let fb = FeatureStats::fit(&y).unwrap();
        let d = frechet_distance(&fa, &fb).unwrap();
        assert!(d < 0.05, "FID of same distribution should be ~0, got {d}");
    }

    #[test]
    fn mean_shift_detected_quantitatively() {
        // For isotropic unit Gaussians shifted by δ per dim: FID ≈ D·δ².
        let x = gaussian_features(4000, 4, 0.0, 1.0, 3);
        let y = gaussian_features(4000, 4, 1.0, 1.0, 4);
        let d = frechet_distance(&FeatureStats::fit(&x).unwrap(), &FeatureStats::fit(&y).unwrap())
            .unwrap();
        assert!((3.0..5.0).contains(&d), "expected ≈4, got {d}");
    }

    #[test]
    fn variance_change_detected() {
        // Unit vs 2-std Gaussians: per-dim term (1-2)² + ... analytically
        // FID = D (σ1−σ2)² = 4·1 = 4 for means equal.
        let x = gaussian_features(4000, 4, 0.0, 1.0, 5);
        let y = gaussian_features(4000, 4, 0.0, 2.0, 6);
        let d = frechet_distance(&FeatureStats::fit(&x).unwrap(), &FeatureStats::fit(&y).unwrap())
            .unwrap();
        assert!((3.0..5.5).contains(&d), "expected ≈4, got {d}");
    }

    #[test]
    fn monotone_in_shift() {
        let base = gaussian_features(2000, 3, 0.0, 1.0, 7);
        let fa = FeatureStats::fit(&base).unwrap();
        let mut last = -1.0f32;
        for (i, shift) in [0.2f32, 0.6, 1.2].iter().enumerate() {
            let y = gaussian_features(2000, 3, *shift, 1.0, 8 + i as u64);
            let d = frechet_distance(&fa, &FeatureStats::fit(&y).unwrap()).unwrap();
            assert!(d > last, "FID must grow with shift: {d} after {last}");
            last = d;
        }
    }

    #[test]
    fn fit_requires_2d_and_samples() {
        assert!(FeatureStats::fit(&Tensor::zeros(&[5])).is_err());
        assert!(FeatureStats::fit(&Tensor::zeros(&[1, 3])).is_err());
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let a = FeatureStats::fit(&gaussian_features(100, 3, 0.0, 1.0, 11)).unwrap();
        let b = FeatureStats::fit(&gaussian_features(100, 4, 0.0, 1.0, 12)).unwrap();
        assert!(frechet_distance(&a, &b).is_err());
    }
}
