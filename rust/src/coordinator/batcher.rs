//! Dynamic request batching.
//!
//! Artifacts are lowered for a *set* of fixed batch sizes (buckets), so the
//! batcher groups single-image slots from concurrent requests into one model
//! batch of up to `max_batch` slots — the largest lowered bucket — flushing a
//! partial batch when a deadline expires before it fills (vLLM-style
//! max-wait batching). The batcher never pads: the router worker picks the
//! smallest bucket covering the formed batch and pads only the gap to *that*
//! bucket (tracked in the `sjd_padded_slots` counter), so an `n=1` request
//! served by a `{1,2,4,8}` bucket set decodes zero throwaway slots.
//!
//! Continuous batching (`serve --refill`) adds two verbs on top: a
//! non-blocking [`Batcher::take_upto`] drain that tops a decoding wave up to
//! the largest bucket at every block boundary, and a per-slot cancellation
//! flag ([`SlotHandle::cancel`]) that lets an abandoned request leave the
//! wave at the next boundary instead of decoding to the end.

use crate::exec::OneShot;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What a slot's completion channel carries: the generated (H, W, C) image,
/// or the decode error message (`String` so every slot of a failed batch
/// gets its own copy) — the HTTP layer turns it into a 500 instead of
/// returning a silently-black 200.
pub type SlotResult = std::result::Result<Tensor, String>;

/// One image slot of a request.
pub struct Slot {
    pub request_id: u64,
    pub seed: u64,
    /// Completion channel: receives the image or the decode error.
    pub done: OneShot<SlotResult>,
    /// Cooperative cancellation flag (client disconnected): the continuous
    /// path sweeps cancelled slots out at the next block boundary instead
    /// of decoding them to the end; monolithic workers ignore it (the slot
    /// still completes, its result is simply discarded).
    pub cancel: Arc<AtomicBool>,
    pub enqueued: Instant,
}

impl Slot {
    /// Whether the submitter abandoned this slot (see [`SlotHandle::cancel`]).
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// The submitter's side of a slot: the completion channel plus the
/// cancellation flag. Cancelling is advisory — the slot still resolves
/// (with an error if it was swept before decoding), so a waiter never
/// hangs.
#[derive(Clone)]
pub struct SlotHandle {
    pub done: OneShot<SlotResult>,
    cancel: Arc<AtomicBool>,
}

impl SlotHandle {
    /// Flag the slot as abandoned; the continuous decode path drops it at
    /// the next block boundary.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// A formed batch handed to a worker: between 1 and `max_batch` real slots.
/// Bucket choice — and therefore padding — is the worker's job.
pub struct Batch {
    pub slots: Vec<Slot>,
    pub formed: Instant,
}

struct QueueInner {
    slots: VecDeque<Slot>,
    closed: bool,
}

/// Shared batching queue.
#[derive(Clone)]
pub struct Batcher {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    /// Largest batch a worker will be handed (= the largest decode bucket).
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher {
            inner: Arc::new((
                Mutex::new(QueueInner { slots: VecDeque::new(), closed: false }),
                Condvar::new(),
            )),
            max_batch,
            max_wait,
        }
    }

    /// Enqueue one slot; returns its completion handle. Fails fast once the
    /// queue is [`Self::close`]d — workers drain and exit after close, so a
    /// late slot would otherwise sit in the queue forever and its completion
    /// handle would never fire.
    pub fn submit(&self, request_id: u64, seed: u64) -> Result<OneShot<SlotResult>> {
        Ok(self.submit_slot(request_id, seed)?.done)
    }

    /// [`Self::submit`] returning the full [`SlotHandle`] (completion +
    /// cancellation); the HTTP layer cancels a request's remaining slots
    /// when the client disconnects mid-decode.
    pub fn submit_slot(&self, request_id: u64, seed: u64) -> Result<SlotHandle> {
        let done = OneShot::new();
        let cancel = Arc::new(AtomicBool::new(false));
        let slot = Slot {
            request_id,
            seed,
            done: done.clone(),
            cancel: cancel.clone(),
            enqueued: Instant::now(),
        };
        let (m, cv) = &*self.inner;
        {
            let mut q = m.lock().unwrap();
            if q.closed {
                bail!("batcher is closed (server shutting down)");
            }
            q.slots.push_back(slot);
        }
        cv.notify_all();
        Ok(SlotHandle { done, cancel })
    }

    pub fn queued(&self) -> usize {
        self.inner.0.lock().unwrap().slots.len()
    }

    /// Close the queue: new [`Self::submit`]s fail fast, waiting workers
    /// drain remaining slots then get `None`.
    pub fn close(&self) {
        self.inner.0.lock().unwrap().closed = true;
        self.inner.1.notify_all();
    }

    /// Worker side: block until a full `max_batch` is available or the
    /// oldest slot has waited `max_wait`, then return the batch. `None`
    /// after [`Self::close`] once the queue is drained.
    pub fn next_batch(&self) -> Option<Batch> {
        let (m, cv) = &*self.inner;
        let mut q = m.lock().unwrap();
        loop {
            if q.slots.len() >= self.max_batch {
                break;
            }
            if !q.slots.is_empty() {
                if q.closed {
                    break; // flush the tail immediately on shutdown
                }
                let oldest = q.slots.front().unwrap().enqueued;
                let waited = oldest.elapsed();
                if waited >= self.max_wait {
                    break; // flush partial batch
                }
                let (nq, _timeout) = cv.wait_timeout(q, self.max_wait - waited).unwrap();
                q = nq;
                continue;
            }
            if q.closed {
                return None;
            }
            q = cv.wait(q).unwrap();
        }
        let take = q.slots.len().min(self.max_batch);
        let slots: Vec<Slot> = q.slots.drain(..take).collect();
        Some(Batch { slots, formed: Instant::now() })
    }

    /// Non-blocking drain of up to `n` queued slots — the continuous-batching
    /// refill: a wave entering stage 0 tops itself up to the largest bucket
    /// from whatever is queued *right now*, without waiting out `max_wait`.
    /// Drains even after [`Self::close`] so a shutdown that lands mid-refill
    /// still flushes every accepted slot to a worker (which then completes
    /// each with an error or an image — never a hang).
    pub fn take_upto(&self, n: usize) -> Vec<Slot> {
        if n == 0 {
            return Vec::new();
        }
        let mut q = self.inner.0.lock().unwrap();
        let take = q.slots.len().min(n);
        q.slots.drain(..take).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_batch_formed_immediately() {
        let b = Batcher::new(4, Duration::from_secs(10));
        let handles: Vec<_> = (0..4).map(|i| b.submit(i, i).unwrap()).collect();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.slots.len(), 4);
        assert_eq!(b.queued(), 0);
        drop(handles);
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let b = Batcher::new(8, Duration::from_millis(30));
        let _h = b.submit(1, 0).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(batch.slots.len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(4, Duration::from_millis(5));
        let _h = b.submit(1, 0).unwrap();
        b.close();
        let batch = b.next_batch();
        assert!(batch.is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn submit_after_close_fails_fast() {
        // A slot accepted after close() could never complete (workers have
        // drained and exited): the submission itself must error.
        let b = Batcher::new(4, Duration::from_millis(5));
        b.close();
        let err = b.submit(1, 0).unwrap_err().to_string();
        assert!(err.contains("closed"), "{err}");
        // Nothing was enqueued and workers still see a clean end-of-queue.
        assert_eq!(b.queued(), 0);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_flushes_waiting_partial_batch_immediately() {
        // A worker parked on a partial batch must not sit out the full
        // max_wait once the queue closes.
        let b = Batcher::new(8, Duration::from_secs(30));
        let _h = b.submit(1, 0).unwrap();
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        let batch = t.join().unwrap().unwrap();
        assert_eq!(batch.slots.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let b = Batcher::new(3, Duration::from_secs(1));
        for i in 0..3 {
            b.submit(i, 0).unwrap();
        }
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.slots.iter().map(|s| s.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn oversubmission_leaves_remainder_queued() {
        let b = Batcher::new(2, Duration::from_secs(1));
        for i in 0..5 {
            b.submit(i, 0).unwrap();
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.slots.len(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn take_upto_is_nonblocking_and_bounded() {
        let b = Batcher::new(8, Duration::from_secs(30));
        assert!(b.take_upto(4).is_empty()); // empty queue: returns immediately
        for i in 0..3 {
            b.submit(i, 0).unwrap();
        }
        let got = b.take_upto(2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].request_id, 0);
        assert_eq!(b.queued(), 1);
        assert!(b.take_upto(0).is_empty());
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn take_upto_drains_after_close() {
        // Shutdown-during-refill: slots accepted before close() must still
        // reach a worker so their completion handles fire.
        let b = Batcher::new(8, Duration::from_secs(30));
        b.submit(1, 0).unwrap();
        b.close();
        assert_eq!(b.take_upto(8).len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn cancel_flag_crosses_to_worker_slot() {
        let b = Batcher::new(1, Duration::from_secs(1));
        let h = b.submit_slot(1, 0).unwrap();
        h.cancel();
        let batch = b.next_batch().unwrap();
        assert!(batch.slots[0].cancelled());
        batch.slots[0].done.put(Err("cancelled".into()));
        assert!(h.done.wait().is_err());
    }

    #[test]
    fn cross_thread_completion() {
        let b = Batcher::new(1, Duration::from_secs(1));
        let h = b.submit(1, 7).unwrap();
        let b2 = b.clone();
        std::thread::spawn(move || {
            let batch = b2.next_batch().unwrap();
            for slot in batch.slots {
                slot.done.put(Ok(Tensor::full(&[2, 2, 3], slot.seed as f32)));
            }
        });
        let img = h.wait().unwrap();
        assert_eq!(img.data()[0], 7.0);
    }
}
