//! Typed configuration system with a TOML-subset file format and environment
//! overrides.
//!
//! Format (subset of TOML): `[section]` headers, `key = value` lines where
//! value is a string (quoted), number, bool, or `[a, b, c]` array of those;
//! `#` comments. Environment variables `SJD_<SECTION>_<KEY>` override file
//! values; CLI-provided pairs override both.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A raw config value.
#[derive(Clone, Debug, PartialEq)]
pub enum CValue {
    Str(String),
    Num(f64),
    Bool(bool),
    List(Vec<CValue>),
}

impl CValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            CValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            CValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            CValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed configuration: `section.key -> value`.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, CValue>,
}

impl Config {
    /// Parse from TOML-subset text.
    pub fn from_text(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header '{raw}'", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected 'key = value'", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let parsed = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value '{}'", lineno + 1, val.trim()))?;
            values.insert(full_key, parsed);
        }
        Ok(Config { values })
    }

    /// Load from file, then apply `SJD_*` environment overrides.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let mut cfg = Self::from_text(&text)?;
        cfg.apply_env_overrides(std::env::vars());
        Ok(cfg)
    }

    /// Apply `SJD_SECTION_KEY=value` overrides from an iterator of env pairs.
    pub fn apply_env_overrides(&mut self, vars: impl Iterator<Item = (String, String)>) {
        for (k, v) in vars {
            if let Some(rest) = k.strip_prefix("SJD_") {
                // SECTION_KEY → section.key (first underscore splits).
                if let Some((section, key)) = rest.split_once('_') {
                    let cfg_key = format!("{}.{}", section.to_lowercase(), key.to_lowercase());
                    let val =
                        parse_value(&v).unwrap_or_else(|_| CValue::Str(v.clone()));
                    self.values.insert(cfg_key, val);
                }
            }
        }
    }

    /// Set an explicit override (CLI layer).
    pub fn set(&mut self, key: &str, value: CValue) {
        self.values.insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> Option<&CValue> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(CValue::as_str).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(CValue::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(CValue::as_f64).map(|n| n as usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(CValue::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<CValue> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            bail!("unterminated string");
        }
        return Ok(CValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(CValue::Bool(true));
    }
    if s == "false" {
        return Ok(CValue::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated list");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part)?);
            }
        }
        return Ok(CValue::List(items));
    }
    s.parse::<f64>()
        .map(CValue::Num)
        .map_err(|_| anyhow!("cannot parse value '{s}'"))
}

/// Serving configuration assembled from file + env + CLI.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub model: String,
    pub addr: String,
    pub workers: usize,
    pub batch_max: usize,
    pub batch_wait_ms: u64,
    pub tau: f32,
    pub policy: String,
    pub seed: u64,
}

impl ServeConfig {
    pub fn from_config(cfg: &Config) -> Self {
        ServeConfig {
            artifacts_dir: cfg.str_or("serve.artifacts_dir", "artifacts"),
            model: cfg.str_or("serve.model", "tf10"),
            addr: cfg.str_or("serve.addr", "127.0.0.1:8471"),
            workers: cfg.usize_or("serve.workers", 2),
            batch_max: cfg.usize_or("serve.batch_max", 8),
            batch_wait_ms: cfg.usize_or("serve.batch_wait_ms", 20) as u64,
            tau: cfg.f64_or("serve.tau", 0.5) as f32,
            policy: cfg.str_or("serve.policy", "selective"),
            seed: cfg.usize_or("serve.seed", 42) as u64,
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self::from_config(&Config::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving config
[serve]
model = "tfafhq"
workers = 4
tau = 0.25
policy = "selective"   # paper default
verbose = true
taus = [0.1, 0.5, 1.0]

[batcher]
max = 16
"#;

    #[test]
    fn parse_sections_and_types() {
        let cfg = Config::from_text(SAMPLE).unwrap();
        assert_eq!(cfg.str_or("serve.model", ""), "tfafhq");
        assert_eq!(cfg.usize_or("serve.workers", 0), 4);
        assert!((cfg.f64_or("serve.tau", 0.0) - 0.25).abs() < 1e-9);
        assert!(cfg.bool_or("serve.verbose", false));
        assert_eq!(cfg.usize_or("batcher.max", 0), 16);
        match cfg.get("serve.taus").unwrap() {
            CValue::List(l) => assert_eq!(l.len(), 3),
            _ => panic!("expected list"),
        }
    }

    #[test]
    fn comments_and_defaults() {
        let cfg = Config::from_text("# only a comment\n").unwrap();
        assert_eq!(cfg.str_or("a.b", "dflt"), "dflt");
        assert_eq!(cfg.usize_or("a.n", 7), 7);
    }

    #[test]
    fn hash_inside_string_kept() {
        let cfg = Config::from_text("[s]\nv = \"a#b\"\n").unwrap();
        assert_eq!(cfg.str_or("s.v", ""), "a#b");
    }

    #[test]
    fn env_overrides() {
        let mut cfg = Config::from_text("[serve]\nworkers = 1\n").unwrap();
        cfg.apply_env_overrides(
            vec![("SJD_SERVE_WORKERS".to_string(), "8".to_string())].into_iter(),
        );
        assert_eq!(cfg.usize_or("serve.workers", 0), 8);
    }

    #[test]
    fn malformed_rejected() {
        assert!(Config::from_text("[unclosed\n").is_err());
        assert!(Config::from_text("keynovalue\n").is_err());
        assert!(Config::from_text("k = \"unterminated\n").is_err());
        assert!(Config::from_text("k = [1, 2\n").is_err());
    }

    #[test]
    fn serve_config_assembly() {
        let cfg = Config::from_text("[serve]\nmodel = \"tf100\"\nbatch_max = 4\n").unwrap();
        let sc = ServeConfig::from_config(&cfg);
        assert_eq!(sc.model, "tf100");
        assert_eq!(sc.batch_max, 4);
        assert_eq!(sc.policy, "selective"); // default preserved
    }
}
