//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the rust [`super::Engine`].
//!
//! Per-model artifacts come in **batch-bucket families** — every role
//! (`block_jstep`, `block_seqstep`, …) lowered once per batch size `B`
//! under the `{m}_<role>_b{B}` naming scheme (`aot.py --batch-sizes`).
//! [`Manifest::decode_buckets`] groups them back into the routable bucket
//! set the serving layer selects from (see `coordinator::router`).
//!
//! ## The `untupled_outputs` residency contract
//!
//! Besides each program's input/output signatures, the manifest records per
//! artifact how its HLO **root** was lowered — and that decides whether
//! `Engine::call_v` may return chainable device-resident values (see
//! `docs/ARCHITECTURE.md` §L2 for the full picture):
//!
//! * [`ArtifactMeta::untupled_outputs`]` == true` — lowered with
//!   `return_tuple=False` (single-output programs only, e.g.
//!   `{m}_reverse_b{B}`). The root is the bare array; the runtime returns
//!   one leaf buffer and the engine wraps it as a device [`super::Value`]
//!   with no leaf-vs-tuple ambiguity. Zero host traffic when chained into
//!   the next call.
//! * `false` — the root is a result tuple (every legacy and multi-output
//!   artifact). If the runtime untuples it into one buffer per output,
//!   those chain device-side too; if it hands back a single tuple-rooted
//!   buffer, the engine takes **one probed forced sync** (destructuring the
//!   result literal, leaf vs tuple judged by shape) and returns host values
//!   — chaining degrades gracefully to a host promotion on the next call,
//!   correctness is unaffected, and the sync time is charged to
//!   `CallStats::marshal_time` so the perf benches stay truthful.
//!
//! The flag is an *assertion about the lowering*, not a preference: setting
//! it on a tuple-rooted artifact would make the engine mis-wrap the result
//! buffer. `python/compile/aot.py` enforces the single-output restriction
//! at lowering time; `python/tests/test_aot.py` pins the flag per artifact
//! and `rust/tests/roundtrip.rs` is the engine-side canary.

use crate::jsonx::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" | "float32" => Ok(DType::F32),
            "i32" | "int32" => Ok(DType::I32),
            other => Err(anyhow!("unsupported dtype '{other}'")),
        }
    }
}

/// Shape + dtype + name of one artifact input or output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(v: &Value) -> Result<Self> {
        let name = v.req_str("name")?.to_string();
        let dtype = DType::from_str(v.req_str("dtype")?)?;
        let shape = v
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name, dtype, shape })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered program.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Model this artifact belongs to (e.g. "tf10"), if any.
    pub model: Option<String>,
    /// Root lowered WITHOUT a result tuple (`return_tuple=False`, single
    /// output only). Lets `Engine::call_v` wrap the output buffer as a
    /// chainable device value with no leaf-vs-tuple ambiguity; tuple-rooted
    /// legacy artifacts leave this false.
    pub untupled_outputs: bool,
}

/// Model-level metadata (mirrors the python config that trained it).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    /// Kind: "tarflow" | "maf" | "ddpm" | "mmdgen" | "metricnet".
    pub kind: String,
    /// Sequence length (tokens for tarflow, dims for maf).
    pub seq_len: usize,
    /// Number of flow blocks K (autoregressive layers for maf).
    pub blocks: usize,
    /// Token dimensionality (patch dim for tarflow; 1 for maf).
    pub token_dim: usize,
    /// Transformer width (tarflow) or hidden width (maf).
    pub model_dim: usize,
    /// Attention layers per block (tarflow only).
    pub layers_per_block: usize,
    /// Image geometry [h, w, c] if the model generates images.
    pub image_hwc: Option<[usize; 3]>,
    /// Patch size (tarflow only).
    pub patch: usize,
    /// Noise std used during training (tarflow dequantization).
    pub noise_std: f64,
    /// Batch sizes this model's artifacts were lowered for.
    pub batch_sizes: Vec<usize>,
    /// Free-form extras (dataset name, temperature, ...).
    pub extra: BTreeMap<String, Value>,
}

/// A reference dataset exported by the build path (raw little-endian f32).
#[derive(Clone, Debug)]
pub struct DatasetMeta {
    pub name: String,
    pub file: String,
    pub shape: Vec<usize>,
    pub extra: BTreeMap<String, Value>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub models: BTreeMap<String, ModelMeta>,
    pub datasets: BTreeMap<String, DatasetMeta>,
}

impl Manifest {
    /// Load and validate a manifest file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let root = jsonx::parse(&text).context("parsing manifest json")?;
        let dir = path.parent().unwrap_or_else(|| Path::new(".")).to_path_buf();

        let mut artifacts = BTreeMap::new();
        for a in root.req_arr("artifacts")? {
            let name = a.req_str("name")?.to_string();
            let meta = ArtifactMeta {
                name: name.clone(),
                file: a.req_str("file")?.to_string(),
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()
                    .with_context(|| format!("artifact '{name}' inputs"))?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()
                    .with_context(|| format!("artifact '{name}' outputs"))?,
                model: a.get("model").and_then(Value::as_str).map(str::to_string),
                untupled_outputs: a
                    .get("untupled_outputs")
                    .and_then(Value::as_bool)
                    .unwrap_or(false),
            };
            artifacts.insert(name, meta);
        }

        let mut models = BTreeMap::new();
        if let Some(arr) = root.get("models").and_then(Value::as_arr) {
            for m in arr {
                let name = m.req_str("name")?.to_string();
                let image_hwc = m.get("image_hwc").and_then(Value::as_arr).map(|a| {
                    [
                        a[0].as_usize().unwrap_or(0),
                        a[1].as_usize().unwrap_or(0),
                        a[2].as_usize().unwrap_or(0),
                    ]
                });
                let batch_sizes = m
                    .get("batch_sizes")
                    .and_then(Value::as_arr)
                    .map(|a| a.iter().filter_map(Value::as_usize).collect())
                    .unwrap_or_default();
                let mut extra = BTreeMap::new();
                if let Some(o) = m.get("extra").and_then(Value::as_obj) {
                    extra = o.clone();
                }
                models.insert(
                    name.clone(),
                    ModelMeta {
                        name,
                        kind: m.req_str("kind")?.to_string(),
                        seq_len: m.req_usize("seq_len")?,
                        blocks: m.req_usize("blocks")?,
                        token_dim: m.req_usize("token_dim")?,
                        model_dim: m.req_usize("model_dim")?,
                        layers_per_block: m.get("layers_per_block").and_then(Value::as_usize).unwrap_or(0),
                        image_hwc,
                        patch: m.get("patch").and_then(Value::as_usize).unwrap_or(1),
                        noise_std: m.get("noise_std").and_then(Value::as_f64).unwrap_or(0.0),
                        batch_sizes,
                        extra,
                    },
                );
            }
        }

        let mut datasets = BTreeMap::new();
        if let Some(arr) = root.get("datasets").and_then(Value::as_arr) {
            for d in arr {
                let name = d.req_str("name")?.to_string();
                let shape = d
                    .req_arr("shape")?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dataset shape")))
                    .collect::<Result<Vec<_>>>()?;
                let extra = d
                    .get("extra")
                    .and_then(Value::as_obj)
                    .cloned()
                    .unwrap_or_default();
                datasets.insert(
                    name.clone(),
                    DatasetMeta { name, file: d.req_str("file")?.to_string(), shape, extra },
                );
            }
        }

        let manifest = Manifest { dir, artifacts, models, datasets };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Load a reference dataset exported by the build path as a [`crate::tensor::Tensor`].
    pub fn load_dataset(&self, name: &str) -> Result<crate::tensor::Tensor> {
        let meta = self
            .datasets
            .get(name)
            .ok_or_else(|| anyhow!("unknown dataset '{name}' (have: {:?})", self.datasets.keys().collect::<Vec<_>>()))?;
        let bytes = std::fs::read(self.dir.join(&meta.file))
            .with_context(|| format!("reading dataset {}", meta.file))?;
        let numel: usize = meta.shape.iter().product();
        if bytes.len() != numel * 4 {
            return Err(anyhow!(
                "dataset '{name}': file has {} bytes, shape {:?} needs {}",
                bytes.len(),
                meta.shape,
                numel * 4
            ));
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        crate::tensor::Tensor::new(&meta.shape, data)
    }

    /// Every artifact's HLO file must exist.
    fn validate(&self) -> Result<()> {
        for a in self.artifacts.values() {
            let p = self.dir.join(&a.file);
            if !p.exists() {
                return Err(anyhow!("artifact '{}' file missing: {}", a.name, p.display()));
            }
            if let Some(m) = &a.model {
                if !self.models.contains_key(m) {
                    return Err(anyhow!("artifact '{}' references unknown model '{m}'", a.name));
                }
            }
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}' (have: {:?})", self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}' (have: {:?})", self.models.keys().collect::<Vec<_>>()))
    }

    /// Artifact names that belong to `model`.
    pub fn artifacts_for(&self, model: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.values().filter(|a| a.model.as_deref() == Some(model)).collect()
    }

    /// Group a model's artifacts into batch buckets: the ascending batch
    /// sizes `B` (from the `{m}_<role>_b{B}` name suffix) that carry the
    /// model's **complete** per-batch artifact set — a bucket missing a
    /// required role another bucket has (e.g. a `_b2` family lowered
    /// without its `block_jstep_b2`) is excluded rather than failing at
    /// decode time. Roles in [`OPTIONAL_DECODE_ROLES`] are exempt from the
    /// completeness requirement: they are pure fast paths the coordinator
    /// probes via `Backend::has_artifact` and degrades without (the fused
    /// multi-step steps fall back to their per-iteration artifacts — see
    /// `Sampler::decode_tokens`), so a bucket lowered before they existed
    /// stays routable. Models with no batch-suffixed artifacts fall back to
    /// the metadata's `batch_sizes` list. This is what the serving router
    /// treats as the routable bucket set.
    pub fn decode_buckets(&self, model: &str) -> Vec<usize> {
        use std::collections::{BTreeMap as Map, BTreeSet as Set};
        let prefix = format!("{model}_");
        let mut roles_by_bucket: Map<usize, Set<&str>> = Map::new();
        let mut required_roles: Set<&str> = Set::new();
        for a in self.artifacts_for(model) {
            let Some(rest) = a.name.strip_prefix(&prefix) else { continue };
            let Some((role, b)) = rest.rsplit_once("_b") else { continue };
            let Ok(b) = b.parse::<usize>() else { continue };
            roles_by_bucket.entry(b).or_default().insert(role);
            if !OPTIONAL_DECODE_ROLES.contains(&role) {
                required_roles.insert(role);
            }
        }
        if roles_by_bucket.is_empty() {
            let mut sizes = self
                .models
                .get(model)
                .map(|m| m.batch_sizes.clone())
                .unwrap_or_default();
            sizes.sort_unstable();
            sizes.dedup();
            return sizes;
        }
        roles_by_bucket
            .into_iter()
            .filter(|(_, roles)| required_roles.is_subset(roles))
            .map(|(b, _)| b)
            .collect()
    }
}

/// Decode-family roles a bucket may lack and still be routable: optional
/// fast paths with a documented fallback in the coordinator — the fused
/// steps degrade to their per-iteration artifacts, the speculative-init
/// projection degrades to the Zeros initialization
/// (`Sampler::decode_tokens`), and the continuous-batching slot-remap
/// gather degrades to a host row permute (`Sampler::gather_slots_v`). Keep
/// in sync with the optional-artifact lowerings in
/// `python/compile/aot.py`.
pub const OPTIONAL_DECODE_ROLES: &[&str] =
    &["block_jstep_fuse", "block_jstep_win_fuse", "init_proj", "slot_gather"];

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_manifest(dir: &Path, body: &str) -> PathBuf {
        let p = dir.join("manifest.json");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(body.as_bytes()).unwrap();
        p
    }

    #[test]
    fn load_minimal_manifest() {
        let dir = std::env::temp_dir().join("sjd_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule m").unwrap();
        let p = write_manifest(
            &dir,
            r#"{
              "artifacts": [
                {"name": "a", "file": "a.hlo.txt", "model": "m1",
                 "untupled_outputs": true,
                 "inputs": [{"name": "x", "dtype": "f32", "shape": [2, 3]}],
                 "outputs": [{"name": "y", "dtype": "f32", "shape": [2, 3]}]}
              ],
              "models": [
                {"name": "m1", "kind": "tarflow", "seq_len": 64, "blocks": 4,
                 "token_dim": 12, "model_dim": 64, "layers_per_block": 2,
                 "patch": 2, "noise_std": 0.05, "image_hwc": [16, 16, 3],
                 "batch_sizes": [1, 8]}
              ]
            }"#,
        );
        let m = Manifest::load(&p).unwrap();
        let a = m.artifact("a").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert!(a.untupled_outputs);
        let mm = m.model("m1").unwrap();
        assert_eq!(mm.seq_len, 64);
        assert_eq!(mm.image_hwc, Some([16, 16, 3]));
        assert_eq!(m.artifacts_for("m1").len(), 1);
    }

    #[test]
    fn decode_buckets_require_complete_artifact_sets() {
        let dir = std::env::temp_dir().join("sjd_manifest_buckets");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule m").unwrap();
        let art = |name: &str| {
            format!(
                r#"{{"name": "{name}", "file": "a.hlo.txt", "model": "m1",
                     "inputs": [], "outputs": []}}"#
            )
        };
        // Buckets 1 and 2 carry both roles; bucket 4 is missing its
        // seqstep, so it must not be routable.
        let arts: Vec<String> = [
            "m1_block_jstep_b1",
            "m1_block_seqstep_b1",
            "m1_block_jstep_b2",
            "m1_block_seqstep_b2",
            "m1_block_jstep_b4",
        ]
        .iter()
        .map(|n| art(n))
        .collect();
        let body = format!(
            r#"{{"artifacts": [{}],
                 "models": [{{"name": "m1", "kind": "tarflow", "seq_len": 8,
                              "blocks": 2, "token_dim": 3, "model_dim": 4,
                              "batch_sizes": [1, 2, 4]}}]}}"#,
            arts.join(",")
        );
        let p = write_manifest(&dir, &body);
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.decode_buckets("m1"), vec![1, 2]);
        // Unknown model → empty; no suffixed artifacts → metadata fallback.
        assert!(m.decode_buckets("ghost").is_empty());
    }

    #[test]
    fn decode_buckets_treat_fused_roles_as_optional() {
        let dir = std::env::temp_dir().join("sjd_manifest_buckets_fused");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule m").unwrap();
        let art = |name: &str| {
            format!(
                r#"{{"name": "{name}", "file": "a.hlo.txt", "model": "m1",
                     "inputs": [], "outputs": []}}"#
            )
        };
        // Bucket 1 predates the fused/init-proj artifacts, bucket 2 has
        // them: BOTH are routable (optional roles are probed fast paths
        // with documented fallbacks, not required roles). Bucket 4 carries
        // only optional roles and misses required ones → excluded.
        let arts: Vec<String> = [
            "m1_block_jstep_b1",
            "m1_block_seqstep_b1",
            "m1_block_jstep_b2",
            "m1_block_seqstep_b2",
            "m1_block_jstep_fuse_b2",
            "m1_block_jstep_win_fuse_b2",
            "m1_init_proj_b2",
            "m1_block_jstep_fuse_b4",
            "m1_init_proj_b4",
        ]
        .iter()
        .map(|n| art(n))
        .collect();
        let body = format!(
            r#"{{"artifacts": [{}],
                 "models": [{{"name": "m1", "kind": "tarflow", "seq_len": 8,
                              "blocks": 2, "token_dim": 3, "model_dim": 4,
                              "batch_sizes": [1, 2, 4]}}]}}"#,
            arts.join(",")
        );
        let p = write_manifest(&dir, &body);
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.decode_buckets("m1"), vec![1, 2]);
    }

    #[test]
    fn decode_buckets_fall_back_to_model_meta() {
        let dir = std::env::temp_dir().join("sjd_manifest_buckets2");
        std::fs::create_dir_all(&dir).unwrap();
        let body = r#"{"artifacts": [],
                       "models": [{"name": "m1", "kind": "maf", "seq_len": 8,
                                   "blocks": 2, "token_dim": 1, "model_dim": 4,
                                   "batch_sizes": [256, 256, 50]}]}"#;
        let p = write_manifest(&dir, body);
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.decode_buckets("m1"), vec![50, 256]);
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("sjd_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_manifest(
            &dir,
            r#"{"artifacts": [{"name": "a", "file": "nope.hlo.txt", "inputs": [], "outputs": []}]}"#,
        );
        assert!(Manifest::load(&p).is_err());
    }

    #[test]
    fn unknown_artifact_error_lists_names() {
        let dir = std::env::temp_dir().join("sjd_manifest_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_manifest(&dir, r#"{"artifacts": []}"#);
        let m = Manifest::load(&p).unwrap();
        let err = m.artifact("ghost").unwrap_err().to_string();
        assert!(err.contains("ghost"));
    }
}
