//! §Perf micro-benches: per-call runtime overhead (marshal vs execute),
//! jstep/seqstep unit costs, batcher formation latency, buffer pool, and RNG
//! throughput. These feed the EXPERIMENTS.md §Perf iteration log.

mod common;

use common::*;
use sjd::benchkit::{time_fn, Report};
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::sampler::Sampler;
use sjd::coordinator::state::BufferPool;
use sjd::runtime::HostTensor;
use sjd::tensor::{Pcg64, Tensor};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let mut report = Report::new("§Perf — microbenchmarks");
    let mut rows = Vec::new();
    let iters = if quick() { 5 } else { 30 };

    // --- artifact call costs ---
    let model = "tf10";
    if engine.manifest().model(model).is_ok() {
        let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
        let sampler = Sampler::new(&engine, model, batch)?;
        let meta = &sampler.meta;
        let (l, d) = (meta.seq_len, meta.token_dim);
        let mut rng = Pcg64::seed(1);
        let z = HostTensor::f32(&[batch, l, d], Tensor::randn(&[batch, l, d], &mut rng).into_data());
        let y = z.clone();
        let jstep = format!("{model}_block_jstep_b{batch}");
        engine.warmup(&[&jstep])?;
        let t = time_fn(3, iters, || {
            let _ = engine
                .call(&jstep, &[HostTensor::scalar_i32(0), z.clone(), y.clone(), HostTensor::scalar_i32(0)])
                .unwrap();
        });
        rows.push(vec![
            format!("jstep call ({model} b{batch})"),
            format!("{:.2} ms", t.mean.as_secs_f64() * 1e3),
        ]);

        // Marshal vs execute split from engine stats.
        engine.reset_stats();
        for _ in 0..iters {
            let _ = engine.call(
                &jstep,
                &[HostTensor::scalar_i32(0), z.clone(), y.clone(), HostTensor::scalar_i32(0)],
            )?;
        }
        let stats = engine.stats();
        let s = &stats[&jstep];
        rows.push(vec![
            "jstep exec / marshal split".into(),
            format!(
                "{:.2} ms exec, {:.3} ms marshal",
                s.exec_time.as_secs_f64() * 1e3 / s.calls as f64,
                s.marshal_time.as_secs_f64() * 1e3 / s.calls as f64
            ),
        ]);

        let seqstep = format!("{model}_block_seqstep_b{batch}");
        engine.warmup(&[&seqstep])?;
        let (nl, dm) = (meta.layers_per_block, meta.model_dim);
        let kv = HostTensor::f32(&[nl, batch, l, dm], vec![0.0; nl * batch * l * dm]);
        let tok = HostTensor::f32(&[batch, d], vec![0.0; batch * d]);
        let t = time_fn(3, iters, || {
            let _ = engine
                .call(
                    &seqstep,
                    &[
                        HostTensor::scalar_i32(0),
                        tok.clone(),
                        tok.clone(),
                        HostTensor::scalar_i32(5),
                        kv.clone(),
                        kv.clone(),
                    ],
                )
                .unwrap();
        });
        rows.push(vec![
            format!("seqstep call ({model} b{batch})"),
            format!("{:.2} ms", t.mean.as_secs_f64() * 1e3),
        ]);
    }

    // --- host-side substrates ---
    let mut rng = Pcg64::seed(2);
    let t = time_fn(2, 50, || {
        let _ = std::hint::black_box(Tensor::randn(&[8, 256, 12], &mut rng));
    });
    rows.push(vec!["prior randn (8×256×12)".into(), format!("{:.0} µs", t.mean.as_secs_f64() * 1e6)]);

    let pool = BufferPool::new();
    let t = time_fn(2, 200, || {
        let b = pool.take_zeroed(&[2, 8, 256, 96]);
        pool.give_back(std::hint::black_box(b));
    });
    rows.push(vec!["buffer pool take+return (1.5 MB)".into(), format!("{:.0} µs", t.mean.as_secs_f64() * 1e6)]);

    let batcher = Batcher::new(8, Duration::from_millis(1));
    let t = time_fn(2, 100, || {
        for i in 0..8 {
            let _ = batcher.submit(i, i);
        }
        let _ = std::hint::black_box(batcher.next_batch());
    });
    rows.push(vec!["batcher 8-slot form".into(), format!("{:.0} µs", t.mean.as_secs_f64() * 1e6)]);

    report.table(&["Operation", "Cost"], &rows);
    report.finish();
    Ok(())
}
