//! Minimal HTTP/1.1 server front end.
//!
//! Routes:
//! * `POST /generate` — body `{"n": 4, "seed": 7}` → JSON with base64 PNGs.
//! * `GET /metrics`   — text exposition of the metrics registry.
//! * `GET /healthz`   — liveness.
//!
//! The HTTP layer is deliberately small (request line + headers +
//! content-length bodies, one request per connection unless keep-alive) —
//! it exists so the serving loop is exercised end-to-end, not to be a
//! general web server.

use super::batcher::Batcher;
use crate::imageio::{self, Image};
use crate::jsonx::{self, Value};
use crate::metrics::Registry;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Parse one HTTP/1.1 request from a buffered stream.
pub fn parse_request(reader: &mut impl BufRead) -> Result<HttpRequest> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        bail!("connection closed");
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }

    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > 64 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(HttpRequest { method, path, body })
}

/// Serialize an HTTP response.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        500 => "Internal Server Error",
        _ => "",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    Ok(())
}

/// Standard base64 (RFC 4648) encoding for PNG payloads in JSON responses.
pub fn base64_encode(data: &[u8]) -> String {
    const TABLE: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(TABLE[(n >> 18) as usize & 63] as char);
        out.push(TABLE[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { TABLE[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { TABLE[n as usize & 63] as char } else { '=' });
    }
    out
}

/// Serving front end bound to a batcher + metrics registry.
pub struct Server {
    pub addr: String,
    batcher: Batcher,
    registry: Registry,
    next_request_id: AtomicU64,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(addr: impl Into<String>, batcher: Batcher, registry: Registry) -> Self {
        Server {
            addr: addr.into(),
            batcher,
            registry,
            next_request_id: AtomicU64::new(1),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Blocking accept loop; returns when the stop flag is set (checked
    /// between connections — pair with a dummy connection to unblock).
    pub fn run(&self) -> Result<()> {
        let listener = TcpListener::bind(&self.addr)
            .with_context(|| format!("binding {}", self.addr))?;
        log::info!("listening on {}", self.addr);
        for conn in listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    if let Err(e) = self.handle(stream) {
                        log::warn!("connection error: {e:#}");
                    }
                }
                Err(e) => log::warn!("accept error: {e}"),
            }
        }
        Ok(())
    }

    fn handle(&self, stream: TcpStream) -> Result<()> {
        let mut reader = BufReader::new(stream.try_clone()?);
        let req = parse_request(&mut reader)?;
        let mut stream = stream;
        self.registry.counter("sjd_http_requests").inc();
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => write_response(&mut stream, 200, "text/plain", b"ok"),
            ("GET", "/metrics") => {
                let text = self.registry.render_text();
                write_response(&mut stream, 200, "text/plain", text.as_bytes())
            }
            ("POST", "/generate") => match self.generate(&req.body) {
                Ok(json) => write_response(&mut stream, 200, "application/json", json.as_bytes()),
                Err(e) => {
                    self.registry.counter("sjd_http_errors").inc();
                    let msg = format!("{{\"error\": \"{e}\"}}");
                    write_response(&mut stream, 400, "application/json", msg.as_bytes())
                }
            },
            _ => write_response(&mut stream, 404, "text/plain", b"not found"),
        }
    }

    fn generate(&self, body: &[u8]) -> Result<String> {
        let text = std::str::from_utf8(body).context("body not utf-8")?;
        let v = if text.trim().is_empty() {
            Value::obj(vec![])
        } else {
            jsonx::parse(text).context("bad json")?
        };
        let n = v.get("n").and_then(Value::as_usize).unwrap_or(1).clamp(1, 64);
        let seed = v.get("seed").and_then(Value::as_usize).unwrap_or(0) as u64;
        let rid = self.next_request_id.fetch_add(1, Ordering::SeqCst);

        // Submit n slots and wait for completion.
        let handles: Vec<_> =
            (0..n).map(|i| self.batcher.submit(rid, seed.wrapping_add(i as u64))).collect();
        let mut pngs = Vec::with_capacity(n);
        for h in handles {
            let img_t = h.wait();
            let img = Image::from_tensor_pm1(&img_t)?;
            let png = imageio::encode_png(&img)?;
            pngs.push(Value::Str(base64_encode(&png)));
        }
        let resp = Value::obj(vec![
            ("request_id", Value::num(rid as f64)),
            ("n", Value::num(n as f64)),
            ("images_png_b64", Value::Arr(pngs)),
        ]);
        Ok(jsonx::to_string_pretty(&resp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base64_vectors() {
        // RFC 4648 test vectors.
        assert_eq!(base64_encode(b""), "");
        assert_eq!(base64_encode(b"f"), "Zg==");
        assert_eq!(base64_encode(b"fo"), "Zm8=");
        assert_eq!(base64_encode(b"foo"), "Zm9v");
        assert_eq!(base64_encode(b"foobar"), "Zm9vYmFy");
    }

    #[test]
    fn parse_simple_request() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"n\":2}";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, b"{\"n\":2}");
    }

    #[test]
    fn parse_request_without_body() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_bad_version_and_eof() {
        let raw = b"GET / SPDY/3\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(parse_request(&mut r).is_err());
        let mut empty = std::io::BufReader::new(&b""[..]);
        assert!(parse_request(&mut empty).is_err());
    }

    #[test]
    fn response_format() {
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "text/plain", b"hi").unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));
    }
}
