//! Jacobi decoding driver (paper Alg 1) and its windowed GS-Jacobi variant.
//!
//! One Jacobi *step* is an AOT artifact call `(k, z_t, y) → (z_{t+1}, resid)`
//! that updates every position of the sequence in parallel from the previous
//! iterate (the L1 Pallas hot path). This driver owns the L3 concerns: the
//! initialization strategy, the τ stopping rule on ‖z^t − z^{t−1}‖∞, the
//! worst-case `L` iteration guard (Prop 3.2 guarantees exactness at `t = L`),
//! and per-layer statistics for the selective policy / paper tables.
//!
//! Both drivers are **device-resident** (see `docs/ARCHITECTURE.md` for the
//! full residency map): the block input `y` and the loop scalars are uploaded
//! once, the iterate `z` chains device→device across iterations, and the only
//! per-iteration host sync is the `[B]` residual needed for the τ test.
//! [`jacobi_decode_block`] is the host-tensor convenience wrapper.
//!
//! ## Windowed GS-Jacobi ([`gs_jacobi_decode_block_v`])
//!
//! Full-sequence Jacobi keeps re-updating positions that converged many
//! iterations ago (early positions are exact after Prop 3.2's induction
//! reaches them). The GS-Jacobi variant (after "Accelerate TarFlow Sampling
//! with GS-Jacobi Iteration", arXiv 2505.12849) partitions the `L` positions
//! into `W` contiguous windows, sweeps the windows **in order**
//! (Gauss–Seidel: window `w` conditions on the already-converged windows
//! `< w`) and iterates Jacobi only **inside** the active window via the
//! `{m}_block_jstep_win_b{B}` artifact, which freezes every position outside
//! `[off, off+len)` and reports the residual over the window only. The
//! per-window iteration cap is the window length — Prop 3.2 applied to the
//! window given an exact prefix — so the sweep with τ = 0 is *exact*, and
//! `W = 1` degrades to plain Jacobi while `W = L` degrades to sequential
//! decoding (one exact iteration per position). Total work is measured in
//! **position-updates** (Σ over windows of `iterations × len`), with two
//! savings regimes: strongly coupled blocks (iterations ≈ `L`) cut from
//! `O(L²)` toward `O(L²/W)` at any window count, while weakly coupled
//! blocks (`t ≪ L` iterations) save only once the window length drops
//! below `t` — the per-window cap then bounds updates by `len·L < t·L`, at
//! the price of more artifact calls. [`calibrate_windows`] picks per-block
//! window counts along exactly this trade-off.
//!
//! [`calibrate_windows`]: super::policy::calibrate_windows

use crate::runtime::{Backend, HostTensor, Value};
use crate::tensor::Pcg64;
use anyhow::{Context, Result};
use std::time::{Duration, Instant};

/// How `z⁰` is initialized (paper Fig 6 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitStrategy {
    /// `z⁰ = 0` (paper default, Alg 1).
    Zeros,
    /// `z⁰ ~ N(0, I)`.
    Normal,
    /// `z⁰ = z_{k+1}` (previous layer's output — the Jacobi input itself).
    PrevLayer,
}

impl InitStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "zeros" => Some(InitStrategy::Zeros),
            "normal" => Some(InitStrategy::Normal),
            "prev" | "prev_layer" => Some(InitStrategy::PrevLayer),
            _ => None,
        }
    }
}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct JacobiConfig {
    /// Stopping threshold τ on ‖z^t − z^{t−1}‖∞ (paper default 0.5).
    pub tau: f32,
    /// Hard iteration cap for the whole block; `None` ⇒ the sequence length
    /// `L` (Prop 3.2 bound). GS-Jacobi treats it as the same *total* budget,
    /// shared across all windows (each window is additionally capped at its
    /// own length).
    pub max_iters: Option<usize>,
    pub init: InitStrategy,
    /// Seed for `InitStrategy::Normal`.
    pub seed: u64,
}

impl Default for JacobiConfig {
    fn default() -> Self {
        JacobiConfig { tau: 0.5, max_iters: None, init: InitStrategy::Zeros, seed: 0 }
    }
}

/// Statistics of one Jacobi decode of one block.
#[derive(Clone, Debug)]
pub struct JacobiStats {
    pub block: usize,
    pub iterations: usize,
    pub wall: Duration,
    /// Residual ‖z^t − z^{t−1}‖∞ after each iteration.
    pub residuals: Vec<f32>,
    /// Whether the τ criterion was reached (vs hitting the iteration cap).
    pub converged: bool,
}

/// Decode block `k` by Jacobi iteration, keeping the iterate device-resident.
///
/// `y` is the block input `z_{k+1}` with shape (B, L, D) — host values are
/// uploaded exactly once, device values are used in place (the block-chaining
/// path of `Sampler::decode_tokens`). The artifact
/// `{model}_block_jstep_b{B}` computes one parallel update plus the residual
/// max over the batch; per iteration only that `[B]` residual crosses to the
/// host. The final iterate is returned still device-resident. `mask_o > 0`
/// applies the paper's eq-6 dependency mask (used for the Fig 1/2 redundancy
/// experiments); `mask_o = 0` is the exact update of Alg 1.
pub fn jacobi_decode_block_v<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
) -> Result<(Value, JacobiStats)> {
    jacobi_decode_block_v_init(engine, artifact, block, y, seq_len, cfg, mask_o, None)
}

/// [`jacobi_decode_block_v`] with an optional pre-built initial iterate.
///
/// When `z0` is provided it is used as `z⁰` verbatim — the caller must make
/// it consistent with `cfg.init` (the `Sampler` passes its pool's cached
/// device zeros for `InitStrategy::Zeros`, turning the per-block z⁰ upload
/// into one upload per process lifetime).
#[allow(clippy::too_many_arguments)]
pub fn jacobi_decode_block_v_init<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
    z0: Option<Value>,
) -> Result<(Value, JacobiStats)> {
    let t0 = Instant::now();
    let (y_dev, k_scalar, mut z) = pin_decode_inputs(engine, block, y, cfg, z0)?;
    let o_scalar = engine.to_device(&HostTensor::scalar_i32(mask_o as i32))?;

    let cap = cfg.max_iters.unwrap_or(seq_len);
    let mut residuals = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    while iterations < cap {
        let outs = engine.call_v(
            artifact,
            &[k_scalar.clone(), z, y_dev.clone(), o_scalar.clone()],
        )?;
        let mut it = outs.into_iter();
        let z_next = it.next().context("jstep returns z'")?;
        let resid_v = it.next().context("jstep returns residual")?;
        // The τ test is the only per-iteration sync: a [B] residual vector.
        let resid =
            engine.to_host(resid_v)?.as_f32()?.iter().copied().fold(0.0f32, f32::max);
        residuals.push(resid);
        z = z_next;
        iterations += 1;
        if resid < cfg.tau {
            converged = true;
            break;
        }
    }

    Ok((
        z,
        JacobiStats { block, iterations, wall: t0.elapsed(), residuals, converged },
    ))
}

/// Host-tensor convenience wrapper over [`jacobi_decode_block_v`]: uploads
/// `y`, decodes, and syncs the final iterate back.
pub fn jacobi_decode_block<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &HostTensor,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
) -> Result<(HostTensor, JacobiStats)> {
    let (z, stats) = jacobi_decode_block_v(
        engine,
        artifact,
        block,
        &Value::Host(y.clone()),
        seq_len,
        cfg,
        mask_o,
    )?;
    Ok((engine.to_host(z)?, stats))
}

/// Pin a block decode's loop constants on device and build its initial
/// iterate — shared by the plain and GS drivers so their init contracts
/// cannot drift. `y` uploads at most once (device values pass through);
/// `z0`, when supplied, is used verbatim; otherwise `PrevLayer` aliases
/// `y`'s device handle (no upload at all) and Zeros/Normal build z⁰
/// host-side via the shared [`init_iterate`] (one source of truth) and
/// upload it once. Returns `(y_dev, k_scalar, z)`.
fn pin_decode_inputs<B: Backend>(
    engine: &B,
    block: usize,
    y: &Value,
    cfg: &JacobiConfig,
    z0: Option<Value>,
) -> Result<(Value, Value, Value)> {
    let y_dev = match y {
        Value::Host(t) => engine.to_device(t)?,
        Value::Device(_) => y.clone(),
    };
    let k_scalar = engine.to_device(&HostTensor::scalar_i32(block as i32))?;
    let z = match (z0, cfg.init) {
        (Some(z0), _) => z0,
        (None, InitStrategy::PrevLayer) => y_dev.clone(),
        (None, _) => {
            let proto = HostTensor::f32(y_dev.shape(), vec![0.0; y_dev.numel()]);
            engine.to_device(&init_iterate(&proto, cfg))?
        }
    };
    Ok((y_dev, k_scalar, z))
}

/// Partition `seq_len` positions into `windows` contiguous windows, as
/// evenly as possible (the first `seq_len % windows` windows get one extra
/// position). `windows` is clamped to `1..=seq_len`, so `W = 0` behaves as
/// one full-sequence window and `W > L` as one window per position.
pub fn window_partition(seq_len: usize, windows: usize) -> Vec<(usize, usize)> {
    if seq_len == 0 {
        return Vec::new();
    }
    let w = windows.clamp(1, seq_len);
    let (base, rem) = (seq_len / w, seq_len % w);
    let mut out = Vec::with_capacity(w);
    let mut off = 0;
    for i in 0..w {
        let len = base + usize::from(i < rem);
        out.push((off, len));
        off += len;
    }
    out
}

/// Statistics of one window of a GS-Jacobi decode.
#[derive(Clone, Debug)]
pub struct WindowStats {
    /// First position of the window.
    pub offset: usize,
    /// Number of positions in the window.
    pub len: usize,
    /// Jacobi iterations spent inside the window.
    pub iterations: usize,
    /// Batch-max windowed residual after each iteration.
    pub residuals: Vec<f32>,
    /// Whether every batch element reached τ (vs hitting the `len` cap).
    pub converged: bool,
    /// Per batch element: the iteration (1-based) at which its windowed
    /// residual first fell below τ; `None` = the window relied on the
    /// exactness cap for that element.
    pub converged_at: Vec<Option<usize>>,
}

/// Statistics of one GS-Jacobi decode of one block.
#[derive(Clone, Debug)]
pub struct GsJacobiStats {
    pub block: usize,
    /// Per-window breakdown, in sweep order.
    pub windows: Vec<WindowStats>,
    pub wall: Duration,
    /// Total jstep_win artifact calls (Σ window iterations).
    pub iterations: usize,
    /// Total position-updates performed: Σ over windows of
    /// `iterations × len`. Full-sequence Jacobi costs `iterations × L`; the
    /// saving is the paper-faithful work metric (`benches/gs_windows.rs`).
    pub position_updates: usize,
    /// Whether every batch element's convergence front reached `L` — each
    /// window settled either by τ (the element's final windowed residual)
    /// or by running its full `len`-iteration exactness cap (Prop 3.2 per
    /// window). `false` only when the `max_iters` budget ran out before a
    /// window reached either (per-window τ-vs-cap detail:
    /// [`WindowStats::converged`]).
    pub converged: bool,
    /// Per batch element: the convergence front — positions `< front[b]`
    /// are frozen and final, certified per window by the element's final
    /// residual under τ or by the exactness cap
    /// ([`WindowStats::converged_at`] records first τ crossings for
    /// observability only). The windowed artifact excludes everything left
    /// of the active window from the residual, so a settled prefix never
    /// re-enters the τ test.
    pub front: Vec<usize>,
}

/// Decode block `k` by windowed GS-Jacobi iteration (module docs), keeping
/// the iterate device-resident throughout.
///
/// `artifact` is the windowed step `{m}_block_jstep_win_b{B}`:
/// `(k, z_t, y, off, len) → (z_{t+1}, resid[B])`, where positions outside
/// `[off, off+len)` are copied through and the residual covers the window
/// only. `y` follows the same one-upload contract as
/// [`jacobi_decode_block_v`]; `z0`, when given, is used verbatim (the
/// `Sampler` passes pooled device zeros). Per iteration only the `[B]`
/// windowed residual syncs to the host.
#[allow(clippy::too_many_arguments)]
pub fn gs_jacobi_decode_block_v<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &Value,
    seq_len: usize,
    windows: usize,
    cfg: &JacobiConfig,
    z0: Option<Value>,
) -> Result<(Value, GsJacobiStats)> {
    let t0 = Instant::now();
    let (y_dev, k_scalar, mut z) = pin_decode_inputs(engine, block, y, cfg, z0)?;

    let mut stats = GsJacobiStats {
        block,
        windows: Vec::new(),
        wall: Duration::ZERO,
        iterations: 0,
        position_updates: 0,
        converged: false,
        front: Vec::new(),
    };
    // `max_iters` keeps its plain-Jacobi meaning — a *total* iteration
    // budget for the block — shared across all windows.
    let mut budget = cfg.max_iters.unwrap_or(usize::MAX);
    for (off, len) in window_partition(seq_len, windows) {
        // Prop 3.2 applied to the window: with the prefix frozen, `len`
        // iterations are exact — never iterate past that.
        let cap = len.min(budget);
        let mut ws = WindowStats {
            offset: off,
            len,
            iterations: 0,
            residuals: Vec::new(),
            converged: false,
            converged_at: Vec::new(),
        };
        let mut last_resid: Vec<f32> = Vec::new();
        if cap > 0 {
            let off_scalar = engine.to_device(&HostTensor::scalar_i32(off as i32))?;
            let len_scalar = engine.to_device(&HostTensor::scalar_i32(len as i32))?;
            while ws.iterations < cap {
                let outs = engine.call_v(
                    artifact,
                    &[
                        k_scalar.clone(),
                        z,
                        y_dev.clone(),
                        off_scalar.clone(),
                        len_scalar.clone(),
                    ],
                )?;
                let mut it = outs.into_iter();
                let z_next = it.next().context("jstep_win returns z'")?;
                let resid_v = it.next().context("jstep_win returns residual")?;
                // The τ test is the only per-iteration sync: a [B] residual.
                let resid = engine.to_host(resid_v)?.as_f32()?.to_vec();
                if stats.front.is_empty() {
                    stats.front = vec![0; resid.len()];
                }
                if ws.converged_at.is_empty() {
                    ws.converged_at = vec![None; resid.len()];
                }
                z = z_next;
                ws.iterations += 1;
                let mut max_r = 0.0f32;
                for (b, &r) in resid.iter().enumerate() {
                    if r < cfg.tau && ws.converged_at[b].is_none() {
                        ws.converged_at[b] = Some(ws.iterations);
                    }
                    max_r = max_r.max(r);
                }
                ws.residuals.push(max_r);
                last_resid = resid;
                if max_r < cfg.tau {
                    ws.converged = true;
                    break;
                }
            }
        }
        budget -= ws.iterations;
        stats.iterations += ws.iterations;
        stats.position_updates += ws.iterations * len;
        // Advance each element's front through windows it settled in,
        // contiguously from the left: its *final* residual under τ, or the
        // full `len`-iteration cap completed (Prop 3.2 ⇒ the window is
        // exact given its settled prefix, even though the last movement
        // exceeded τ). An intermediate dip below τ certifies nothing — the
        // residual is not monotone while window positions still move.
        let exact_stop = ws.iterations == len;
        for (b, f) in stats.front.iter_mut().enumerate() {
            let tau_ok = last_resid.get(b).is_some_and(|&r| r < cfg.tau);
            if *f == off && (tau_ok || exact_stop) {
                *f = off + len;
            }
        }
        stats.windows.push(ws);
    }
    stats.converged =
        !stats.front.is_empty() && stats.front.iter().all(|&f| f == seq_len);
    stats.wall = t0.elapsed();
    Ok((z, stats))
}

/// Host-tensor convenience wrapper over [`gs_jacobi_decode_block_v`].
#[allow(clippy::too_many_arguments)]
pub fn gs_jacobi_decode_block<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &HostTensor,
    seq_len: usize,
    windows: usize,
    cfg: &JacobiConfig,
) -> Result<(HostTensor, GsJacobiStats)> {
    let (z, stats) = gs_jacobi_decode_block_v(
        engine,
        artifact,
        block,
        &Value::Host(y.clone()),
        seq_len,
        windows,
        cfg,
        None,
    )?;
    Ok((engine.to_host(z)?, stats))
}

/// Build the initial iterate `z⁰` per the configured strategy (host-side;
/// [`jacobi_decode_block_v`] uploads its result for the Zeros/Normal cases).
pub fn init_iterate(y: &HostTensor, cfg: &JacobiConfig) -> HostTensor {
    match cfg.init {
        InitStrategy::Zeros => HostTensor::f32(y.shape(), vec![0.0; y.len()]),
        InitStrategy::Normal => {
            let mut rng = Pcg64::seed(cfg.seed);
            HostTensor::f32(y.shape(), (0..y.len()).map(|_| rng.next_gaussian()).collect())
        }
        InitStrategy::PrevLayer => y.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_strategies() {
        let y = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let zeros = init_iterate(&y, &JacobiConfig::default());
        assert_eq!(zeros.as_f32().unwrap(), &[0.0; 6]);

        let prev = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::PrevLayer, ..Default::default() },
        );
        assert_eq!(prev.as_f32().unwrap(), y.as_f32().unwrap());

        let n1 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        let n2 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        assert_eq!(n1.as_f32().unwrap(), n2.as_f32().unwrap());
        assert_ne!(n1.as_f32().unwrap(), zeros.as_f32().unwrap());
    }

    #[test]
    fn parse_init() {
        assert_eq!(InitStrategy::parse("zeros"), Some(InitStrategy::Zeros));
        assert_eq!(InitStrategy::parse("normal"), Some(InitStrategy::Normal));
        assert_eq!(InitStrategy::parse("prev"), Some(InitStrategy::PrevLayer));
        assert_eq!(InitStrategy::parse("bogus"), None);
    }

    #[test]
    fn default_config_matches_paper() {
        let c = JacobiConfig::default();
        assert_eq!(c.tau, 0.5);
        assert_eq!(c.init, InitStrategy::Zeros);
        assert!(c.max_iters.is_none());
    }

    #[test]
    fn window_partition_covers_sequence() {
        for (l, w) in [(64, 4), (64, 1), (64, 64), (7, 3), (8, 5), (1, 1)] {
            let parts = window_partition(l, w);
            assert_eq!(parts.len(), w.min(l));
            assert_eq!(parts[0].0, 0);
            let mut expect_off = 0;
            for &(off, len) in &parts {
                assert_eq!(off, expect_off, "windows must be contiguous");
                assert!(len >= 1);
                expect_off += len;
            }
            assert_eq!(expect_off, l, "windows must cover all {l} positions");
            // Even split: lengths differ by at most one.
            let lens: Vec<usize> = parts.iter().map(|p| p.1).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "uneven partition {lens:?}");
        }
    }

    #[test]
    fn window_partition_degenerate_cases() {
        // W = 1 ⇒ one full-sequence window (plain Jacobi).
        assert_eq!(window_partition(8, 1), vec![(0, 8)]);
        // W = L ⇒ one window per position (sequential-equivalent).
        assert_eq!(window_partition(3, 3), vec![(0, 1), (1, 1), (2, 1)]);
        // W = 0 and W > L clamp rather than panic.
        assert_eq!(window_partition(8, 0), vec![(0, 8)]);
        assert_eq!(window_partition(2, 9), vec![(0, 1), (1, 1)]);
        // Non-divisible: extra positions go to the leading windows.
        assert_eq!(window_partition(7, 3), vec![(0, 3), (3, 2), (5, 2)]);
        assert!(window_partition(0, 4).is_empty());
    }
}
