//! **Fig 4 / Fig A2**: convergence dynamics of Jacobi decoding per layer —
//! ℓ2 error between the iterate z^t and the exact sequential solution, with
//! the sequential baseline's prefix error as reference.
//!
//! Paper shape: all layers converge in ≪ L iterations; the first generation
//! layer (decode position 0) converges markedly slower than the rest.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::jacobi::{init_iterate, JacobiConfig};
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::runtime::HostTensor;
use sjd::tensor::Pcg64;

fn l2(a: &HostTensor, b: &HostTensor) -> f64 {
    let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
    (a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()).sqrt()
}

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = if engine.manifest().model("tfafhq").is_ok() { "tfafhq" } else { "tf10" };
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().min().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let kk = sampler.meta.blocks;
    let ll = sampler.meta.seq_len;
    let max_t = if quick() { 12 } else { 24.min(ll) };

    let mut report = Report::new(format!("Fig 4/A2 — Jacobi convergence per layer ({model})"));
    let exact_cfg = JacobiConfig { tau: 0.0, max_iters: Some(ll), ..Default::default() };

    // Decode a prior batch, capturing the error trajectory per block.
    let mut rng = Pcg64::seed(21);
    let mut h = sampler.sample_prior(&mut rng);
    for pos in 0..kk {
        let k = kk - 1 - pos;
        // Ground truth: exact solve (L iterations, Prop 3.2).
        let (u_star, _) = sampler.jacobi_decode(k, &h, &exact_cfg, 0)?;

        // Jacobi trajectory errors.
        let mut z = init_iterate(&h, &JacobiConfig::default());
        let mut errs = vec![l2(&z, &u_star)];
        for _ in 0..max_t {
            let outs = engine.call(
                sampler.jstep_artifact(),
                &[
                    HostTensor::scalar_i32(k as i32),
                    z,
                    h.clone(),
                    HostTensor::scalar_i32(0),
                ],
            )?;
            z = outs.into_iter().next().unwrap();
            errs.push(l2(&z, &u_star));
        }

        // Sequential reference: error of the baseline after t of its L steps,
        // with un-inferred positions taken from the block input (paper's
        // default-implementation convention). Computed from u_star directly:
        // after t sequential steps positions < t are exact, >= t hold h.
        let d = sampler.meta.token_dim;
        let us = u_star.as_f32()?;
        let hs = h.as_f32()?;
        let mut seq_errs = Vec::with_capacity(max_t + 1);
        for t in 0..=max_t {
            let cut = (t * ll) / max_t.max(1); // rescale t to L steps
            let mut e2 = 0.0f64;
            for bi in 0..batch {
                for li in cut..ll {
                    for di in 0..d {
                        let idx = (bi * ll + li) * d + di;
                        e2 += ((hs[idx] - us[idx]) as f64).powi(2);
                    }
                }
            }
            seq_errs.push(e2.sqrt());
        }

        println!("layer {} (block {k}): jacobi errs {:?}", pos + 1, &errs[..8.min(errs.len())]);
        report.series(&format!("layer{}_jacobi_l2err", pos + 1), &errs);
        report.series(&format!("layer{}_sequential_ref (x-axis rescaled to L steps)", pos + 1), &seq_errs);

        // Move on with the exact solution (keeps layers comparable).
        h = if k % 2 == 1 {
            sampler.reverse_tokens(&u_star)?
        } else {
            u_star
        };
    }

    report.note("Paper shape: all layers ≪ L iterations to near-zero error; the first generation layer is markedly slower.");

    // Position-update accounting at the paper-default τ: the convergence
    // curves above translate into total work — windowed GS-Jacobi stops
    // re-updating the converged prefix, UJD/SJD do not (detailed sweep in
    // `benches/gs_windows.rs`).
    let mut policies = vec![
        DecodePolicy::UniformJacobi,
        DecodePolicy::Selective { seq_blocks: 1 },
    ];
    if sampler.has_gs_artifact() {
        policies.push(DecodePolicy::GsJacobi { windows: 4 });
    } else {
        report.note("(windowed jstep artifact not lowered — GS-Jacobi row skipped)");
    }
    let mut rows = Vec::new();
    for policy in policies {
        let label = policy.label();
        let opts = SampleOptions { policy, ..Default::default() };
        let mut rng = Pcg64::seed(22);
        let z = sampler.sample_prior(&mut rng);
        let out = sampler.decode_tokens(z, &opts)?;
        let calls: usize = out.traces.iter().map(|t| t.steps).sum();
        println!(
            "{label:>14}: {} position-updates, {calls} step calls at τ = 0.5",
            out.total_position_updates()
        );
        rows.push(vec![
            label,
            out.total_position_updates().to_string(),
            calls.to_string(),
        ]);
    }
    report.table(&["policy", "position-updates (τ = 0.5)", "step calls"], &rows);
    report.finish();
    Ok(())
}
