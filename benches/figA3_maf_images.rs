//! **Fig A3**: MAF on binary digit images — sequential vs all-layer Jacobi
//! decoding, visual sheet + timing (paper: 18.4× on binary MNIST).

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::maf::{MafMode, MafSampler};
use sjd::imageio::{compose_grid, write_png, Image};
use sjd::tensor::{Pcg64, Tensor};

fn to_images(samples: &[f32], n: usize, side: usize) -> anyhow::Result<Vec<Image>> {
    let d = side * side;
    (0..n)
        .map(|i| {
            let px: Vec<f32> = samples[i * d..(i + 1) * d]
                .iter()
                .flat_map(|&v| {
                    let b = if v > 0.0 { 1.0 } else { -1.0 };
                    [b, b, b]
                })
                .collect();
            Image::from_tensor_pm1(&Tensor::new(&[side, side, 3], px)?)
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    if engine.manifest().model("maf_img").is_err() {
        println!("SKIP: maf_img not in manifest");
        return Ok(());
    }
    let batch = *engine.manifest().model("maf_img")?.batch_sizes.first().unwrap();
    let sampler = MafSampler::new(&engine, "maf_img", batch)?;
    let side = (sampler.meta.seq_len as f64).sqrt() as usize;
    let batches = if quick() { 1 } else { 2 };
    let cfg = sjd::coordinator::maf::maf_config(0.1);

    let mut report = Report::new("Fig A3 — MAF binary-image generation");
    let mut rows = Vec::new();
    let mut sheets = Vec::new();
    let mut seq_time = None;

    for (mode, label) in [(MafMode::Sequential, "Sequential"), (MafMode::Jacobi, "Ours")] {
        let mut rng = Pcg64::seed(1);
        let _ = sampler.sample(mode, &cfg, &mut rng)?; // warmup
        let mut rng = Pcg64::seed(9);
        let mut wall = 0.0;
        let mut evals = 0;
        let mut all: Vec<f32> = Vec::new();
        for _ in 0..batches {
            let out = sampler.sample(mode, &cfg, &mut rng)?;
            wall += out.total_wall.as_secs_f64();
            evals += out.made_evals();
            all.extend_from_slice(out.samples.as_f32()?);
        }
        let speed = match seq_time {
            None => {
                seq_time = Some(wall);
                "1.0x".to_string()
            }
            Some(s) => format!("{:.1}x", s / wall),
        };
        println!("{label}: {wall:.2}s, {evals} MADE evals ({speed})");
        rows.push(vec![label.into(), format!("{wall:.2}"), format!("{evals}"), speed]);
        sheets.extend(to_images(&all, 10.min(batch), side)?);
    }

    let grid = compose_grid(&sheets, 10, 2);
    let out = artifacts_dir().join("figA3_maf_digits.png");
    write_png(&grid, &out)?;
    report.table(&["Method", "Time (s)", "MADE evals", "Speedup"], &rows);
    report.note(format!("sample sheet: {} (row 1 sequential, row 2 ours)", out.display()));
    report.note("Paper shape: ~18x acceleration with visually identical digits (all-layer Jacobi — no KV cache for MLPs).");
    report.finish();
    Ok(())
}
