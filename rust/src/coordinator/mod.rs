//! L3 coordinator — the paper's system contribution wired as a serving stack.
//!
//! See `docs/ARCHITECTURE.md` at the repo root for the full layer map
//! (Pallas kernels → AOT manifest → runtime Value/Engine → this coordinator
//! → HTTP server) and the device-residency rules the hot paths rely on.
//!
//! * [`jacobi`] — the parallel Jacobi decoding drivers: full-sequence
//!   (paper Alg 1, iterate `z ← F(z)` until `‖z^t − z^{t−1}‖∞ < τ`),
//!   windowed GS-Jacobi with convergence-front tracking
//!   ([`jacobi::gs_jacobi_decode_block_v`]), and their fused **chunked**
//!   variants ([`jacobi::jacobi_decode_block_fused_v`],
//!   [`jacobi::gs_jacobi_decode_block_fused_v`]) that sync one residual
//!   history per chunk instead of one residual per iteration.
//! * [`policy`] — where/how to use Jacobi (paper §3.5): sequential for the
//!   dependency-heavy first block, Jacobi or windowed GS-Jacobi for the
//!   rest, plus uniform / sequential / fused-chunked (`fuse[:S]`) /
//!   calibrated per-block variants with JSON persistence.
//! * [`sampler`] — full noise→image pipeline over the AOT artifacts; a
//!   [`sampler::SamplerSet`] holds one sampler per lowered batch bucket.
//! * [`batcher`] — dynamic request batching up to the largest bucket.
//! * [`router`] — multi-worker dispatch (one engine per worker thread);
//!   each batch decodes via the smallest bucket covering it, padding only
//!   the gap to that bucket (`sjd_padded_slots`).
//! * [`server`] — HTTP/1.1 front end (`/generate`, `/metrics`, `/healthz`)
//!   on a connection thread pool with keep-alive; PNG encodes run as pool
//!   jobs that overlap decode.
//! * [`state`] — per-request decode state & KV-cache buffers.

pub mod batcher;
pub mod jacobi;
pub mod maf;
pub mod policy;
pub mod router;
pub mod sampler;
pub mod server;
pub mod state;

pub use jacobi::{
    ChunkScheduler, GsJacobiStats, InitStrategy, JacobiConfig, JacobiStats, WindowStats,
};
pub use policy::{BlockDecode, DecodePolicy};
pub use sampler::{SampleOptions, Sampler, SamplerSet};
