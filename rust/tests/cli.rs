//! CLI binary smoke tests (run the real `sjd` binary).

use std::process::Command;

fn artifacts() -> Option<String> {
    let dir = std::env::var("SJD_ARTIFACTS").unwrap_or_else(|_| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .display()
            .to_string()
    });
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built");
        None
    }
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_sjd"))
        .args(args)
        .output()
        .expect("spawn sjd");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["--help"]);
    assert!(!ok); // help goes through the error path with exit 2
    for cmd in ["serve", "sample", "recon", "calibrate", "info"] {
        assert!(text.contains(cmd), "missing '{cmd}' in help:\n{text}");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn info_lists_models() {
    let Some(dir) = artifacts() else { return };
    let (ok, text) = run(&["info", "--artifacts", &dir]);
    assert!(ok, "{text}");
    assert!(text.contains("tf10"), "{text}");
    assert!(text.contains("artifacts:"));
}

#[test]
fn sample_writes_png() {
    let Some(dir) = artifacts() else { return };
    let out = std::env::temp_dir().join("sjd_cli_sample.png");
    let _ = std::fs::remove_file(&out);
    let (ok, text) = run(&[
        "sample",
        "--artifacts",
        &dir,
        "--model",
        "tf10",
        "--batch",
        "1",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let bytes = std::fs::read(&out).expect("png written");
    assert_eq!(&bytes[1..4], b"PNG");
    assert!(text.contains("jacobi"));
}

#[test]
fn recon_reports_mse() {
    let Some(dir) = artifacts() else { return };
    let (ok, text) = run(&["recon", "--artifacts", &dir, "--model", "tf10", "--batch", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("reconstruction MSE"), "{text}");
}
