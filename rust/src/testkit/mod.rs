//! Mini property-based testing framework (proptest substitute — see
//! DESIGN.md §2: crates.io is unreachable in this environment).
//!
//! Provides seeded generators, a runner that reports the failing seed/case,
//! and greedy shrinking for the built-in generator types.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't get the crate's rpath flags in
//! // this offline environment; the same snippet runs in unit tests below.)
//! use sjd::testkit::*;
//! check(100, gen_vec(gen_f32(-10.0, 10.0), 1, 32), |v| {
//!     let s: f32 = v.iter().sum();
//!     s.is_finite()
//! });
//! ```

pub mod fault;
pub mod fuzz;
pub mod http;
pub mod mockflow;

use crate::tensor::Pcg64;
use std::fmt::Debug;

/// A generator of random values with an optional shrink strategy.
pub trait Gen {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate smaller values, largest-first. Default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `cases` random cases of `prop` over `gen`; panic with the minimized
/// counterexample on failure.
pub fn check<G: Gen>(cases: usize, gen: G, prop: impl Fn(&G::Value) -> bool) {
    check_seeded(0xC0FFEE, cases, gen, prop)
}

/// Like [`check`] but with an explicit base seed (printed on failure so runs
/// are reproducible).
pub fn check_seeded<G: Gen>(
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&G::Value) -> bool,
) {
    let mut rng = Pcg64::seed(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let minimized = minimize(&gen, v.clone(), &prop);
            panic!(
                "property failed (seed {seed:#x}, case {case}/{cases})\n  original: {v:?}\n  minimized: {minimized:?}"
            );
        }
    }
}

fn minimize<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // Greedy descent: repeatedly take the first shrink candidate that still
    // fails, up to a step budget.
    'outer: for _ in 0..200 {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

// ---------------------------------------------------------------------------
// Built-in generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi].
pub struct GenUsize {
    pub lo: usize,
    pub hi: usize,
}

pub fn gen_usize(lo: usize, hi: usize) -> GenUsize {
    assert!(lo <= hi);
    GenUsize { lo, hi }
}

impl Gen for GenUsize {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.lo + rng.next_below(self.hi - self.lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        // Geometric ladder towards `lo`: enables bisection-like minimization
        // under the greedy descent in `minimize`.
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            let mut delta = (*v - self.lo) / 2;
            while delta > 0 {
                out.push(*v - delta);
                delta /= 2;
            }
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f32 in [lo, hi).
pub struct GenF32 {
    pub lo: f32,
    pub hi: f32,
}

pub fn gen_f32(lo: f32, hi: f32) -> GenF32 {
    assert!(lo < hi);
    GenF32 { lo, hi }
}

impl Gen for GenF32 {
    type Value = f32;
    fn generate(&self, rng: &mut Pcg64) -> f32 {
        self.lo + rng.next_f32() * (self.hi - self.lo)
    }
    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mut out = Vec::new();
        if *v != 0.0 && (self.lo..=self.hi).contains(&0.0) {
            out.push(0.0);
        }
        if v.abs() > 1e-3 {
            out.push(v / 2.0);
        }
        out
    }
}

/// Vec of inner-generated values with length in [min_len, max_len].
pub struct GenVec<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn gen_vec<G: Gen>(inner: G, min_len: usize, max_len: usize) -> GenVec<G> {
    assert!(min_len <= max_len);
    GenVec { inner, min_len, max_len }
}

impl<G: Gen> Gen for GenVec<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        let len = self.min_len + rng.next_below(self.max_len - self.min_len + 1);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        // Shorter prefixes first.
        if v.len() > self.min_len {
            out.push(v[..self.min_len].to_vec());
            out.push(v[..v.len() - 1].to_vec());
            out.push(v[..(v.len() / 2).max(self.min_len)].to_vec());
        }
        // Then shrink one element.
        for (i, item) in v.iter().enumerate().take(8) {
            for cand in self.inner.shrink(item) {
                let mut copy = v.clone();
                copy[i] = cand;
                out.push(copy);
            }
        }
        out
    }
}

/// Pair generator.
pub struct GenPair<A, B>(pub A, pub B);

pub fn gen_pair<A: Gen, B: Gen>(a: A, b: B) -> GenPair<A, B> {
    GenPair(a, b)
}

impl<A: Gen, B: Gen> Gen for GenPair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Choice among a fixed set of values.
pub struct GenChoice<T: Clone + Debug>(pub Vec<T>);

pub fn gen_choice<T: Clone + Debug>(items: Vec<T>) -> GenChoice<T> {
    assert!(!items.is_empty());
    GenChoice(items)
}

impl<T: Clone + Debug> Gen for GenChoice<T> {
    type Value = T;
    fn generate(&self, rng: &mut Pcg64) -> T {
        self.0[rng.next_below(self.0.len())].clone()
    }
}

/// Map a generator through a function (no shrinking through the map).
pub struct GenMap<G, F> {
    pub inner: G,
    pub f: F,
}

pub fn gen_map<G: Gen, T: Clone + Debug, F: Fn(G::Value) -> T>(inner: G, f: F) -> GenMap<G, F> {
    GenMap { inner, f }
}

impl<G: Gen, T: Clone + Debug, F: Fn(G::Value) -> T> Gen for GenMap<G, F> {
    type Value = T;
    fn generate(&self, rng: &mut Pcg64) -> T {
        (self.f)(self.inner.generate(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(200, gen_usize(0, 100), |&n| n <= 100);
        check(200, gen_f32(-1.0, 1.0), |&x| (-1.0..1.0).contains(&x));
    }

    #[test]
    fn vec_lengths_respected() {
        check(200, gen_vec(gen_usize(0, 9), 2, 5), |v| (2..=5).contains(&v.len()));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(200, gen_usize(0, 100), |&n| n < 90);
    }

    #[test]
    fn shrinking_minimizes() {
        // Catch the panic and assert the minimized case is the boundary.
        let res = std::panic::catch_unwind(|| {
            check(500, gen_usize(0, 1000), |&n| n < 500);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land at or near the boundary 500.
        assert!(msg.contains("minimized: 500"), "got: {msg}");
    }

    #[test]
    fn pair_and_choice() {
        check(100, gen_pair(gen_usize(1, 4), gen_f32(0.0, 1.0)), |(n, x)| {
            *n >= 1 && *x < 1.0
        });
        check(100, gen_choice(vec!["a", "b"]), |s| *s == "a" || *s == "b");
    }

    #[test]
    fn map_generator() {
        check(100, gen_map(gen_usize(0, 10), |n| n * 2), |&n| n % 2 == 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Pcg64::seed(5);
        let mut r2 = Pcg64::seed(5);
        let g = gen_vec(gen_f32(0.0, 1.0), 3, 3);
        assert_eq!(g.generate(&mut r1), g.generate(&mut r2));
    }
}
