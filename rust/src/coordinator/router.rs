//! Multi-worker router: each worker is a dedicated OS thread owning its own
//! backend (PJRT engines are `Rc`-based and thread-pinned) plus a
//! [`SamplerSet`] — one sampler per lowered batch bucket — all pulling
//! batches from the shared [`Batcher`] queue. Work-stealing via a single
//! MPMC queue gives least-loaded dispatch for free.
//!
//! ## Bucket routing
//!
//! The batcher forms batches of 1..=max-bucket real slots; the worker picks
//! the **smallest bucket covering the batch** and pads only the gap to that
//! bucket. Padding is real decode work (a padded slot costs as much as a
//! real one), so it is tracked in the `sjd_padded_slots` counter and the
//! per-bucket `sjd_bucket_{B}_batches` counters — the load bench and the
//! serving tests assert on both.
//!
//! ## Metrics
//!
//! Per batch: `sjd_batch_fill` (real slots), `sjd_decode_time`,
//! `sjd_batches_processed`, `sjd_bucket_{B}_batches`, `sjd_padded_slots`.
//! Per slot: `sjd_queue_wait` (submit → decode start) and
//! `sjd_request_latency` (submit → image ready). `sjd_encode_time` is
//! recorded by the HTTP layer's encode jobs (see `coordinator::server`).
//! Per decoded block: `sjd_block_iters` (decode steps) and
//! `sjd_host_syncs` (blocking host syncs, see `BlockTrace::host_syncs`) —
//! together they expose per-request convergence behavior and how well the
//! fused chunked decode is amortizing its τ-test round-trips
//! (`⌈iters/S⌉` syncs when the fused artifacts are live, `iters` on the
//! per-iteration fallback).

use super::batcher::Batcher;
use super::sampler::{SampleOptions, SamplerSet};
use crate::metrics::Registry;
use crate::runtime::{Backend, Engine, Manifest};
use crate::tensor::Pcg64;
use anyhow::Result;
use std::path::PathBuf;
use std::thread::JoinHandle;
use std::time::Instant;

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    /// Decode buckets to serve, ascending. Empty = every *complete* lowered
    /// per-batch artifact family ([`Router::start`] resolves it via
    /// `Manifest::decode_buckets`; the backend-generic
    /// [`Router::start_with`] falls back to `ModelMeta::batch_sizes`).
    pub buckets: Vec<usize>,
    pub workers: usize,
    pub options: SampleOptions,
}

/// Running worker fleet.
pub struct Router {
    pub batcher: Batcher,
    pub registry: Registry,
    workers: Vec<JoinHandle<()>>,
}

impl Router {
    /// Spawn `cfg.workers` worker threads over real PJRT engines. Each
    /// validates its engine before the router returns (fail-fast on bad
    /// artifacts). Empty `cfg.buckets` resolves through
    /// [`Manifest::decode_buckets`], so an incomplete per-batch artifact
    /// family on disk is excluded instead of failing worker startup.
    pub fn start(mut cfg: RouterConfig, batcher: Batcher, registry: Registry) -> Result<Self> {
        if cfg.buckets.is_empty() {
            let manifest = Manifest::load(cfg.artifacts_dir.join("manifest.json"))?;
            cfg.buckets = manifest.decode_buckets(&cfg.model);
        }
        let dir = cfg.artifacts_dir.clone();
        Self::start_with(cfg, batcher, registry, move |_widx| Engine::new(&dir))
    }

    /// Spawn workers over any backend. The factory runs *inside* each worker
    /// thread (backends may be thread-pinned, like the PJRT engine), so it
    /// must be `Send + Clone` but the backend itself need not be `Send`.
    /// This is the seam the mock-backend serving tests and the load bench
    /// plug into.
    pub fn start_with<B, F>(
        cfg: RouterConfig,
        batcher: Batcher,
        registry: Registry,
        factory: F,
    ) -> Result<Self>
    where
        B: Backend,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();

        for widx in 0..cfg.workers.max(1) {
            let cfg = cfg.clone();
            let batcher = batcher.clone();
            let registry = registry.clone();
            let ready = ready_tx.clone();
            let factory = factory.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sjd-worker-{widx}"))
                    .spawn(move || worker_main(widx, cfg, batcher, registry, ready, factory))
                    .expect("spawn worker"),
            );
        }
        drop(ready_tx);
        for _ in 0..cfg.workers.max(1) {
            ready_rx.recv().expect("worker startup signal")?;
        }
        Ok(Router { batcher, registry, workers })
    }

    /// Stop workers: close the queue (new submissions fail fast, see
    /// [`Batcher::submit`]), let workers drain what is already queued, then
    /// join them.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_main<B, F>(
    widx: usize,
    cfg: RouterConfig,
    batcher: Batcher,
    registry: Registry,
    ready: std::sync::mpsc::Sender<Result<()>>,
    factory: F,
) where
    B: Backend,
    F: Fn(usize) -> Result<B>,
{
    // Build the thread-pinned backend + per-bucket samplers; report readiness.
    let engine = match factory(widx) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let set = match SamplerSet::new(&engine, &cfg.model, &cfg.buckets) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    let lat = registry.histogram("sjd_request_latency");
    let queue_wait = registry.histogram("sjd_queue_wait");
    let decode_time = registry.histogram("sjd_decode_time");
    let block_iters = registry.histogram("sjd_block_iters");
    let host_syncs = registry.histogram("sjd_host_syncs");
    let batch_fill = registry.histogram("sjd_batch_fill");
    let images = registry.counter("sjd_images_generated");
    let batches = registry.counter("sjd_batches_processed");
    let padded = registry.counter("sjd_padded_slots");
    let errors = registry.counter("sjd_worker_errors");
    let inflight = registry.gauge("sjd_batches_inflight");

    // Workers exit when the closed queue drains (`next_batch` → None), so a
    // shutdown never abandons an accepted slot.
    while let Some(batch) = batcher.next_batch() {
        inflight.add(1);
        batch_fill.record(batch.slots.len() as u64);
        // Every slot MUST complete: an oversized batch (a batcher formed
        // past the largest bucket — a misconfiguration, but a recoverable
        // one) is decoded in max-bucket chunks instead of silently dropping
        // the slots the zip below would not cover.
        let mut slots = batch.slots;
        while !slots.is_empty() {
            let take = slots.len().min(set.max_bucket());
            let chunk: Vec<_> = slots.drain(..take).collect();
            // Smallest lowered bucket covering the chunk; pad only up to it.
            let sampler = set.select(chunk.len());
            padded.add(sampler.batch.saturating_sub(chunk.len()) as u64);
            registry.counter(&format!("sjd_bucket_{}_batches", sampler.batch)).inc();
            for slot in &chunk {
                queue_wait.record_duration(slot.enqueued.elapsed());
            }
            // Derive the batch RNG from the first slot's seed alone (fixed
            // stream) so identical requests reproduce identical images
            // regardless of which worker picks up the batch.
            let seed = chunk.first().map(|s| s.seed).unwrap_or(0);
            let mut rng = Pcg64::seed_stream(seed, 1);
            let t_decode = Instant::now();
            match sampler.sample_images(&cfg.options, &mut rng) {
                Ok((imgs, trace)) => {
                    decode_time.record_duration(t_decode.elapsed());
                    // Per-block convergence + sync behavior of this decode.
                    for t in &trace.traces {
                        block_iters.record(t.steps as u64);
                        host_syncs.record(t.host_syncs as u64);
                    }
                    // Padded images (if any) fall off the end of the zip.
                    for (slot, img) in chunk.iter().zip(imgs.into_iter()) {
                        lat.record_duration(slot.enqueued.elapsed());
                        slot.done.put(Ok(img));
                        images.inc();
                    }
                    batches.inc();
                }
                Err(e) => {
                    errors.inc();
                    log::error!("worker {widx} sample failed: {e:#}");
                    // Complete slots with the error so clients get a 500
                    // instead of hanging (or a silently-black 200).
                    let msg = format!("decode failed: {e:#}");
                    for slot in &chunk {
                        slot.done.put(Err(msg.clone()));
                    }
                }
            }
        }
        inflight.add(-1);
    }
}
