//! Generation-quality metrics (FID / CLIP-IQA / BRISQUE substitutes — see
//! DESIGN.md §5 for the substitution rationale).
//!
//! * [`frechet_distance`] — Fréchet distance between two Gaussian fits of
//!   feature sets; fed with features from the fixed-seed `metricnet`
//!   artifact, this is the repo's "proxy-FID".
//! * [`brisque`] — BRISQUE natural-scene-statistics features (MSCN + AGGD
//!   fits) with a fixed linear readout.
//! * [`clip_iqa_proxy`] — feature-space contrast/sharpness score standing in
//!   for CLIP-IQA's no-reference quality role.

mod brisque;
mod eval;
mod frechet;

pub use brisque::{brisque, brisque_features};
pub use eval::{evaluate_quality, metric_features, QualityReport};
pub use frechet::{frechet_distance, FeatureStats};

use crate::imageio::Image;

/// No-reference quality proxy standing in for CLIP-IQA: combines local
/// contrast (Laplacian energy) and dynamic range, mapped to (0, 1).
///
/// Like CLIP-IQA it is *only* used to detect relative quality drift between
/// decoding strategies, never as an absolute score.
pub fn clip_iqa_proxy(img: &Image) -> f32 {
    let lum = img.luminance();
    let (w, h) = (img.width, img.height);
    if w < 3 || h < 3 {
        return 0.5;
    }
    // Laplacian response energy (sharpness).
    let mut lap_energy = 0.0f64;
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            let c = lum[y * w + x];
            let l = 4.0 * c - lum[y * w + x - 1] - lum[y * w + x + 1] - lum[(y - 1) * w + x]
                - lum[(y + 1) * w + x];
            lap_energy += (l as f64) * (l as f64);
        }
    }
    lap_energy /= ((w - 2) * (h - 2)) as f64;
    // Dynamic range utilization.
    let mn = lum.iter().copied().fold(f32::INFINITY, f32::min);
    let mx = lum.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let range = ((mx - mn) / 255.0).clamp(0.0, 1.0) as f64;
    // Squash sharpness to (0,1) and combine.
    let sharp = 1.0 - (-lap_energy / 500.0).exp();
    (0.5 * sharp + 0.5 * range) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn noise_image(w: usize, h: usize, seed: u64) -> Image {
        let mut rng = Pcg64::seed(seed);
        let mut img = Image::new(w, h);
        for p in img.pixels.iter_mut() {
            *p = (rng.next_f32() * 255.0) as u8;
        }
        img
    }

    #[test]
    fn clip_iqa_flat_vs_texture() {
        let flat = Image::new(16, 16); // all black
        let tex = noise_image(16, 16, 1);
        let s_flat = clip_iqa_proxy(&flat);
        let s_tex = clip_iqa_proxy(&tex);
        assert!(s_tex > s_flat, "texture {s_tex} should beat flat {s_flat}");
        assert!((0.0..=1.0).contains(&s_flat));
        assert!((0.0..=1.0).contains(&s_tex));
    }

    #[test]
    fn clip_iqa_tiny_image_safe() {
        let img = Image::new(2, 2);
        assert_eq!(clip_iqa_proxy(&img), 0.5);
    }
}
