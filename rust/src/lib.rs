//! # sjd — Selective Jacobi Decoding serving stack
//!
//! A three-layer reproduction of *“Accelerating Inference of Discrete
//! Autoregressive Normalizing Flows by Selective Jacobi Decoding”*:
//!
//! * **L1** — Pallas kernels (causal attention with dependency-offset masking,
//!   fused affine-inverse/Jacobi update), authored in `python/compile/kernels/`
//!   and lowered at build time.
//! * **L2** — JAX TarFlow / MAF models, trained on synthetic data and AOT-lowered
//!   to HLO text artifacts (`make artifacts`).
//! * **L3** — this crate: a rust coordinator that owns the request path —
//!   HTTP server, router, dynamic batcher, per-block decode policy
//!   (sequential + KV cache vs parallel Jacobi iteration), metrics — and runs
//!   the artifacts through the PJRT CPU client (`xla` crate).
//!
//! Python never runs on the request path; the binary is self-contained once
//! `artifacts/` is built.

pub mod benchkit;
pub mod cli;
pub mod configx;
pub mod coordinator;
pub mod exec;
pub mod imageio;
pub mod jsonx;
pub mod metrics;
pub mod physics;
pub mod quality;
pub mod runtime;
pub mod tensor;
pub mod testkit;

/// Crate-wide result type (anyhow-based; library APIs that need typed errors
/// define their own error enums).
pub type Result<T> = anyhow::Result<T>;
