"""L1 Pallas kernels + pure-jnp reference oracles."""

from . import affine_update, attention, ref

__all__ = ["affine_update", "attention", "ref"]
