//! The [`Engine`]: one PJRT client + a lazy compile cache over the artifacts
//! listed in the manifest.
//!
//! `PjRtClient` is `Rc`-based and therefore **thread-pinned**: an `Engine`
//! lives on one thread, and so does every device-resident [`Value`] it mints
//! (see the [module docs](super) for the full residency rules). Multi-worker
//! serving (see `coordinator::router`) gives each worker thread its own
//! `Engine`; requests/results cross threads as [`HostTensor`]s, which are
//! plain `Send` data.
//!
//! Each engine is additionally **device-pinned**: construction resolves one
//! of the client's addressable devices ([`Engine::new_on`]) and every minted
//! buffer is stamped with that ordinal, so a multi-device deployment (stage
//! sharding in `coordinator::pipeline`) can run one engine per ordinal with
//! hard aliasing guards between them.

use super::manifest::{ArtifactMeta, DType, Manifest};
use super::value::DeviceValue;
use super::{HostTensor, Value};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// Per-artifact call statistics (compile time, call count, execute time).
#[derive(Clone, Debug, Default)]
pub struct CallStats {
    pub compile_time: Duration,
    pub calls: u64,
    pub exec_time: Duration,
    /// Host→literal packing + literal→host unpacking time, including the
    /// host-arg promotion inside [`Engine::call_v`] and its tuple-output
    /// fallback — every byte that crosses the host boundary on behalf of this
    /// artifact is charged here.
    pub marshal_time: Duration,
    /// Inputs consumed directly as device-resident buffers (no host marshal).
    pub device_hits: u64,
    /// Host inputs promoted to device buffers on call entry.
    pub host_marshals: u64,
    /// Blocking output syncs charged to this artifact: the tuple-root
    /// fallback in [`Engine::call_v`] (destructuring the result literal
    /// host-side) and the always-synced legacy [`Engine::call`] path.
    /// Complements [`TransferStats::syncs`], which counts the *explicit*
    /// `to_host` sync points — together they are every blocking
    /// device→host crossing, the quantity the fused multi-step decode path
    /// exists to shrink.
    pub output_syncs: u64,
}

/// Engine-wide explicit transfer statistics ([`Engine::to_device`] /
/// [`Engine::to_host`] / [`Engine::to_ordinal`]), outside any one artifact's
/// ledger.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransferStats {
    pub uploads: u64,
    pub upload_time: Duration,
    pub syncs: u64,
    pub sync_time: Duration,
    /// Ordinal this engine is pinned to — every upload/sync above happened
    /// against this device, so stats from engines on different ordinals can
    /// be told apart after the fact.
    pub device_ordinal: usize,
    /// Cross-ordinal moves that stayed on the device fabric
    /// ([`Engine::to_ordinal`] via PJRT device→device copy — no host hop).
    pub device_copies: u64,
    pub device_copy_time: Duration,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

/// Decompose a synced output literal into host tensors, handling both
/// tuple-rooted artifacts (the `return_tuple=True` legacy lowering) and
/// untupled single-output roots — discriminated by probing the literal's
/// shape, never by assumption.
fn literal_to_host_outputs(
    name: &str,
    meta: &ArtifactMeta,
    lit: &xla::Literal,
) -> Result<Vec<HostTensor>> {
    if lit.array_shape().is_ok() {
        if meta.outputs.len() != 1 {
            bail!(
                "artifact '{}' returned a single array but declares {} outputs",
                name,
                meta.outputs.len()
            );
        }
        return Ok(vec![HostTensor::from_literal(lit)?]);
    }
    let parts = lit.to_tuple().context("decomposing output tuple")?;
    if parts.len() != meta.outputs.len() {
        bail!(
            "artifact '{}' declared {} outputs but returned {}",
            name,
            meta.outputs.len(),
            parts.len()
        );
    }
    parts.iter().map(HostTensor::from_literal).collect()
}

/// Device-side payload of a [`Value::Device`] minted by this engine. The
/// ordinal stamp is the aliasing guard: a buffer living on ordinal `a` can
/// never be executed or synced through an engine pinned to ordinal `b ≠ a`.
struct EngineBuffer {
    buf: xla::PjRtBuffer,
    ordinal: usize,
}

/// Loads HLO-text artifacts on demand, validates signatures, executes.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
    stats: RefCell<HashMap<String, CallStats>>,
    transfer: RefCell<TransferStats>,
    /// Ordinal into the client's addressable devices this engine is pinned
    /// to; every minted buffer carries it (see [`EngineBuffer`]).
    device_ordinal: usize,
    /// Addressable-device count, snapshotted at construction.
    device_count: usize,
    /// When true, input shapes/dtypes are checked against the manifest on
    /// every call (cheap; disabled only in the innermost perf benches).
    pub validate_calls: bool,
}

impl Engine {
    /// Create an engine over `artifacts/manifest.json` in `artifacts_dir`,
    /// pinned to device ordinal 0 (the runtime's default placement).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Self::new_on(artifacts_dir, 0)
    }

    /// Create an engine pinned to one of the client's addressable devices.
    /// Fails fast on an out-of-range ordinal rather than silently aliasing
    /// device 0.
    pub fn new_on(artifacts_dir: impl AsRef<Path>, device_ordinal: usize) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir.as_ref().join("manifest.json"))?;
        Self::with_manifest_on(manifest, device_ordinal)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        Self::with_manifest_on(manifest, 0)
    }

    pub fn with_manifest_on(manifest: Manifest, device_ordinal: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let devices = client.addressable_devices();
        let device_count = devices.len();
        if device_ordinal >= device_count {
            bail!(
                "device ordinal {device_ordinal} out of range: platform '{}' has \
                 {device_count} addressable device(s)",
                client.platform_name()
            );
        }
        log::info!(
            "engine: platform '{}', pinned to device ordinal {device_ordinal}/{device_count} \
             (device id {})",
            client.platform_name(),
            devices[device_ordinal].id()
        );
        drop(devices);
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            transfer: RefCell::new(TransferStats {
                device_ordinal,
                ..TransferStats::default()
            }),
            device_ordinal,
            device_count,
            validate_calls: true,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ordinal (into the client's addressable devices) this engine is pinned
    /// to.
    pub fn device_ordinal(&self) -> usize {
        self.device_ordinal
    }

    /// Number of addressable devices on this engine's client.
    pub fn device_count(&self) -> usize {
        self.device_count
    }

    /// Resolve an addressable device by ordinal. The `Vec` detour is the
    /// only enumeration xla-rs exposes; devices are cheap handles.
    fn resolve_device(&self, ordinal: usize) -> Result<xla::PjRtDevice<'_>> {
        let mut devices = self.client.addressable_devices();
        if ordinal >= devices.len() {
            bail!("device ordinal {ordinal} out of range ({} addressable)", devices.len());
        }
        Ok(devices.swap_remove(ordinal))
    }

    /// Upload one literal onto an ordinal's device. Ordinal 0 keeps the
    /// legacy `None` (runtime default placement) fast path byte-for-byte;
    /// any other ordinal passes the resolved device explicitly.
    fn upload_literal(&self, lit: &xla::Literal, ordinal: usize) -> Result<xla::PjRtBuffer> {
        if ordinal == 0 {
            Ok(self.client.buffer_from_host_literal(None, lit)?)
        } else {
            let dev = self.resolve_device(ordinal)?;
            Ok(self.client.buffer_from_host_literal(Some(&dev), lit)?)
        }
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn compiled(&self, name: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let meta = self.manifest.artifact(name)?.clone();
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let compile_time = t0.elapsed();
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_time = compile_time;
        log::info!("compiled artifact '{name}' in {compile_time:?}");
        let c = Rc::new(Compiled { exe, meta });
        self.cache.borrow_mut().insert(name.to_string(), c.clone());
        Ok(c)
    }

    /// Eagerly compile a set of artifacts (warmup before serving).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.compiled(n)?;
        }
        Ok(())
    }

    fn validate_inputs(&self, meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (spec, t) in meta.inputs.iter().zip(inputs) {
            let ok_dtype = matches!(
                (spec.dtype, t),
                (DType::F32, HostTensor::F32 { .. }) | (DType::I32, HostTensor::I32 { .. })
            );
            if !ok_dtype {
                bail!("artifact '{}' input '{}': dtype mismatch", meta.name, spec.name);
            }
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact '{}' input '{}': shape {:?} != expected {:?}",
                    meta.name,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Validate value inputs — both variants carry shape/dtype metadata, so
    /// device-resident inputs are checked without touching the device.
    fn validate_values(&self, meta: &ArtifactMeta, inputs: &[Value]) -> Result<()> {
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (spec, v) in meta.inputs.iter().zip(inputs) {
            if v.dtype() != spec.dtype {
                bail!("artifact '{}' input '{}': dtype mismatch", meta.name, spec.name);
            }
            if v.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact '{}' input '{}': shape {:?} != expected {:?}",
                    meta.name,
                    spec.name,
                    v.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact with host inputs; returns host outputs.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single result
    /// literal is a tuple which is decomposed into one `HostTensor` per
    /// declared output. This is the legacy convenience path; the serving hot
    /// loops use [`Engine::call_v`] to keep chained state device-resident.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let c = self.compiled(name)?;
        if self.validate_calls {
            self.validate_inputs(&c.meta, inputs)?;
        }

        let tm0 = Instant::now();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let marshal_in = tm0.elapsed();

        let t0 = Instant::now();
        let result = c
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of '{name}'"))?;
        let exec_time = t0.elapsed();

        let tm1 = Instant::now();
        let outs = literal_to_host_outputs(name, &c.meta, &out_lit)?;
        let marshal_out = tm1.elapsed();

        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.exec_time += exec_time;
        s.marshal_time += marshal_in + marshal_out;
        s.host_marshals += inputs.len() as u64;
        s.output_syncs += 1;
        Ok(outs)
    }

    /// Execute an artifact on a mix of host and device-resident [`Value`]s.
    ///
    /// Host inputs are promoted to device buffers on entry (counted in
    /// [`CallStats::host_marshals`] / `marshal_time`); device inputs are used
    /// in place (counted in [`CallStats::device_hits`], costing no marshal
    /// time) — the perf-pass fast path for chained state like Jacobi iterates
    /// and sequential-decode KV caches.
    ///
    /// Output residency is decided without guessing at tuple semantics:
    /// an artifact marked `untupled_outputs` in the manifest (single-output,
    /// `return_tuple=False` lowering — e.g. `{m}_reverse_b{B}`) has its one
    /// result buffer wrapped device-resident; a multi-output artifact whose
    /// buffers came back one-per-output (the runtime untupled the root) is
    /// wrapped device-resident likewise. Anything else — a tuple root the
    /// runtime did not untuple, including every legacy single-output
    /// artifact — takes a single forced sync that destructures the result
    /// literal (probing leaf vs tuple by shape) and returns host values,
    /// charged to `marshal_time`, so chaining degrades gracefully instead of
    /// breaking.
    pub fn call_v(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let c = self.compiled(name)?;
        if self.validate_calls {
            self.validate_values(&c.meta, inputs)?;
        }

        // Promote host args to device buffers (two passes so the borrows of
        // `owned` are taken only after it stops growing). Only actual
        // promotions are timed — an all-device call adds zero marshal time.
        let mut marshal_in = Duration::ZERO;
        let mut host_marshals = 0u64;
        let mut owned: Vec<Option<xla::PjRtBuffer>> = Vec::with_capacity(inputs.len());
        for v in inputs {
            owned.push(match v {
                Value::Host(t) => {
                    host_marshals += 1;
                    let tm0 = Instant::now();
                    let lit = t.to_literal()?;
                    let buf = self
                        .upload_literal(&lit, self.device_ordinal)
                        .with_context(|| format!("promoting host input for '{name}'"))?;
                    marshal_in += tm0.elapsed();
                    Some(buf)
                }
                Value::Device(_) => None,
            });
        }

        let mut borrowed: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (v, own) in inputs.iter().zip(&owned) {
            borrowed.push(match v {
                Value::Host(_) => own.as_ref().unwrap(),
                Value::Device(d) => {
                    let eb = d.downcast::<EngineBuffer>().ok_or_else(|| {
                        anyhow::anyhow!(
                            "artifact '{name}': device input was not minted by this engine"
                        )
                    })?;
                    if eb.ordinal != self.device_ordinal {
                        bail!(
                            "artifact '{name}': device input was not minted by this engine's \
                             device (buffer lives on ordinal {}, engine is pinned to ordinal {})",
                            eb.ordinal,
                            self.device_ordinal
                        );
                    }
                    &eb.buf
                }
            });
        }

        let t0 = Instant::now();
        let result = c
            .exe
            .execute_b::<&xla::PjRtBuffer>(&borrowed)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let exec_time = t0.elapsed();
        let bufs: Vec<xla::PjRtBuffer> = result.into_iter().next().unwrap_or_default();

        let mut marshal_out = Duration::ZERO;
        let mut output_syncs = 0u64;
        let wrap_device = (c.meta.untupled_outputs && c.meta.outputs.len() == 1)
            || c.meta.outputs.len() > 1;
        let outs: Vec<Value> = if bufs.len() == c.meta.outputs.len() && wrap_device {
            // Unambiguously one leaf buffer per declared output (untupled
            // root, or a runtime that untupled a multi-output root): wrap
            // device-resident.
            bufs.into_iter()
                .zip(&c.meta.outputs)
                .map(|(buf, spec)| {
                    Value::Device(DeviceValue::new(
                        spec.shape.clone(),
                        spec.dtype,
                        Rc::new(EngineBuffer { buf, ordinal: self.device_ordinal }),
                    ))
                })
                .collect()
        } else if bufs.len() == 1 {
            // Tuple root the runtime did not untuple, or a legacy
            // single-output artifact (leaf vs tuple-of-1 is undecidable
            // without inspection): forced sync point, probed by shape.
            let tm1 = Instant::now();
            let lit = bufs[0]
                .to_literal_sync()
                .with_context(|| format!("fetching output of '{name}'"))?;
            let host: Vec<Value> = literal_to_host_outputs(name, &c.meta, &lit)?
                .into_iter()
                .map(Value::Host)
                .collect();
            marshal_out = tm1.elapsed();
            output_syncs = 1;
            host
        } else {
            bail!(
                "artifact '{}' returned {} buffers, expected {}",
                name,
                bufs.len(),
                c.meta.outputs.len()
            );
        };

        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.exec_time += exec_time;
        s.marshal_time += marshal_in + marshal_out;
        s.host_marshals += host_marshals;
        s.device_hits += inputs.len() as u64 - host_marshals;
        s.output_syncs += output_syncs;
        Ok(outs)
    }

    /// Upload a host tensor to this engine's pinned device once, for reuse
    /// across calls.
    pub fn to_device(&self, t: &HostTensor) -> Result<Value> {
        self.upload_to_ordinal(t, self.device_ordinal)
    }

    fn upload_to_ordinal(&self, t: &HostTensor, ordinal: usize) -> Result<Value> {
        let tm0 = Instant::now();
        let lit = t.to_literal()?;
        let buf = self.upload_literal(&lit, ordinal).context("uploading host tensor")?;
        let dtype = match t {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        };
        let mut xfer = self.transfer.borrow_mut();
        xfer.uploads += 1;
        xfer.upload_time += tm0.elapsed();
        Ok(Value::Device(DeviceValue::new(
            t.shape().to_vec(),
            dtype,
            Rc::new(EngineBuffer { buf, ordinal }),
        )))
    }

    /// Sync a value to the host — a forced synchronization point.
    pub fn to_host(&self, v: Value) -> Result<HostTensor> {
        match v {
            Value::Host(t) => Ok(t),
            Value::Device(d) => {
                let eb = d
                    .downcast::<EngineBuffer>()
                    .context("device value was not minted by this engine")?;
                if eb.ordinal != self.device_ordinal {
                    bail!(
                        "device value was not minted by this engine's device (buffer lives \
                         on ordinal {}, engine is pinned to ordinal {})",
                        eb.ordinal,
                        self.device_ordinal
                    );
                }
                let tm0 = Instant::now();
                let lit = eb.buf.to_literal_sync().context("syncing device buffer")?;
                let t = HostTensor::from_literal(&lit)?;
                let mut xfer = self.transfer.borrow_mut();
                xfer.syncs += 1;
                xfer.sync_time += tm0.elapsed();
                Ok(t)
            }
        }
    }

    /// Move a value onto addressable-device `ordinal` of this engine's
    /// client.
    ///
    /// Same-ordinal device values come back as cheap handle clones — no
    /// transfer, nothing charged. A cross-ordinal move tries the PJRT
    /// device→device copy first (charged to [`TransferStats::device_copies`],
    /// no host round-trip); where the runtime rejects the copy it falls back
    /// to the documented host hop — one blocking sync plus one upload,
    /// truthfully charged to `syncs`/`uploads` like any other host crossing.
    /// Host values are plain uploads to the target ordinal.
    ///
    /// The result is stamped with `ordinal`, so only an engine pinned there
    /// may execute or sync it. This moves values across *devices*, never
    /// across engines or threads — the client stays thread-pinned, and
    /// cross-thread span handoff remains host-mediated (module docs).
    pub fn to_ordinal(&self, v: &Value, ordinal: usize) -> Result<Value> {
        if ordinal >= self.device_count {
            bail!("device ordinal {ordinal} out of range ({} addressable)", self.device_count);
        }
        let d = match v {
            Value::Host(t) => return self.upload_to_ordinal(t, ordinal),
            Value::Device(d) => d,
        };
        let eb = d
            .downcast::<EngineBuffer>()
            .context("device value was not minted by this engine")?;
        if eb.ordinal == ordinal {
            return Ok(v.clone());
        }
        let t0 = Instant::now();
        let target = self.resolve_device(ordinal)?;
        match eb.buf.copy_to_device(target) {
            Ok(buf) => {
                let mut xfer = self.transfer.borrow_mut();
                xfer.device_copies += 1;
                xfer.device_copy_time += t0.elapsed();
                Ok(Value::Device(DeviceValue::new(
                    v.shape().to_vec(),
                    v.dtype(),
                    Rc::new(EngineBuffer { buf, ordinal }),
                )))
            }
            Err(e) => {
                // Fallback: the documented host hop, charged where it really
                // happens (one sync, one upload) so TransferStats never
                // under-reports the cost of a runtime without fabric copies.
                log::debug!(
                    "device→device copy {}→{ordinal} unsupported ({e}); host fallback",
                    eb.ordinal
                );
                let tm0 = Instant::now();
                let lit = eb.buf.to_literal_sync().context("syncing device buffer")?;
                let t = HostTensor::from_literal(&lit)?;
                {
                    let mut xfer = self.transfer.borrow_mut();
                    xfer.syncs += 1;
                    xfer.sync_time += tm0.elapsed();
                }
                self.upload_to_ordinal(&t, ordinal)
            }
        }
    }

    /// Snapshot of per-artifact statistics.
    pub fn stats(&self) -> HashMap<String, CallStats> {
        self.stats.borrow().clone()
    }

    /// Snapshot of explicit upload/sync statistics.
    pub fn transfer_stats(&self) -> TransferStats {
        *self.transfer.borrow()
    }

    /// Reset call statistics (keeps compile times).
    pub fn reset_stats(&self) {
        for s in self.stats.borrow_mut().values_mut() {
            s.calls = 0;
            s.exec_time = Duration::ZERO;
            s.marshal_time = Duration::ZERO;
            s.device_hits = 0;
            s.host_marshals = 0;
            s.output_syncs = 0;
        }
        *self.transfer.borrow_mut() =
            TransferStats { device_ordinal: self.device_ordinal, ..TransferStats::default() };
    }
}
