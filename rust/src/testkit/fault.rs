//! Deterministic, seedable fault injection over the mock serving backend.
//!
//! A [`FaultPlan`] is an ordered list of [`FaultRule`]s — *(artifact
//! pattern × per-artifact call-index window) → action* — evaluated by a
//! [`FaultyBackend`] wrapping [`MockServeBackend`]. Call indices are
//! counted **per artifact name**, so "fail the 3rd dispatch of
//! `mock_block_jstep_b4`" is expressible and exactly reproducible; plans
//! built by [`FaultPlan::random`] derive from the repo's seeded `Pcg64`,
//! so every chaos soak replays from its seed. All injection happens at the
//! `Backend::call_v` boundary — exactly where the fault-tolerant layer
//! ([`coordinator::fault`](crate::coordinator::fault)) installs its
//! recovery — which makes every recovery path testable without artifacts
//! or devices.
//!
//! Actions mirror the taxonomy plus two things no error type reports:
//!
//! * [`Fail`](FaultAction::Fail) — typed [`Fault`] of any class
//!   (fail-once / fail-N via the rule's index window).
//! * [`Hang`](FaultAction::Hang) — sleep before delegating: a stalled
//!   dispatch, the watchdog's prey.
//! * [`CorruptOutput`](FaultAction::CorruptOutput) — delegate, then
//!   NaN-poison the first output. Deliberately *silent*: it pins the
//!   taxonomy boundary that fault tolerance recovers **reported** faults,
//!   while silent corruption is only caught by end-to-end bit-exactness
//!   checks (which is why the chaos gates compare against solo decodes).
//! * [`Panic`](FaultAction::Panic) — panic mid-dispatch: a worker kill,
//!   exercising the completion guard + supervised respawn path.

use super::mockflow::MockServeBackend;
use crate::runtime::{Backend, Fault, FaultClass, HostTensor, ModelMeta, Value};
use crate::tensor::Pcg64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What an armed [`FaultRule`] does to a matching call.
#[derive(Clone, Debug)]
pub enum FaultAction {
    /// Fail with a typed [`Fault`] of this class, *before* the inner
    /// backend runs (the call never happens — a retry can succeed).
    Fail(FaultClass),
    /// Sleep this long, then delegate — a hung-but-alive dispatch.
    Hang(Duration),
    /// Delegate, then overwrite the first output with NaNs (silent
    /// corruption; see module docs).
    CorruptOutput,
    /// Panic mid-dispatch (simulated worker kill).
    Panic,
}

/// One injection rule: fire `action` on calls whose artifact name contains
/// `artifact` and whose per-artifact call index falls in
/// `[from, from + count)`.
#[derive(Clone, Debug)]
pub struct FaultRule {
    /// Substring match on the artifact name (`""` matches every call).
    pub artifact: String,
    /// First per-artifact call index (0-based) the rule fires on.
    pub from: usize,
    /// How many consecutive indices it fires on (`usize::MAX` = forever).
    pub count: usize,
    pub action: FaultAction,
}

impl FaultRule {
    fn matches(&self, name: &str, index: usize) -> bool {
        name.contains(self.artifact.as_str())
            && index >= self.from
            && index - self.from < self.count
    }
}

/// A deterministic fault schedule. Cloning shares the injection counter
/// (but not call-index state, which lives in the [`FaultyBackend`]), so a
/// multi-worker test can hand each worker the same plan and still read one
/// fleet-wide injected-fault total.
#[derive(Clone, Default)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    injected: Arc<AtomicUsize>,
}

impl FaultPlan {
    /// The empty plan (injects nothing).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn rule(mut self, r: FaultRule) -> Self {
        self.rules.push(r);
        self
    }

    /// Fail the `index`-th call of artifacts matching `artifact`, once.
    pub fn fail_once(self, artifact: &str, index: usize, class: FaultClass) -> Self {
        self.fail_n(artifact, index, 1, class)
    }

    /// Fail `count` consecutive calls starting at per-artifact index
    /// `from`.
    pub fn fail_n(self, artifact: &str, from: usize, count: usize, class: FaultClass) -> Self {
        self.rule(FaultRule {
            artifact: artifact.into(),
            from,
            count,
            action: FaultAction::Fail(class),
        })
    }

    /// Stall the `index`-th matching call for `d` before it proceeds.
    pub fn hang_for(self, artifact: &str, index: usize, d: Duration) -> Self {
        self.rule(FaultRule { artifact: artifact.into(), from: index, count: 1, action: FaultAction::Hang(d) })
    }

    /// NaN-poison the output of the `index`-th matching call.
    pub fn corrupt_output(self, artifact: &str, index: usize) -> Self {
        self.rule(FaultRule {
            artifact: artifact.into(),
            from: index,
            count: 1,
            action: FaultAction::CorruptOutput,
        })
    }

    /// Panic inside the `index`-th matching call (worker kill).
    pub fn panic_at(self, artifact: &str, index: usize) -> Self {
        self.rule(FaultRule { artifact: artifact.into(), from: index, count: 1, action: FaultAction::Panic })
    }

    /// A seeded random plan for chaos soaks: ~`rate` of decode dispatches
    /// fail `Transient` (expressed as scattered fail-once rules over the
    /// first `horizon` per-artifact call indices of `jstep`/`seqstep`
    /// calls). Only *recoverable* faults are generated — the soak's
    /// bit-exactness gate is the proof that recovery, not luck, answered
    /// the requests.
    pub fn random(seed: u64, rate: f64, horizon: usize) -> Self {
        let mut rng = Pcg64::seed(seed);
        let mut plan = FaultPlan::none();
        for role in ["jstep", "seqstep"] {
            for idx in 0..horizon {
                if rng.next_f64() < rate {
                    plan = plan.fail_once(role, idx, FaultClass::Transient);
                }
            }
        }
        plan
    }

    /// Total faults this plan (all clones) has injected so far.
    pub fn injected(&self) -> usize {
        self.injected.load(Ordering::SeqCst)
    }
}

/// [`MockServeBackend`] plus a [`FaultPlan`]: the deterministic
/// fault-injection harness. Implements [`Backend`] by evaluating the plan
/// at every `call_v`, so it slots anywhere the mock does — under
/// [`FaultTolerantBackend`](crate::coordinator::fault::FaultTolerantBackend)
/// in recovery tests, or bare to pin unrecovered behavior.
pub struct FaultyBackend {
    inner: MockServeBackend,
    plan: FaultPlan,
    /// Per-artifact dispatch counts (the rule index space).
    calls: Mutex<HashMap<String, usize>>,
}

impl FaultyBackend {
    pub fn new(inner: MockServeBackend, plan: FaultPlan) -> Self {
        FaultyBackend { inner, plan, calls: Mutex::new(HashMap::new()) }
    }

    pub fn inner(&self) -> &MockServeBackend {
        &self.inner
    }

    /// Total faults injected through this backend's plan (shared across
    /// plan clones).
    pub fn injected(&self) -> usize {
        self.plan.injected()
    }

    /// The action armed for this call, if any. Counts the call index.
    fn armed(&self, name: &str) -> Option<FaultAction> {
        let mut calls = self.calls.lock().unwrap();
        let idx = calls.entry(name.to_string()).or_insert(0);
        let index = *idx;
        *idx += 1;
        drop(calls);
        self.plan
            .rules
            .iter()
            .find(|r| r.matches(name, index))
            .map(|r| r.action.clone())
    }
}

impl Backend for FaultyBackend {
    fn call_v(&self, name: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        match self.armed(name) {
            None => self.inner.call_v(name, inputs),
            Some(FaultAction::Fail(class)) => {
                self.plan.injected.fetch_add(1, Ordering::SeqCst);
                Err(Fault::new(class, name).context("injected fault"))
            }
            Some(FaultAction::Hang(d)) => {
                self.plan.injected.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(d);
                self.inner.call_v(name, inputs)
            }
            Some(FaultAction::CorruptOutput) => {
                self.plan.injected.fetch_add(1, Ordering::SeqCst);
                let mut out = self.inner.call_v(name, inputs)?;
                if let Some(Value::Host(t)) = out.first() {
                    let shape = t.shape().to_vec();
                    let n = t.len();
                    out[0] = Value::Host(HostTensor::f32(&shape, vec![f32::NAN; n]));
                }
                Ok(out)
            }
            Some(FaultAction::Panic) => {
                self.plan.injected.fetch_add(1, Ordering::SeqCst);
                panic!("injected fault: worker kill during '{name}'");
            }
        }
    }

    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta> {
        self.inner.model_meta(model)
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.inner.has_artifact(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::mockflow::MockLedger;

    fn backend(plan: FaultPlan) -> FaultyBackend {
        let ledger = MockLedger::new();
        FaultyBackend::new(MockServeBackend::new(&[1, 4], Duration::ZERO, ledger), plan)
    }

    /// A real jstep call at bucket 1: (k, z, y, mask) with [1, L, D] data.
    fn jstep_inputs() -> Vec<Value> {
        let n = 8 * 3; // L × D of MockFlow::standard at batch 1
        vec![
            Value::Host(HostTensor::scalar_i32(0)),
            Value::Host(HostTensor::f32(&[1, 8, 3], vec![0.0; n])),
            Value::Host(HostTensor::f32(&[1, 8, 3], vec![0.1; n])),
            Value::Host(HostTensor::scalar_i32(0)),
        ]
    }

    #[test]
    fn fail_once_hits_exactly_its_call_index() {
        let be = backend(FaultPlan::none().fail_once("jstep", 1, FaultClass::Transient));
        assert!(be.call_v("mock_block_jstep_b1", &jstep_inputs()).is_ok(), "index 0 clean");
        let err = be.call_v("mock_block_jstep_b1", &jstep_inputs()).unwrap_err();
        assert_eq!(crate::runtime::classify(&err), FaultClass::Transient);
        assert!(be.call_v("mock_block_jstep_b1", &jstep_inputs()).is_ok(), "index 2 clean");
        assert_eq!(be.injected(), 1);
    }

    #[test]
    fn call_indices_are_counted_per_artifact() {
        let be = backend(FaultPlan::none().fail_once("_b1", 0, FaultClass::Poison));
        // The reverse artifact's index 0 fires independently of jstep's.
        let n = 8 * 3;
        let rev = vec![Value::Host(HostTensor::f32(&[1, 8, 3], vec![0.0; n]))];
        assert!(be.call_v("mock_reverse_b1", &rev).is_err());
        assert!(be.call_v("mock_block_jstep_b1", &jstep_inputs()).is_err(), "own index 0");
        assert!(be.call_v("mock_block_jstep_b1", &jstep_inputs()).is_ok());
        assert!(be.call_v("mock_reverse_b1", &rev).is_ok());
    }

    #[test]
    fn random_plans_are_reproducible_per_seed() {
        let a = FaultPlan::random(7, 0.3, 16);
        let b = FaultPlan::random(7, 0.3, 16);
        let sig = |p: &FaultPlan| {
            p.rules.iter().map(|r| (r.artifact.clone(), r.from)).collect::<Vec<_>>()
        };
        assert_eq!(sig(&a), sig(&b));
        assert!(!a.rules.is_empty(), "rate 0.3 over 32 slots must arm something");
        let c = FaultPlan::random(8, 0.3, 16);
        assert_ne!(sig(&a), sig(&c), "different seed, different plan");
    }

    #[test]
    fn corrupt_output_is_silent_but_not_bit_exact() {
        // The taxonomy boundary: corruption doesn't error — only an
        // end-to-end reference comparison can catch it.
        let be = backend(FaultPlan::none().corrupt_output("jstep", 0));
        let out = be.call_v("mock_block_jstep_b1", &jstep_inputs()).unwrap();
        let Value::Host(t) = &out[0] else { panic!("host output") };
        assert!(t.as_f32().unwrap().iter().all(|v| v.is_nan()));
        assert_eq!(be.injected(), 1);
    }

    #[test]
    fn panic_action_panics_with_artifact_name() {
        let be = backend(FaultPlan::none().panic_at("jstep", 0));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = be.call_v("mock_block_jstep_b1", &jstep_inputs());
        }));
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("worker kill"), "{msg}");
        assert!(msg.contains("mock_block_jstep_b1"), "{msg}");
    }
}
