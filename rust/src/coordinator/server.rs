//! Minimal HTTP/1.1 server front end.
//!
//! Routes:
//! * `POST /generate` — body `{"n": 4, "seed": 7}` → JSON with base64 PNGs.
//! * `GET /metrics`   — text exposition of the metrics registry.
//! * `GET /healthz`   — liveness; 503 once the worker fleet is degraded
//!   (a worker retired after exhausting its restart budget).
//! * `GET /policy`    — the effective decode policy as JSON: the live
//!   [`PolicyTuner`] state under `serve --tune`, else the static configured
//!   policy (404 when no [`PolicySource`] was wired in). `sjd policy show
//!   --addr` pretty-prints it.
//!
//! ## Threading model
//!
//! Connections are handled on a fixed [`ThreadPool`]
//! (`ServerConfig::conn_threads`): the accept loop only hands sockets off,
//! so `/healthz` and `/metrics` answer while `/generate` decodes are in
//! flight, and N clients make progress concurrently. A second, independent
//! pool (`ServerConfig::encode_threads`) runs PNG encode + base64 as one
//! pure-CPU job per image, dispatched as each image's decode completes —
//! so encoding image `i` overlaps decoding image `i+1` instead of
//! serializing after the whole batch. The pools are separate on purpose:
//! connection handlers block (on decode completions and slow clients), and
//! a shared pool would let waiting handlers starve the encodes queued
//! behind them.
//!
//! Connections are keep-alive by HTTP/1.1 default (`Connection: close`
//! honored). The model is thread-per-connection: an **open** connection
//! holds one conn-pool thread for its lifetime, so size
//! `ServerConfig::conn_threads` (`--http-threads`) to the expected number
//! of concurrent clients — beyond it, new connections queue. The
//! `keepalive_timeout` bounds how long an *idle* connection may hold its
//! thread; in-request reads get the larger `REQUEST_READ_TIMEOUT` so a
//! slow-but-alive client is served rather than dropped.
//!
//! The HTTP layer is deliberately small (request line + headers +
//! content-length bodies) — it exists so the serving loop is exercised
//! end-to-end, not to be a general web server. It is still defensive where
//! it must be: header size/count are capped so a client streaming headers
//! can't grow memory unboundedly, error bodies go through the `jsonx`
//! emitter so they stay valid JSON whatever the message contains, and
//! malformed requests (400) are distinguished from internal failures (500).
//!
//! ## Failure classes (overload honesty)
//!
//! `/generate` failures map to distinct statuses so a load balancer (or a
//! client backoff loop) can react correctly: **429** Too Many Requests with
//! a `Retry-After` hint when admission control sheds the request
//! ([`super::batcher::QueueFull`]), **503** Service Unavailable when the
//! batcher is closed (shutdown — not an internal fault), **504** Gateway
//! Timeout when the request's deadline (`X-SJD-Deadline-Ms` header, or
//! `ServerConfig::default_deadline`) expires before its images complete,
//! and **500** only for genuine internal failures. Sheds are counted in
//! `sjd_shed_total{reason="queue_full"}` / `sjd_shed_total{reason="shutdown"}`.
//! `X-SJD-Priority: high` routes a request into the batcher's high-priority
//! class (see `Batcher` weighted drain).
//!
//! With `serve --client-rate R` each client — identified by its
//! `X-SJD-Client` header, headerless requests pooled under `"-"` — gets a
//! token bucket refilling at R requests/second (burst of one second's
//! worth, floor 1). An over-quota `/generate` is shed **before** it touches
//! the batcher: 429 with a `Retry-After` hint sized to the bucket's actual
//! refill, counted in `sjd_shed_total{reason="quota"}` — so one greedy
//! client exhausts its own budget, not the shared admission queue.

use super::batcher::{
    Batcher, BatcherClosed, Priority, QueueFull, SlotHandle, SubmitOpts, DEADLINE_EXPIRED_MSG,
};
use super::policy::PolicyTuner;
use super::router::FleetStatus;
use crate::exec::ThreadPool;
use crate::imageio::{self, Image};
use crate::jsonx::{self, Value};
use crate::metrics::Registry;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Total bytes allowed for the request line + all headers.
const MAX_HEADER_BYTES: usize = 64 << 10;
/// Maximum number of header lines.
const MAX_HEADERS: usize = 128;
/// Maximum request body size.
const MAX_BODY_BYTES: usize = 64 << 20;
/// Maximum `X-SJD-Client` identity length (identities key a shared map).
const MAX_CLIENT_ID_BYTES: usize = 128;
/// Distinct client identities tracked before idle buckets are evicted — a
/// bound on quota-map memory against identity-spraying clients.
const MAX_QUOTA_CLIENTS: usize = 4096;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the connection may serve another request after this one
    /// (HTTP/1.1 default unless `Connection: close`; HTTP/1.0 opt-in via
    /// `Connection: keep-alive`).
    pub keep_alive: bool,
    /// `X-SJD-Deadline-Ms` header: the client's completion budget in
    /// milliseconds, counted from request parse. `None` falls back to
    /// `ServerConfig::default_deadline`.
    pub deadline_ms: Option<u64>,
    /// `X-SJD-Priority` header (`high` | `normal`, default normal).
    pub priority: Priority,
    /// `X-SJD-Client` header: the caller's identity for per-client quota
    /// accounting (`serve --client-rate`). `None` (no header) pools the
    /// request under the shared anonymous identity.
    pub client: Option<String>,
}

/// Marker error for a connection that closed cleanly before sending a
/// request — the normal end of a keep-alive session, not a protocol error.
/// Callers distinguish it via `Error::is::<ConnectionClosed>()`.
#[derive(Debug)]
pub struct ConnectionClosed;

impl std::fmt::Display for ConnectionClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "connection closed")
    }
}

impl std::error::Error for ConnectionClosed {}

/// Marker error for a per-client quota shed (`serve --client-rate`):
/// `/generate` answers 429 with a `Retry-After` sized to the bucket's
/// actual refill and counts the shed in `sjd_shed_total{reason="quota"}`.
#[derive(Debug)]
pub struct QuotaExceeded {
    /// Whole seconds until the client's bucket holds a token again (≥ 1).
    pub retry_after: u64,
}

impl std::fmt::Display for QuotaExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "client over quota (retry after {}s)", self.retry_after)
    }
}

impl std::error::Error for QuotaExceeded {}

/// Read one `\n`-terminated line without buffering more than `max` bytes.
///
/// Returns an empty string at a clean EOF (no bytes read), mirroring
/// `read_line`'s 0-return so callers can treat it as end-of-headers.
fn read_line_capped(reader: &mut impl BufRead, max: usize) -> Result<String> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                (true, 0)
            } else {
                match available.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        buf.extend_from_slice(&available[..=i]);
                        (true, i + 1)
                    }
                    None => {
                        buf.extend_from_slice(available);
                        (false, available.len())
                    }
                }
            }
        };
        reader.consume(used);
        if buf.len() > max {
            bail!("header line exceeds {max} bytes");
        }
        if done {
            break;
        }
    }
    String::from_utf8(buf).context("header not utf-8")
}

/// Parse one HTTP/1.1 request from a buffered stream.
///
/// Header bytes (request line included) are capped at [`MAX_HEADER_BYTES`]
/// and header count at [`MAX_HEADERS`] — a client streaming an endless
/// header section gets an error instead of unbounded buffering. A clean EOF
/// before any byte of a request yields a [`ConnectionClosed`] error.
pub fn parse_request(reader: &mut impl BufRead) -> Result<HttpRequest> {
    let mut budget = MAX_HEADER_BYTES;
    let line = read_line_capped(reader, budget)?;
    if line.is_empty() {
        return Err(ConnectionClosed.into());
    }
    budget = budget.saturating_sub(line.len());
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }
    // HTTP/1.1 defaults to keep-alive; HTTP/1.0 to close.
    let mut keep_alive = version != "HTTP/1.0";

    let mut content_length = 0usize;
    let mut deadline_ms: Option<u64> = None;
    let mut priority = Priority::Normal;
    let mut client: Option<String> = None;
    let mut n_headers = 0usize;
    loop {
        if budget == 0 {
            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
        }
        let h = read_line_capped(reader, budget)?;
        budget = budget.saturating_sub(h.len());
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            bail!("too many headers (> {MAX_HEADERS})");
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            } else if k.eq_ignore_ascii_case("connection") {
                let v = v.trim();
                if v.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if v.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            } else if k.eq_ignore_ascii_case("x-sjd-deadline-ms") {
                deadline_ms = Some(v.trim().parse().context("bad x-sjd-deadline-ms")?);
            } else if k.eq_ignore_ascii_case("x-sjd-priority") {
                let v = v.trim();
                if v.eq_ignore_ascii_case("high") {
                    priority = Priority::High;
                } else if v.eq_ignore_ascii_case("normal") {
                    priority = Priority::Normal;
                } else {
                    bail!("bad x-sjd-priority {v:?} (expected high|normal)");
                }
            } else if k.eq_ignore_ascii_case("x-sjd-client") {
                let v = v.trim();
                // Identities key a shared map, so cap their size; an empty
                // value is the same as no header (anonymous pool).
                if v.len() > MAX_CLIENT_ID_BYTES {
                    bail!("x-sjd-client exceeds {MAX_CLIENT_ID_BYTES} bytes");
                }
                if !v.is_empty() {
                    client = Some(v.to_string());
                }
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(HttpRequest { method, path, body, keep_alive, deadline_ms, priority, client })
}

/// Serialize an HTTP response; `keep_alive` picks the `Connection` header.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Result<()> {
    write_response_extra(stream, status, content_type, &[], body, keep_alive)
}

/// [`write_response`] with extra response headers (e.g. `Retry-After` on a
/// 429 shed, so well-behaved clients back off instead of hammering).
pub fn write_response_extra(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    };
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {conn}\r\n",
        body.len()
    )?;
    for (k, v) in extra_headers {
        write!(stream, "{k}: {v}\r\n")?;
    }
    stream.write_all(b"\r\n")?;
    stream.write_all(body)?;
    Ok(())
}

/// JSON error body built through the `jsonx` emitter, so messages containing
/// quotes/backslashes stay valid JSON (a `format!` template would not).
pub fn error_json(err: &anyhow::Error) -> String {
    jsonx::to_string_pretty(&Value::obj(vec![("error", Value::str(format!("{err:#}")))]))
}

/// Standard base64 (RFC 4648) encoding for PNG payloads in JSON responses.
///
/// Emits each 3-byte chunk as a 4-byte group straight into a pre-sized byte
/// buffer (base64 output is pure ASCII) — no per-char `String::push` UTF-8
/// bookkeeping on what is a multi-megabyte hot path per generated image.
pub fn base64_encode(data: &[u8]) -> String {
    const TABLE: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";
    let mut out = vec![0u8; data.len().div_ceil(3) * 4];
    for (chunk, group) in data.chunks(3).zip(out.chunks_exact_mut(4)) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        group[0] = TABLE[(n >> 18) as usize & 63];
        group[1] = TABLE[(n >> 12) as usize & 63];
        group[2] = if chunk.len() > 1 { TABLE[(n >> 6) as usize & 63] } else { b'=' };
        group[3] = if chunk.len() > 2 { TABLE[n as usize & 63] } else { b'=' };
    }
    // SAFETY-free: every byte written above is ASCII from TABLE or '='.
    String::from_utf8(out).expect("base64 output is ASCII")
}

/// Parse and validate a `/generate` body → `(n, seed)`. Failures here are
/// the client's fault (HTTP 400); failures past this point are ours (500).
fn parse_generate_body(body: &[u8]) -> Result<(usize, u64)> {
    let text = std::str::from_utf8(body).context("body not utf-8")?;
    let v = if text.trim().is_empty() {
        Value::obj(vec![])
    } else {
        jsonx::parse(text).context("bad json")?
    };
    let n = v.get("n").and_then(Value::as_usize).unwrap_or(1).clamp(1, 64);
    let seed = v.get("seed").and_then(Value::as_usize).unwrap_or(0) as u64;
    Ok((n, seed))
}

/// What `GET /policy` serves: the statically configured policy, overridden
/// by the live tuner state whenever one is attached (`serve --tune`).
#[derive(Clone, Debug)]
pub struct PolicySource {
    /// JSON of the configured policy (`DecodePolicy::to_json`).
    pub configured: jsonx::Value,
    /// Live tuner; its `to_json` state wins over `configured` when present.
    pub tuner: Option<Arc<PolicyTuner>>,
}

impl PolicySource {
    /// The `/policy` response body.
    fn body(&self) -> String {
        let v = match &self.tuner {
            Some(t) => t.to_json(),
            None => Value::obj(vec![
                ("source", Value::str("static")),
                ("policy", self.configured.clone()),
            ]),
        };
        jsonx::to_string_pretty(&v)
    }
}

/// One client's token bucket: continuous refill, burst capacity of one
/// second's worth of rate (floor 1 so a rate < 1 req/s still ever admits).
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

/// Per-client admission quotas (`serve --client-rate`), keyed by the
/// `X-SJD-Client` identity. One lock around a small map: the charge is a
/// handful of float ops on the request path, orders of magnitude under the
/// decode it gates.
pub struct ClientQuotas {
    rate: f64,
    burst: f64,
    buckets: Mutex<HashMap<String, TokenBucket>>,
}

impl ClientQuotas {
    pub fn new(rate: f64) -> Self {
        ClientQuotas { rate, burst: rate.max(1.0), buckets: Mutex::new(HashMap::new()) }
    }

    /// Charge one request to `client`'s bucket. `Err` carries the whole
    /// seconds until the bucket holds a token again (the `Retry-After`
    /// hint).
    pub fn admit(&self, client: &str) -> std::result::Result<(), u64> {
        let mut buckets = self.buckets.lock().unwrap();
        let now = Instant::now();
        if !buckets.contains_key(client) && buckets.len() >= MAX_QUOTA_CLIENTS {
            // Cap reached by identity spraying: evict buckets that have
            // idled back to full — they hold no throttling state. If every
            // bucket is mid-charge (a genuine 4096-client storm), the new
            // identity is shed rather than growing the map.
            let rate = self.rate;
            let burst = self.burst;
            buckets.retain(|_, b| {
                (b.tokens + now.duration_since(b.last).as_secs_f64() * rate) < burst
            });
            if buckets.len() >= MAX_QUOTA_CLIENTS {
                return Err(1);
            }
        }
        let b = buckets
            .entry(client.to_string())
            .or_insert(TokenBucket { tokens: self.burst, last: now });
        b.tokens = (b.tokens + now.duration_since(b.last).as_secs_f64() * self.rate).min(self.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err((((1.0 - b.tokens) / self.rate).ceil() as u64).max(1))
        }
    }
}

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Connection-handling pool size. Thread-per-connection: this caps
    /// concurrently **open** connections, not just in-flight requests —
    /// size it to the expected number of concurrent clients.
    pub conn_threads: usize,
    /// PNG-encode pool size (separate from `conn_threads`, see module docs).
    pub encode_threads: usize,
    /// Idle keep-alive connections (no request bytes pending) are dropped
    /// after this long so they free their connection-pool thread.
    pub keepalive_timeout: Duration,
    /// Backing data of the `/policy` endpoint; `None` answers it 404.
    pub policy: Option<PolicySource>,
    /// Completion budget applied to requests that carry no
    /// `X-SJD-Deadline-Ms` header (`serve --default-deadline`); `None`
    /// leaves headerless requests deadline-free.
    pub default_deadline: Option<Duration>,
    /// Live/configured worker counts from `Router::fleet`. When set and the
    /// fleet is degraded (a worker retired after exhausting its restart
    /// budget), `/healthz` answers 503 so load balancers rotate the replica
    /// out. `None` keeps `/healthz` unconditionally 200.
    pub fleet: Option<FleetStatus>,
    /// Per-client admission quota in requests/second (`serve
    /// --client-rate`), keyed by the `X-SJD-Client` header (headerless
    /// requests pool under `"-"`). `0.0` disables quota enforcement.
    pub client_rate: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            conn_threads: 8,
            encode_threads: 4,
            keepalive_timeout: Duration::from_secs(5),
            policy: None,
            default_deadline: None,
            fleet: None,
            client_rate: 0.0,
        }
    }
}

/// Handler-side server state. Deliberately does NOT own the connection
/// pool: handler jobs clone this `Arc`, and if the pool lived inside it the
/// last clone could drop — and therefore join — the pool from one of its
/// own worker threads. The encode pool is safe here because encode jobs
/// never capture the state.
struct ServerState {
    addr: String,
    batcher: Batcher,
    registry: Registry,
    next_request_id: AtomicU64,
    stop: Arc<AtomicBool>,
    encode_pool: ThreadPool,
    keepalive_timeout: Duration,
    policy: Option<PolicySource>,
    default_deadline: Option<Duration>,
    fleet: Option<FleetStatus>,
    quotas: Option<ClientQuotas>,
}

/// Serving front end bound to a batcher + metrics registry.
pub struct Server {
    state: Arc<ServerState>,
    conn_pool: ThreadPool,
}

impl Server {
    pub fn new(addr: impl Into<String>, batcher: Batcher, registry: Registry) -> Self {
        Self::with_config(addr, batcher, registry, ServerConfig::default())
    }

    pub fn with_config(
        addr: impl Into<String>,
        batcher: Batcher,
        registry: Registry,
        cfg: ServerConfig,
    ) -> Self {
        Server {
            state: Arc::new(ServerState {
                addr: addr.into(),
                batcher,
                registry,
                next_request_id: AtomicU64::new(1),
                stop: Arc::new(AtomicBool::new(false)),
                encode_pool: ThreadPool::new(cfg.encode_threads),
                keepalive_timeout: cfg.keepalive_timeout,
                policy: cfg.policy,
                default_deadline: cfg.default_deadline,
                fleet: cfg.fleet,
                quotas: (cfg.client_rate > 0.0).then(|| ClientQuotas::new(cfg.client_rate)),
            }),
            conn_pool: ThreadPool::new(cfg.conn_threads),
        }
    }

    pub fn addr(&self) -> &str {
        &self.state.addr
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.state.stop.clone()
    }

    /// Blocking accept loop; returns when the stop flag is set (checked
    /// between accepts — pair with a dummy connection to unblock). Each
    /// accepted connection is handed to the connection pool, so the loop
    /// itself never blocks on request handling.
    pub fn run(&self) -> Result<()> {
        let listener = TcpListener::bind(&self.state.addr)
            .with_context(|| format!("binding {}", self.state.addr))?;
        log::info!("listening on {}", self.state.addr);
        for conn in listener.incoming() {
            if self.state.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = self.state.clone();
                    self.conn_pool.spawn(move || {
                        if let Err(e) = handle_conn(&state, stream) {
                            log::warn!("connection error: {e:#}");
                        }
                    });
                }
                Err(e) => log::warn!("accept error: {e}"),
            }
        }
        Ok(())
    }
}

/// Whether a parse failure is a dead/idle transport (EOF mid-request, idle
/// keep-alive timeout, reset) rather than a protocol violation — nothing to
/// answer, the peer is gone.
fn is_benign_disconnect(e: &anyhow::Error) -> bool {
    e.downcast_ref::<std::io::Error>().is_some_and(|io| {
        matches!(
            io.kind(),
            std::io::ErrorKind::WouldBlock
                | std::io::ErrorKind::TimedOut
                | std::io::ErrorKind::UnexpectedEof
                | std::io::ErrorKind::ConnectionReset
                | std::io::ErrorKind::BrokenPipe
        )
    })
}

/// Ceiling on how long reading one request may stall once its first byte
/// has arrived — generous (slow networks finish), but bounded so a dead
/// mid-request peer cannot pin a connection-pool thread forever.
const REQUEST_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Handle one connection: serve requests until the client closes, asks for
/// `Connection: close`, errors, or goes idle past the keep-alive timeout.
///
/// The keep-alive timeout only covers the *idle* wait for a request's first
/// byte (probed via `peek`, so nothing is consumed); once a request has
/// started, reads run under the much larger [`REQUEST_READ_TIMEOUT`] — a
/// slow-but-alive client is served, not silently dropped.
fn handle_conn(inner: &Arc<ServerState>, stream: TcpStream) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut served = 0usize;
    loop {
        // A stopping server closes keep-alive connections between requests;
        // otherwise a client re-requesting within the idle window would pin
        // its handler thread — and the pool's drop/join — forever.
        if inner.stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        // Idle wait — skipped when a pipelined request already sits in the
        // read buffer (peeking the socket would wrongly block past it).
        if reader.buffer().is_empty() {
            stream
                .set_read_timeout(Some(inner.keepalive_timeout))
                .context("set idle timeout")?;
            let mut first = [0u8; 1];
            match stream.peek(&mut first) {
                Ok(0) => return Ok(()), // clean close between requests
                Ok(_) => {}
                // Idle past the keep-alive window, or a dead transport:
                // nothing to answer.
                Err(_) => return Ok(()),
            }
        }
        stream
            .set_read_timeout(Some(REQUEST_READ_TIMEOUT))
            .context("set request timeout")?;
        let req = match parse_request(&mut reader) {
            Ok(r) => r,
            Err(e) => {
                // Clean close, or a transport death mid-request (reset, EOF,
                // a stall past REQUEST_READ_TIMEOUT): not a protocol error,
                // nothing to answer.
                if e.is::<ConnectionClosed>() || is_benign_disconnect(&e) {
                    return Ok(());
                }
                // Malformed or oversized request framing is the client's
                // fault: answer 400 (best effort — the peer may already be
                // gone) instead of silently resetting the connection, on
                // first and reused keep-alive requests alike.
                inner.registry.counter("sjd_http_errors").inc();
                let _ = write_response(
                    &mut stream,
                    400,
                    "application/json",
                    error_json(&e).as_bytes(),
                    false,
                );
                return Err(e);
            }
        };
        if served > 0 {
            inner.registry.counter("sjd_http_keepalive_reuses").inc();
        }
        served += 1;
        let keep = req.keep_alive;
        handle_request(inner, &req, &mut stream, keep)?;
        if !keep {
            return Ok(());
        }
    }
}

/// Route one parsed request and write its response.
fn handle_request(
    inner: &Arc<ServerState>,
    req: &HttpRequest,
    stream: &mut TcpStream,
    keep: bool,
) -> Result<()> {
    inner.registry.counter("sjd_http_requests").inc();
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => match &inner.fleet {
            // Degraded fleet (a worker retired after exhausting its restart
            // budget): non-200 so load balancers rotate this replica out.
            // Mid-respawn workers still count as live — only permanent loss
            // degrades health.
            Some(fleet) if fleet.degraded() => {
                let body = format!("degraded: {}/{} workers live", fleet.live(), fleet.configured());
                write_response(stream, 503, "text/plain", body.as_bytes(), keep)
            }
            _ => write_response(stream, 200, "text/plain", b"ok", keep),
        },
        ("GET", "/metrics") => {
            let text = inner.registry.render_text();
            write_response(stream, 200, "text/plain", text.as_bytes(), keep)
        }
        ("GET", "/policy") => match &inner.policy {
            Some(src) => {
                write_response(stream, 200, "application/json", src.body().as_bytes(), keep)
            }
            None => {
                let e = anyhow::anyhow!("no policy endpoint configured");
                write_response(stream, 404, "application/json", error_json(&e).as_bytes(), keep)
            }
        },
        ("POST", "/generate") => match parse_generate_body(&req.body) {
            // Malformed request: the client's fault.
            Err(e) => {
                inner.registry.counter("sjd_http_errors").inc();
                write_response(stream, 400, "application/json", error_json(&e).as_bytes(), keep)
            }
            Ok((n, seed)) => {
                // Per-client quota, charged before the request touches the
                // batcher: an over-quota client is shed out of its own
                // budget, not out of the shared admission queue.
                if let Some(quotas) = &inner.quotas {
                    if let Err(retry_after) = quotas.admit(req.client.as_deref().unwrap_or("-")) {
                        let e = anyhow::Error::new(QuotaExceeded { retry_after });
                        return write_generate_error(inner, &e, stream, keep);
                    }
                }
                // Per-request QoS: header deadline wins over the configured
                // default; both are absolute from this point.
                let deadline = req
                    .deadline_ms
                    .map(Duration::from_millis)
                    .or(inner.default_deadline)
                    .map(|d| Instant::now() + d);
                let opts = SubmitOpts { deadline, priority: req.priority };
                match generate(inner, n, seed, opts, stream) {
                    Ok(json) => {
                        write_response(stream, 200, "application/json", json.as_bytes(), keep)
                    }
                    Err(e) => write_generate_error(inner, &e, stream, keep),
                }
            }
        },
        _ => write_response(stream, 404, "text/plain", b"not found", keep),
    }
}

/// Classify a `/generate` failure into its honest status class and write
/// the response: 429 (admission shed, with `Retry-After`), 503 (shutdown),
/// 504 (deadline expired) or 500 (genuine internal failure). Every
/// non-500 class keeps its own counter so overload behavior is observable;
/// 500 stays reserved for faults that need a human.
fn write_generate_error(
    inner: &Arc<ServerState>,
    e: &anyhow::Error,
    stream: &mut TcpStream,
    keep: bool,
) -> Result<()> {
    let body = error_json(e);
    if let Some(q) = e.downcast_ref::<QuotaExceeded>() {
        inner.registry.counter("sjd_shed_total{reason=\"quota\"}").inc();
        let retry = q.retry_after.to_string();
        return write_response_extra(
            stream,
            429,
            "application/json",
            &[("Retry-After", &retry)],
            body.as_bytes(),
            keep,
        );
    }
    if e.is::<QueueFull>() {
        inner.registry.counter("sjd_shed_total{reason=\"queue_full\"}").inc();
        // Retry-After: one batch window is the natural backoff quantum.
        return write_response_extra(
            stream,
            429,
            "application/json",
            &[("Retry-After", "1")],
            body.as_bytes(),
            keep,
        );
    }
    if e.is::<BatcherClosed>() {
        inner.registry.counter("sjd_shed_total{reason=\"shutdown\"}").inc();
        return write_response(stream, 503, "application/json", body.as_bytes(), keep);
    }
    if format!("{e:#}").contains(DEADLINE_EXPIRED_MSG) {
        // The expiry itself is counted where it is enforced (batcher purge /
        // block-boundary sweep / handler wait) — not double-counted here.
        return write_response(stream, 504, "application/json", body.as_bytes(), keep);
    }
    inner.registry.counter("sjd_http_errors").inc();
    write_response(stream, 500, "application/json", body.as_bytes(), keep)
}

/// How often a `/generate` handler waiting on a decode re-checks its
/// transport for a client disconnect (see [`client_gone`]).
const DISCONNECT_POLL: Duration = Duration::from_millis(50);

/// Whether the peer has closed the connection, probed without consuming
/// bytes: a non-blocking `peek` returning `Ok(0)` is EOF; pending bytes
/// (e.g. a pipelined next request) or `WouldBlock` mean the peer is alive.
fn client_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut first = [0u8; 1];
    let gone = match stream.peek(&mut first) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    // Restore blocking mode; handle_conn re-arms read timeouts per request.
    let _ = stream.set_nonblocking(false);
    gone
}

/// Submit all `n` slots up front (so the batcher can group them), then wait
/// for each image **on this request's thread** and hand it to the encode
/// pool as a pure-CPU PNG+base64 job. Encoding image `i` overlaps decoding
/// image `i+1`, and encode-pool threads never block on decode — so one
/// still-queued request cannot head-of-line-block another request's
/// already-decoded images out of the encoder.
///
/// While waiting on a decode the handler polls the transport every
/// [`DISCONNECT_POLL`]: if the client is gone it cancels the request's
/// remaining slots — the continuous decode path (`serve --refill`) sweeps
/// them out at the next block boundary instead of decoding work nobody will
/// read — and errors out (the 500 write is best-effort, the peer is gone).
/// The same poll enforces the request deadline end-to-end: once it passes,
/// remaining slots are cancelled and the request resolves 504 even if a
/// non-sweeping (monolithic) worker would have decoded them to the end.
fn generate(
    inner: &Arc<ServerState>,
    n: usize,
    seed: u64,
    opts: SubmitOpts,
    stream: &TcpStream,
) -> Result<String> {
    let rid = inner.next_request_id.fetch_add(1, Ordering::SeqCst);
    let encode_time = inner.registry.histogram("sjd_encode_time");

    let handles: Vec<SlotHandle> = (0..n)
        .map(|i| inner.batcher.submit_slot_opts(rid, seed.wrapping_add(i as u64), opts))
        .collect::<Result<_>>()?;
    let mut jobs = Vec::with_capacity(n);
    for (i, handle) in handles.iter().enumerate() {
        // A decode failure completes the slot with its error → 500.
        let result = loop {
            if let Some(r) = handle.done.wait_timeout(DISCONNECT_POLL) {
                break r;
            }
            if opts.deadline.is_some_and(|d| Instant::now() >= d) {
                for h in &handles[i..] {
                    h.cancel();
                }
                inner.registry.counter("sjd_deadline_expired").inc();
                bail!("{DEADLINE_EXPIRED_MSG} (waiting on decode; cancelled {} slot(s))", n - i);
            }
            if client_gone(stream) {
                for h in &handles[i..] {
                    h.cancel();
                }
                bail!("client disconnected mid-request; cancelled {} slot(s)", n - i);
            }
        };
        let img_t = result.map_err(|msg| anyhow::anyhow!(msg))?;
        let encode_time = encode_time.clone();
        jobs.push(inner.encode_pool.spawn_result(move || -> Result<String> {
            let t0 = Instant::now();
            let img = Image::from_tensor_pm1(&img_t)?;
            let png = imageio::encode_png(&img)?;
            let b64 = base64_encode(&png);
            encode_time.record_duration(t0.elapsed());
            Ok(b64)
        }));
    }
    let mut pngs = Vec::with_capacity(n);
    for job in jobs {
        pngs.push(Value::Str(job.wait()?));
    }
    let resp = Value::obj(vec![
        ("request_id", Value::num(rid as f64)),
        ("n", Value::num(n as f64)),
        ("images_png_b64", Value::Arr(pngs)),
    ]);
    Ok(jsonx::to_string_pretty(&resp))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base64_vectors() {
        // RFC 4648 test vectors.
        assert_eq!(base64_encode(b""), "");
        assert_eq!(base64_encode(b"f"), "Zg==");
        assert_eq!(base64_encode(b"fo"), "Zm8=");
        assert_eq!(base64_encode(b"foo"), "Zm9v");
        assert_eq!(base64_encode(b"foobar"), "Zm9vYmFy");
    }

    /// Test-only RFC 4648 decoder for the round-trip check.
    fn base64_decode(s: &str) -> Vec<u8> {
        const TABLE: &[u8; 64] =
            b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";
        let val = |c: u8| TABLE.iter().position(|&t| t == c).unwrap() as u32;
        let mut out = Vec::new();
        for group in s.as_bytes().chunks(4) {
            let pad = group.iter().filter(|&&c| c == b'=').count();
            let n = group
                .iter()
                .take(4 - pad)
                .fold(0u32, |acc, &c| (acc << 6) | val(c))
                << (6 * pad);
            out.push((n >> 16) as u8);
            if pad < 2 {
                out.push((n >> 8) as u8);
            }
            if pad < 1 {
                out.push(n as u8);
            }
        }
        out
    }

    #[test]
    fn base64_long_input_roundtrip() {
        // A few-hundred-KB pseudo-random payload (PNG-sized) survives
        // encode → decode byte-exactly, across all three length residues.
        for extra in 0..3usize {
            let data: Vec<u8> = (0..300_000 + extra)
                .map(|i| {
                    ((i as u64).wrapping_mul(6364136223846793005).wrapping_add(13) >> 32) as u8
                })
                .collect();
            let enc = base64_encode(&data);
            assert_eq!(enc.len(), data.len().div_ceil(3) * 4);
            assert!(enc.is_ascii());
            assert_eq!(base64_decode(&enc), data, "residue {extra}");
        }
    }

    #[test]
    fn parse_simple_request() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"n\":2}";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, b"{\"n\":2}");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn parse_request_without_body() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(!parse_request(&mut r).unwrap().keep_alive);
        // HTTP/1.0 defaults to close, opts back in via keep-alive.
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(!parse_request(&mut r).unwrap().keep_alive);
        let raw = b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(parse_request(&mut r).unwrap().keep_alive);
    }

    #[test]
    fn rejects_bad_version_and_eof() {
        let raw = b"GET / SPDY/3\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(parse_request(&mut r).is_err());
        let mut empty = std::io::BufReader::new(&b""[..]);
        let err = parse_request(&mut empty).unwrap_err();
        // Clean EOF is flagged with the marker type keep-alive loops check.
        assert!(err.is::<ConnectionClosed>());
    }

    #[test]
    fn rejects_header_flood() {
        // More headers than MAX_HEADERS, each small: must error, not loop
        // buffering forever.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 10) {
            raw.push_str(&format!("X-H{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        let mut r = std::io::BufReader::new(raw.as_bytes());
        let err = parse_request(&mut r).unwrap_err().to_string();
        assert!(err.contains("too many headers"), "{err}");
    }

    #[test]
    fn rejects_oversized_header_section() {
        // One giant header line past the byte budget.
        let mut raw = String::from("GET / HTTP/1.1\r\nX-Big: ");
        raw.push_str(&"a".repeat(MAX_HEADER_BYTES + 1024));
        raw.push_str("\r\n\r\n");
        let mut r = std::io::BufReader::new(raw.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn rejects_unterminated_header_line() {
        // A header that never ends (no newline at all): the cap must fire
        // even though read_line would otherwise buffer indefinitely.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        raw.push_str(&"b".repeat(MAX_HEADER_BYTES + 4096));
        let mut r = std::io::BufReader::new(raw.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn header_budget_counts_request_line() {
        // Exhaust the budget with the request line itself (long path).
        let mut raw = String::from("GET /");
        raw.push_str(&"p".repeat(MAX_HEADER_BYTES + 16));
        raw.push_str(" HTTP/1.1\r\n\r\n");
        let mut r = std::io::BufReader::new(raw.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn error_json_stays_valid_with_quotes_and_backslashes() {
        let err = anyhow::anyhow!("bad \"json\" in C:\\path\nline2");
        let body = error_json(&err);
        let parsed = jsonx::parse(&body).expect("error body must be valid JSON");
        assert_eq!(
            parsed.get("error").and_then(Value::as_str),
            Some("bad \"json\" in C:\\path\nline2")
        );
    }

    #[test]
    fn parse_generate_body_defaults_and_errors() {
        assert_eq!(parse_generate_body(b"").unwrap(), (1, 0));
        assert_eq!(parse_generate_body(br#"{"n": 3, "seed": 9}"#).unwrap(), (3, 9));
        // Clamped to [1, 64].
        assert_eq!(parse_generate_body(br#"{"n": 1000}"#).unwrap().0, 64);
        assert!(parse_generate_body(b"{invalid").is_err());
        assert!(parse_generate_body(&[0xff, 0xfe]).is_err());
    }

    #[test]
    fn response_format() {
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "text/plain", b"hi", false).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));

        let mut buf = Vec::new();
        write_response(&mut buf, 200, "text/plain", b"hi", true).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("Connection: keep-alive\r\n"));
    }

    #[test]
    fn overload_status_reasons_and_extra_headers() {
        let mut buf = Vec::new();
        write_response_extra(&mut buf, 429, "application/json", &[("Retry-After", "1")], b"{}", true)
            .unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{s}");
        assert!(s.contains("Retry-After: 1\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));

        for (status, reason) in [(503, "Service Unavailable"), (504, "Gateway Timeout")] {
            let mut buf = Vec::new();
            write_response(&mut buf, status, "application/json", b"{}", false).unwrap();
            let s = String::from_utf8(buf).unwrap();
            assert!(s.starts_with(&format!("HTTP/1.1 {status} {reason}\r\n")), "{s}");
        }
    }

    #[test]
    fn parse_qos_headers() {
        let raw = b"POST /generate HTTP/1.1\r\nX-SJD-Deadline-Ms: 250\r\nX-SJD-Priority: high\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.deadline_ms, Some(250));
        assert_eq!(req.priority, Priority::High);

        // Absent headers: no deadline, normal class.
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.deadline_ms, None);
        assert_eq!(req.priority, Priority::Normal);

        // Case-insensitive names/values; garbage values are the client's
        // fault (400), not a silent default.
        let raw = b"GET / HTTP/1.1\r\nx-sjd-priority: NORMAL\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert_eq!(parse_request(&mut r).unwrap().priority, Priority::Normal);
        let raw = b"GET / HTTP/1.1\r\nX-SJD-Priority: urgent\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(parse_request(&mut r).is_err());
        let raw = b"GET / HTTP/1.1\r\nX-SJD-Deadline-Ms: soon\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn parse_client_header() {
        let raw = b"POST /generate HTTP/1.1\r\nX-SJD-Client: tenant-a\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert_eq!(parse_request(&mut r).unwrap().client.as_deref(), Some("tenant-a"));

        // No header, and an empty value, both pool as anonymous.
        let raw = b"POST /generate HTTP/1.1\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert_eq!(parse_request(&mut r).unwrap().client, None);
        let raw = b"POST /generate HTTP/1.1\r\nx-sjd-client:   \r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert_eq!(parse_request(&mut r).unwrap().client, None);

        // Oversized identities are the client's fault (400), not a
        // silently-truncated map key.
        let raw = format!(
            "POST /generate HTTP/1.1\r\nX-SJD-Client: {}\r\n\r\n",
            "c".repeat(MAX_CLIENT_ID_BYTES + 1)
        );
        let mut r = std::io::BufReader::new(raw.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn quota_bucket_burst_and_isolation() {
        // rate 2 req/s → burst 2: two immediate admits, the third sheds
        // with a refill-sized Retry-After.
        let q = ClientQuotas::new(2.0);
        assert!(q.admit("a").is_ok());
        assert!(q.admit("a").is_ok());
        let wait = q.admit("a").unwrap_err();
        assert!(wait >= 1, "Retry-After must be at least a second, got {wait}");
        // Another client's bucket is untouched by a's exhaustion.
        assert!(q.admit("b").is_ok());
        // Sub-1 rates still get a one-token burst (floor), so a polite
        // low-rate client is admitted at all.
        let slow = ClientQuotas::new(0.25);
        assert!(slow.admit("c").is_ok());
        let wait = slow.admit("c").unwrap_err();
        assert!(wait >= 4, "0.25 req/s refills a token in 4s, got {wait}");
    }

    #[test]
    fn quota_map_bounded_under_identity_spray() {
        // Spraying distinct identities cannot grow the map past the cap:
        // idle-full buckets are evicted to make room, so fresh identities
        // keep being admitted while the map stays bounded.
        let q = ClientQuotas::new(1000.0);
        for i in 0..(MAX_QUOTA_CLIENTS + 500) {
            let _ = q.admit(&format!("spray-{i}"));
        }
        assert!(q.buckets.lock().unwrap().len() <= MAX_QUOTA_CLIENTS);
    }

    #[test]
    fn fuzz_http_parser_never_panics() {
        // Structure-aware fuzz sweep over the request parser: mutated/spliced
        // byte soups must parse-or-reject, never panic or loop. A parsed
        // request additionally upholds basic invariants.
        let corpus: &[&[u8]] = &[
            b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"n\":2}",
            b"GET /healthz HTTP/1.1\r\n\r\n",
            b"GET /metrics HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n",
            b"POST /generate HTTP/1.1\r\nX-SJD-Deadline-Ms: 250\r\nX-SJD-Priority: high\r\n\r\n",
            b"POST /generate HTTP/1.1\r\nX-SJD-Client: tenant-a\r\n\r\n",
            b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n",
        ];
        let dict: &[&[u8]] = &[
            b"Content-Length:",
            b"Connection:",
            b"X-SJD-Deadline-Ms:",
            b"X-SJD-Priority:",
            b"X-SJD-Client:",
            b"HTTP/1.1",
            b"HTTP/1.0",
            b"\r\n",
            b"\r\n\r\n",
            b"18446744073709551615",
            b"-1",
            b"high",
            b"close",
        ];
        crate::testkit::fuzz::fuzz_cases(corpus, dict, 12_000, 0xC0FFEE, |case| {
            let mut r = std::io::BufReader::new(case);
            if let Ok(req) = parse_request(&mut r) {
                // Parsed requests obey the documented caps.
                assert!(req.body.len() <= MAX_BODY_BYTES);
                assert!(!req.method.is_empty() && !req.path.is_empty());
            }
        });
    }
}
