//! §Perf micro-benches: per-call runtime overhead (marshal vs execute),
//! jstep/seqstep unit costs host-marshalled vs device-resident, batcher
//! formation latency, buffer pool, and RNG throughput. These feed the
//! EXPERIMENTS.md §Perf iteration log.

mod common;

use common::*;
use sjd::benchkit::{time_fn, Report};
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::sampler::Sampler;
use sjd::coordinator::state::BufferPool;
use sjd::runtime::{HostTensor, Value};
use sjd::tensor::{Pcg64, Tensor};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let mut report = Report::new("§Perf — microbenchmarks");
    let mut rows = Vec::new();
    let iters = if quick() { 5 } else { 30 };

    // --- artifact call costs ---
    let model = "tf10";
    if engine.manifest().model(model).is_ok() {
        let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
        let sampler = Sampler::new(&engine, model, batch)?;
        let meta = &sampler.meta;
        let (l, d) = (meta.seq_len, meta.token_dim);
        let mut rng = Pcg64::seed(1);
        let z = HostTensor::f32(&[batch, l, d], Tensor::randn(&[batch, l, d], &mut rng).into_data());
        let y = z.clone();
        let jstep = format!("{model}_block_jstep_b{batch}");
        engine.warmup(&[&jstep])?;
        let t = time_fn(3, iters, || {
            let _ = engine
                .call(&jstep, &[HostTensor::scalar_i32(0), z.clone(), y.clone(), HostTensor::scalar_i32(0)])
                .unwrap();
        });
        rows.push(vec![
            format!("jstep call ({model} b{batch})"),
            format!("{:.2} ms", t.mean.as_secs_f64() * 1e3),
        ]);

        // Marshal vs execute split from engine stats — the host-marshalled
        // baseline the Value API is measured against.
        engine.reset_stats();
        for _ in 0..iters {
            let _ = engine.call(
                &jstep,
                &[HostTensor::scalar_i32(0), z.clone(), y.clone(), HostTensor::scalar_i32(0)],
            )?;
        }
        let stats = engine.stats();
        let s = &stats[&jstep];
        let base_marshal_ms = s.marshal_time.as_secs_f64() * 1e3 / s.calls as f64;
        rows.push(vec![
            "jstep exec / marshal split (host path)".into(),
            format!(
                "{:.2} ms exec, {:.3} ms marshal",
                s.exec_time.as_secs_f64() * 1e3 / s.calls as f64,
                base_marshal_ms
            ),
        ]);

        // Device-resident jstep chain — the jacobi_decode_block_v hot-loop
        // shape: upload y/z⁰/scalars once, chain z device→device, sync only
        // the [B] residual per iteration.
        engine.reset_stats();
        let t0 = std::time::Instant::now();
        let k0 = engine.to_device(&HostTensor::scalar_i32(0))?;
        let o0 = engine.to_device(&HostTensor::scalar_i32(0))?;
        let y_dev = engine.to_device(&y)?;
        let mut zv: Value = engine.to_device(&z)?;
        for _ in 0..iters {
            let outs = engine.call_v(&jstep, &[k0.clone(), zv, y_dev.clone(), o0.clone()])?;
            let mut it = outs.into_iter();
            zv = it.next().expect("z'");
            let resid = it.next().expect("resid");
            let _ = engine.to_host(resid)?;
        }
        let _ = engine.to_host(zv)?;
        let chain_wall = t0.elapsed();
        let stats = engine.stats();
        let s = &stats[&jstep];
        let chain_marshal_ms = s.marshal_time.as_secs_f64() * 1e3 / s.calls.max(1) as f64;
        rows.push(vec![
            "jstep device-chain (value path)".into(),
            format!(
                "{:.2} ms/iter wall, {:.3} ms marshal ({} device hits, {} host marshals)",
                chain_wall.as_secs_f64() * 1e3 / iters as f64,
                chain_marshal_ms,
                s.device_hits,
                s.host_marshals
            ),
        ]);
        rows.push(vec![
            "jstep marshal Δ (host − device)".into(),
            format!("{:.3} ms/iter", base_marshal_ms - chain_marshal_ms),
        ]);

        let seqstep = format!("{model}_block_seqstep_b{batch}");
        engine.warmup(&[&seqstep])?;
        let (nl, dm) = (meta.layers_per_block, meta.model_dim);
        let kv = HostTensor::f32(&[nl, batch, l, dm], vec![0.0; nl * batch * l * dm]);
        let tok = HostTensor::f32(&[batch, d], vec![0.0; batch * d]);
        engine.reset_stats();
        let t = time_fn(3, iters, || {
            let _ = engine
                .call(
                    &seqstep,
                    &[
                        HostTensor::scalar_i32(0),
                        tok.clone(),
                        tok.clone(),
                        HostTensor::scalar_i32(5),
                        kv.clone(),
                        kv.clone(),
                    ],
                )
                .unwrap();
        });
        rows.push(vec![
            format!("seqstep call ({model} b{batch})"),
            format!("{:.2} ms", t.mean.as_secs_f64() * 1e3),
        ]);
        let stats = engine.stats();
        let seq_base_marshal_ms = {
            let s = &stats[&seqstep];
            s.marshal_time.as_secs_f64() * 1e3 / s.calls.max(1) as f64
        };

        // Device-resident seqstep chain — KV caches and u_prev never leave
        // the device; only the [B, D] token slice crosses per step.
        engine.reset_stats();
        let t0 = std::time::Instant::now();
        let k0 = engine.to_device(&HostTensor::scalar_i32(0))?;
        let mut u_prev = engine.to_device(&tok)?;
        let mut kv_k = engine.to_device(&kv)?;
        let mut kv_v = engine.to_device(&kv)?;
        let steps = iters.min(l);
        for pos in 0..steps {
            let outs = engine.call_v(
                &seqstep,
                &[
                    k0.clone(),
                    u_prev,
                    Value::Host(tok.clone()),
                    Value::Host(HostTensor::scalar_i32(pos as i32)),
                    kv_k,
                    kv_v,
                ],
            )?;
            let mut it = outs.into_iter();
            u_prev = it.next().expect("u_tok");
            kv_k = it.next().expect("kv_k");
            kv_v = it.next().expect("kv_v");
        }
        let _ = engine.to_host(u_prev)?;
        let seq_chain_wall = t0.elapsed();
        let stats = engine.stats();
        let s = &stats[&seqstep];
        let seq_chain_marshal_ms = s.marshal_time.as_secs_f64() * 1e3 / s.calls.max(1) as f64;
        rows.push(vec![
            "seqstep device-chain (value path)".into(),
            format!(
                "{:.2} ms/step wall, {:.3} ms marshal ({} device hits, {} host marshals)",
                seq_chain_wall.as_secs_f64() * 1e3 / steps.max(1) as f64,
                seq_chain_marshal_ms,
                s.device_hits,
                s.host_marshals
            ),
        ]);
        rows.push(vec![
            "seqstep marshal Δ (host − device)".into(),
            format!("{:.3} ms/step", seq_base_marshal_ms - seq_chain_marshal_ms),
        ]);
    }

    // --- host-side substrates ---
    let mut rng = Pcg64::seed(2);
    let t = time_fn(2, 50, || {
        let _ = std::hint::black_box(Tensor::randn(&[8, 256, 12], &mut rng));
    });
    rows.push(vec!["prior randn (8×256×12)".into(), format!("{:.0} µs", t.mean.as_secs_f64() * 1e6)]);

    let pool = BufferPool::new();
    let t = time_fn(2, 200, || {
        let b = pool.take_zeroed(&[2, 8, 256, 96]);
        pool.give_back(std::hint::black_box(b));
    });
    rows.push(vec!["buffer pool take+return (1.5 MB)".into(), format!("{:.0} µs", t.mean.as_secs_f64() * 1e6)]);

    let t = time_fn(2, 200, || {
        let v = pool
            .device_zeroed(&[2, 8, 256, 96], |t| Ok(Value::Host(t.clone())))
            .unwrap();
        let _ = std::hint::black_box(v);
    });
    rows.push(vec![
        "pool device_zeroed cached hit (1.5 MB)".into(),
        format!("{:.0} µs", t.mean.as_secs_f64() * 1e6),
    ]);

    let batcher = Batcher::new(8, Duration::from_millis(1));
    let t = time_fn(2, 100, || {
        for i in 0..8 {
            let _ = batcher.submit(i, i);
        }
        let _ = std::hint::black_box(batcher.next_batch());
    });
    rows.push(vec!["batcher 8-slot form".into(), format!("{:.0} µs", t.mean.as_secs_f64() * 1e6)]);

    report.table(&["Operation", "Cost"], &rows);
    report.finish();
    Ok(())
}
