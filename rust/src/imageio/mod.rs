//! Image output substrate: PNG encoder (zlib via the vendored `flate2`),
//! PPM fallback, and a grid compositor for sample sheets.

mod grid;
pub mod png;
mod ppm;

pub use grid::compose_grid;
pub use png::{encode_png, write_png};
pub use ppm::write_ppm;

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// An 8-bit RGB image.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    /// RGB, row-major, 3 bytes per pixel.
    pub pixels: Vec<u8>,
}

impl Image {
    pub fn new(width: usize, height: usize) -> Self {
        Image { width, height, pixels: vec![0; width * height * 3] }
    }

    /// From an (H, W, 3) tensor with values in [-1, 1] (model output range).
    pub fn from_tensor_pm1(t: &Tensor) -> Result<Self> {
        if t.ndim() != 3 || t.shape()[2] != 3 {
            bail!("expected (H, W, 3) tensor, got {:?}", t.shape());
        }
        let (h, w) = (t.shape()[0], t.shape()[1]);
        let pixels = t
            .data()
            .iter()
            .map(|&v| (((v.clamp(-1.0, 1.0) + 1.0) * 0.5) * 255.0).round() as u8)
            .collect();
        Ok(Image { width: w, height: h, pixels })
    }

    /// Back to a (H, W, 3) tensor in [-1, 1].
    pub fn to_tensor_pm1(&self) -> Tensor {
        let data = self
            .pixels
            .iter()
            .map(|&p| (p as f32 / 255.0) * 2.0 - 1.0)
            .collect();
        Tensor::new(&[self.height, self.width, 3], data).unwrap()
    }

    pub fn get(&self, x: usize, y: usize) -> [u8; 3] {
        let o = (y * self.width + x) * 3;
        [self.pixels[o], self.pixels[o + 1], self.pixels[o + 2]]
    }

    pub fn set(&mut self, x: usize, y: usize, rgb: [u8; 3]) {
        let o = (y * self.width + x) * 3;
        self.pixels[o..o + 3].copy_from_slice(&rgb);
    }

    /// Luminance plane as f32 in [0, 255] (BRISQUE input).
    pub fn luminance(&self) -> Vec<f32> {
        self.pixels
            .chunks_exact(3)
            .map(|p| 0.299 * p[0] as f32 + 0.587 * p[1] as f32 + 0.114 * p[2] as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(&[2, 2, 3], vec![
            -1.0, 0.0, 1.0, 0.5, -0.5, 0.25, 1.0, 1.0, -1.0, 0.0, 0.0, 0.0,
        ])
        .unwrap();
        let img = Image::from_tensor_pm1(&t).unwrap();
        assert_eq!(img.get(0, 0), [0, 128, 255]);
        let back = img.to_tensor_pm1();
        for (a, b) in t.data().iter().zip(back.data()) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let t = Tensor::new(&[1, 1, 3], vec![-5.0, 0.0, 5.0]).unwrap();
        let img = Image::from_tensor_pm1(&t).unwrap();
        assert_eq!(img.get(0, 0), [0, 128, 255]);
    }

    #[test]
    fn wrong_shape_rejected() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(Image::from_tensor_pm1(&t).is_err());
    }

    #[test]
    fn luminance_gray() {
        let mut img = Image::new(1, 1);
        img.set(0, 0, [100, 100, 100]);
        let l = img.luminance();
        assert!((l[0] - 100.0).abs() < 0.5);
    }
}
