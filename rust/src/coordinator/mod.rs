//! L3 coordinator — the paper's system contribution wired as a serving stack.
//!
//! * [`jacobi`] — the parallel Jacobi decoding driver (Alg 1): iterate the
//!   per-block fixed point `z ← F(z)` until `‖z^t − z^{t−1}‖∞ < τ`.
//! * [`policy`] — where to use Jacobi (paper §3.5): sequential for the
//!   dependency-heavy first block, Jacobi for the rest, plus uniform /
//!   sequential / adaptive variants.
//! * [`sampler`] — full noise→image pipeline over the AOT artifacts.
//! * [`batcher`] — dynamic request batching onto artifact batch shapes.
//! * [`router`] — multi-worker dispatch (one engine per worker thread).
//! * [`server`] — HTTP/1.1 front end (`/generate`, `/metrics`, `/healthz`).
//! * [`state`] — per-request decode state & KV-cache buffers.

pub mod batcher;
pub mod jacobi;
pub mod maf;
pub mod policy;
pub mod router;
pub mod sampler;
pub mod server;
pub mod state;

pub use jacobi::{InitStrategy, JacobiConfig, JacobiStats};
pub use policy::DecodePolicy;
pub use sampler::{SampleOptions, Sampler};
