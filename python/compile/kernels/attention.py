"""L1 Pallas kernel: causal multi-head attention with dependency-offset mask.

TPU-oriented design (see DESIGN.md §6 — Hardware Adaptation):

* Grid is (B, H): one program per (batch, head). For the model sizes in this
  repo the whole (L, Dh) tile fits comfortably in VMEM (L ≤ 256, Dh ≤ 24 →
  Q/K/V tiles ≤ 24 KB each), so a single-tile schedule with both matmuls on
  the MXU is already roofline-bound; no double-buffering is needed.
* The paper's eq-6 band mask (`col <= row - o`, pad column 0 open) is built
  from iota *inside* the kernel on the score tile — nothing is materialized
  in HBM, unlike a (L, L) boolean mask input.
* Softmax is computed in f32 with the usual max-subtraction, fused between
  the two MXU matmuls — one VMEM round trip for the whole attention op.

Lowered with ``interpret=True``: the CPU PJRT client cannot execute Mosaic
custom-calls, so artifacts embed the interpreted (plain-HLO) form; the real
TPU schedule is what the BlockSpecs above describe.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(o_ref, q_ref, k_ref, v_ref, out_ref):
    """One (batch, head) program: full (L, Dh) attention in VMEM."""
    q = q_ref[0, 0]  # (L, Dh)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    l = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    # MXU matmul #1: scores.
    scores = jnp.dot(q, k.T) * scale  # (L, L)
    # eq-6 band mask from iota — no HBM mask tensor.
    o = o_ref[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    mask = (cols <= rows - o) | (cols == 0)
    scores = jnp.where(mask, scores, -1e30)
    # Fused softmax (f32, max-subtracted).
    m = jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores - m)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # MXU matmul #2: weighted values.
    out_ref[0, 0] = jnp.dot(w, v)


@functools.partial(jax.jit, static_argnames=("interpret",))
def causal_attention(q, k, v, o, interpret=True):
    """Pallas causal attention with eq-6 offset masking.

    Args:
      q, k, v: (B, H, L, Dh) f32
      o: scalar i32 (0 = plain causal) — passed as a (1,) array
      interpret: must stay True for CPU-PJRT execution (see module doc)

    Returns:
      (B, H, L, Dh) f32
    """
    b, h, l, dh = q.shape
    o_arr = jnp.asarray(o, jnp.int32).reshape((1,))
    spec = pl.BlockSpec((1, 1, l, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _attn_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            spec,
            spec,
            spec,
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, l, dh), jnp.float32),
        interpret=interpret,
    )(o_arr, q, k, v)


def vmem_bytes_estimate(l: int, dh: int) -> int:
    """Static VMEM working-set estimate for one program (DESIGN.md §Perf):
    Q, K, V, OUT tiles (L, Dh) + the (L, L) score/weight tile, all f32."""
    return 4 * (4 * l * dh + l * l)


def mxu_flops_estimate(b: int, h: int, l: int, dh: int) -> int:
    """MXU flops for the two matmuls across the grid."""
    return b * h * (2 * l * l * dh) * 2
