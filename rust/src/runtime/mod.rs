//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! The python build path (`make artifacts`) lowers every JAX/Pallas program to
//! **HLO text** (see DESIGN.md §2 — text, not serialized protos, because the
//! xla_extension 0.5.1 proto parser rejects jax ≥ 0.5's 64-bit instruction
//! ids) and records each program's signature in `artifacts/manifest.json`.
//!
//! [`Engine`] owns one `PjRtClient` plus a lazy compile cache keyed by
//! artifact name; [`HostTensor`] is the host-side value type that crosses the
//! boundary.
//!
//! This module is the L2 layer of the stack — see `docs/ARCHITECTURE.md` at
//! the repo root for the full layer map (Pallas kernels → AOT manifest →
//! this runtime → coordinator → HTTP server), and the `manifest.rs` module
//! docs for the `untupled_outputs` output-residency contract the rules
//! below depend on.
//!
//! ## Value lifecycle & device residency
//!
//! Execution is **value-based**: [`Backend::call_v`] consumes and produces
//! [`Value`]s, which are either host data or device-resident buffer handles.
//! The residency rules the coordinator layer relies on:
//!
//! * A `Value::Device` returned by `call_v` stays on the device until someone
//!   calls [`Backend::to_host`] — feeding it back into another `call_v` costs
//!   zero host traffic (a "device hit" in [`CallStats`]).
//! * A `Value::Host` passed to `call_v` is promoted to a device buffer on
//!   entry; the promotion is counted in `CallStats::host_marshals` and its
//!   wall time in `CallStats::marshal_time`, so the marshal numbers in the
//!   perf benches stay truthful for both entry paths.
//! * [`Backend::to_device`] uploads once, explicitly — hot loops use it to
//!   pin loop constants (the Jacobi block input `y`, scalar indices) before
//!   iterating.
//! * **Output residency is decided, never guessed.** `Engine::call_v` wraps
//!   results device-resident only when that is unambiguous: artifacts marked
//!   `untupled_outputs` in the manifest (single-output,
//!   `return_tuple=False` lowering such as `{m}_reverse_b{B}`), or
//!   multi-output artifacts whose root the runtime untupled into one leaf
//!   buffer per output. Everything else — notably every legacy tuple-rooted
//!   artifact when the runtime hands back a single buffer — takes one forced
//!   sync that destructures the result literal (probing leaf vs tuple by
//!   shape) and returns `Value::Host`; the time is charged to
//!   `marshal_time`, and chaining degrades gracefully to host promotion on
//!   the next call instead of breaking.
//! * **Forced sync points** are exactly: `to_host`, and that output
//!   fallback. Everything else stays device-side.
//! * **Thread pinning**: `PjRtClient` is `Rc`-based, so an [`Engine`] and
//!   every `Value::Device` it mints live on one thread. Multi-worker serving
//!   (see `coordinator::router`) gives each worker its own engine; anything
//!   crossing threads must be synced to a plain `Send` [`HostTensor`] first.
//!   Dropping the last clone of a device value frees its buffer.
//! * **Device ordinals**: a client may expose several addressable devices. An
//!   [`Engine`] is pinned to one ordinal at construction and stamps every
//!   buffer it mints with that ordinal; feeding a buffer minted on ordinal
//!   `a` to an engine pinned to `b ≠ a` is a hard error (the aliasing guard —
//!   two engines can no longer silently share device 0). Within one engine,
//!   [`Backend::to_ordinal`] is the sanctioned cross-ordinal move: a PJRT
//!   device→device copy where the runtime supports it, the documented host
//!   hop (one sync + one upload) otherwise, both truthfully charged in
//!   [`TransferStats`]. Ordinal pinning never relaxes thread pinning: values
//!   still cannot cross threads, whatever their ordinal.
//!
//! The legacy host-tensor [`Backend::call`] survives as a default-method shim
//! over `call_v` + `to_host` so the long tail of benches and examples keeps
//! working unchanged.

mod engine;
mod fault;
mod host;
mod manifest;
mod value;

pub use engine::{CallStats, Engine, TransferStats};
pub use fault::{classify, fault_artifact, Fault, FaultClass};
pub use host::HostTensor;
pub use manifest::{
    ArtifactMeta, DType, DatasetMeta, Manifest, ModelMeta, TensorSpec, OPTIONAL_DECODE_ROLES,
};
pub use value::{DeviceValue, Value};

/// Execution backend abstraction: the real PJRT [`Engine`] in production,
/// mock backends in coordinator unit tests (`rust/tests/mock_backend.rs`).
///
/// Implementors provide the value-based [`Backend::call_v`]; backends with
/// real device memory also override [`Backend::to_device`] / [`Backend::to_host`]
/// so callers can pin inputs and pick their sync points (see the
/// [module docs](self) for the residency rules).
pub trait Backend {
    /// Execute an artifact by name on a mix of host and device-resident
    /// values. Outputs are device-resident whenever the backend supports it.
    fn call_v(&self, name: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>>;

    /// Model metadata lookup.
    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta>;

    /// Upload a host tensor to the device once, for reuse across calls.
    ///
    /// Backends without device memory return the data as a `Value::Host`
    /// (the default), which `call_v` accepts equally.
    fn to_device(&self, t: &HostTensor) -> anyhow::Result<Value> {
        Ok(Value::Host(t.clone()))
    }

    /// Sync a value to the host — a forced synchronization point.
    fn to_host(&self, v: Value) -> anyhow::Result<HostTensor> {
        match v {
            Value::Host(t) => Ok(t),
            Value::Device(d) => anyhow::bail!(
                "backend cannot sync a device value (shape {:?}) — was it minted by a different backend?",
                d.shape()
            ),
        }
    }

    /// The addressable-device ordinal this backend's minted values live on.
    /// Host-only backends are ordinal 0 by definition; the real [`Engine`]
    /// reports the ordinal it was pinned to at construction, and multi-device
    /// placement (`coordinator::pipeline`) keys its per-device metrics off it.
    fn device_ordinal(&self) -> usize {
        0
    }

    /// Move a value onto addressable-device `ordinal`, staying on the device
    /// fabric where the runtime supports it (see [`Engine::to_ordinal`]).
    ///
    /// The host-only default passes host values through unchanged — host
    /// tensors carry no device identity — and rejects foreign device values,
    /// mirroring [`Backend::to_host`].
    fn to_ordinal(&self, v: &Value, _ordinal: usize) -> anyhow::Result<Value> {
        match v {
            Value::Host(_) => Ok(v.clone()),
            Value::Device(d) => anyhow::bail!(
                "backend cannot move a device value (shape {:?}) — was it minted by a different backend?",
                d.shape()
            ),
        }
    }

    /// Whether an artifact is available, for optional fast paths (e.g. the
    /// device-side token-reversal gather). Backends default to `false`, which
    /// routes callers to their documented host fallback.
    fn has_artifact(&self, _name: &str) -> bool {
        false
    }

    /// Execute an artifact with host inputs and host outputs — the legacy
    /// entry point, shimmed over [`Backend::call_v`].
    fn call(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let values: Vec<Value> = inputs.iter().cloned().map(Value::Host).collect();
        self.call_v(name, &values)?
            .into_iter()
            .map(|v| self.to_host(v))
            .collect()
    }
}

impl Backend for Engine {
    fn call_v(&self, name: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        Engine::call_v(self, name, inputs)
    }

    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta> {
        self.manifest().model(model).cloned()
    }

    fn to_device(&self, t: &HostTensor) -> anyhow::Result<Value> {
        Engine::to_device(self, t)
    }

    fn to_host(&self, v: Value) -> anyhow::Result<HostTensor> {
        Engine::to_host(self, v)
    }

    fn device_ordinal(&self) -> usize {
        Engine::device_ordinal(self)
    }

    fn to_ordinal(&self, v: &Value, ordinal: usize) -> anyhow::Result<Value> {
        Engine::to_ordinal(self, v, ordinal)
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.manifest().artifacts.contains_key(name)
    }

    // The literal-based host path is kept as the `call` override (rather than
    // the generic shim) because it round-trips through one result literal —
    // the behavior the seed's artifact lowering was validated against.
    fn call(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        Engine::call(self, name, inputs)
    }
}
