//! Thread-pool + channel execution substrate (tokio substitute).
//!
//! The serving stack is synchronous-threaded: a fixed pool of worker threads
//! consumes jobs from an MPMC queue built on `std::sync::mpsc` + `Mutex`.
//! PJRT engines are thread-pinned (`Rc` internals), so model workers are
//! *dedicated* threads created by the router, not pool workers; pools are
//! used for HTTP connection handling, per-image PNG encoding
//! (`coordinator::server` runs one of each, deliberately separate — see its
//! module docs), and load generation.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false, in_flight: 0 }),
            cv: Condvar::new(),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("sjd-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Submit a job.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.queue.lock().unwrap();
        if q.shutdown {
            return;
        }
        q.jobs.push_back(Box::new(job));
        drop(q);
        self.shared.cv.notify_one();
    }

    /// Submit a job and get a [`OneShot`] for its return value — the
    /// building block for dispatching work (e.g. per-image PNG encodes) and
    /// collecting results in submission order.
    pub fn spawn_result<R: Send + 'static>(
        &self,
        job: impl FnOnce() -> R + Send + 'static,
    ) -> OneShot<R> {
        let slot = OneShot::new();
        let out = slot.clone();
        self.spawn(move || out.put(job()));
        slot
    }

    /// Block until the queue is empty and no job is running.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.jobs.is_empty() || q.in_flight > 0 {
            q = self.shared.cv.wait(q).unwrap();
        }
    }

    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    q.in_flight += 1;
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        job();
        let mut q = shared.queue.lock().unwrap();
        q.in_flight -= 1;
        drop(q);
        shared.cv.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One-shot result slot: a worker fills it, the requester blocks on `wait`.
/// (std::sync::mpsc oneshot with a friendlier API and timeout support.)
///
/// Waiters *take* the value, so an empty slot cannot distinguish "never
/// produced" from "already consumed" — the separate `filled` flag records
/// whether a value was EVER put, which is what completion guards and
/// [`put_once`](OneShot::put_once) key on for exactly-once resolution.
pub struct OneShot<T> {
    inner: Arc<(Mutex<OneShotState<T>>, Condvar)>,
}

struct OneShotState<T> {
    value: Option<T>,
    /// True once any `put`/`put_once` has run, even after `wait` consumed
    /// the value.
    filled: bool,
}

impl<T> Clone for OneShot<T> {
    fn clone(&self) -> Self {
        OneShot { inner: self.inner.clone() }
    }
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OneShot<T> {
    pub fn new() -> Self {
        OneShot {
            inner: Arc::new((Mutex::new(OneShotState { value: None, filled: false }), Condvar::new())),
        }
    }

    pub fn put(&self, v: T) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        g.value = Some(v);
        g.filled = true;
        drop(g);
        cv.notify_all();
    }

    /// Fill the slot only if nothing was ever put before; returns whether
    /// this call won. Concurrent resolvers (worker, watchdog, completion
    /// guard) race through this so a slot resolves exactly once.
    pub fn put_once(&self, v: T) -> bool {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        if g.filled {
            return false;
        }
        g.value = Some(v);
        g.filled = true;
        drop(g);
        cv.notify_all();
        true
    }

    /// Whether a value was ever put (true even after a waiter consumed it).
    pub fn filled(&self) -> bool {
        self.inner.0.lock().unwrap().filled
    }

    pub fn wait(&self) -> T {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        loop {
            if let Some(v) = g.value.take() {
                return v;
            }
            g = cv.wait(g).unwrap();
        }
    }

    pub fn wait_timeout(&self, d: std::time::Duration) -> Option<T> {
        let (m, cv) = &*self.inner;
        let deadline = std::time::Instant::now() + d;
        let mut g = m.lock().unwrap();
        loop {
            if let Some(v) = g.value.take() {
                return Some(v);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, timeout) = cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if timeout.timed_out() {
                return g.value.take();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let n = n.clone();
            pool.spawn(move || {
                n.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(n.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_shutdown_joins() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }

    #[test]
    fn spawn_result_returns_in_submission_order() {
        let pool = ThreadPool::new(4);
        let slots: Vec<_> = (0..16u64).map(|i| pool.spawn_result(move || i * i)).collect();
        for (i, s) in slots.into_iter().enumerate() {
            assert_eq!(s.wait(), (i * i) as u64);
        }
    }

    #[test]
    fn oneshot_roundtrip() {
        let slot = OneShot::new();
        let s2 = slot.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            s2.put(42);
        });
        assert_eq!(slot.wait(), 42);
    }

    #[test]
    fn oneshot_timeout() {
        let slot: OneShot<i32> = OneShot::new();
        assert_eq!(slot.wait_timeout(std::time::Duration::from_millis(10)), None);
        slot.put(1);
        assert_eq!(slot.wait_timeout(std::time::Duration::from_millis(10)), Some(1));
    }

    #[test]
    fn oneshot_put_once_resolves_exactly_once() {
        let slot: OneShot<i32> = OneShot::new();
        assert!(!slot.filled());
        assert!(slot.put_once(1));
        assert!(slot.filled());
        assert!(!slot.put_once(2), "second put_once must lose");
        assert_eq!(slot.wait(), 1);
        // `filled` survives consumption: a completion guard checking after
        // the waiter took the value must still see the slot as resolved.
        assert!(slot.filled());
        assert!(!slot.put_once(3));
    }
}
