"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle, including
hypothesis sweeps over shapes and mask offsets."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover — CI installs hypothesis
    # Keep the module collectable without hypothesis: the sweep tests skip,
    # the direct (non-hypothesis) kernel tests still run.
    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Stub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Stub()

from compile.kernels import affine_update, attention, ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

class TestAttention:
    @pytest.mark.parametrize("o", [0, 1, 2, 5])
    def test_matches_ref(self, o):
        b, h, l, dh = 2, 4, 32, 8
        q, k, v = _rand(0, (b, h, l, dh)), _rand(1, (b, h, l, dh)), _rand(2, (b, h, l, dh))
        out_p = attention.causal_attention(q, k, v, o)
        out_r = ref.causal_attention_ref(q, k, v, o)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), atol=1e-5)

    def test_causality(self):
        """Output at position l must not depend on inputs at positions >= l."""
        b, h, l, dh = 1, 2, 16, 4
        q, k, v = _rand(3, (b, h, l, dh)), _rand(4, (b, h, l, dh)), _rand(5, (b, h, l, dh))
        base = np.asarray(attention.causal_attention(q, k, v, 0))
        # Perturb position 10 of k and v; outputs at positions < 10 unchanged.
        k2 = k.at[:, :, 10, :].add(100.0)
        v2 = v.at[:, :, 10, :].add(100.0)
        pert = np.asarray(attention.causal_attention(q, k2, v2, 0))
        np.testing.assert_allclose(base[:, :, :10], pert[:, :, :10], atol=1e-5)
        assert np.abs(base[:, :, 10:] - pert[:, :, 10:]).max() > 1e-3

    def test_offset_mask_blocks_nearest(self):
        """With offset o, position l must ignore positions (l-o, l]."""
        b, h, l, dh = 1, 1, 12, 4
        o = 3
        q, k, v = _rand(6, (b, h, l, dh)), _rand(7, (b, h, l, dh)), _rand(8, (b, h, l, dh))
        base = np.asarray(attention.causal_attention(q, k, v, o))
        # Perturbing position 8 must not affect queries at positions 8..10
        # (they can see only <= pos-o) but may affect position 11.
        k2 = k.at[:, :, 8, :].add(50.0)
        v2 = v.at[:, :, 8, :].add(50.0)
        pert = np.asarray(attention.causal_attention(q, k2, v2, o))
        np.testing.assert_allclose(base[:, :, 8:11], pert[:, :, 8:11], atol=1e-5)
        assert np.abs(base[:, :, 11] - pert[:, :, 11]).max() > 1e-4

    def test_pad_column_always_visible(self):
        """Column 0 stays attendable under any offset (eq-6 convention)."""
        mask = np.asarray(ref.attention_mask(8, 7))
        assert mask[:, 0].all()
        # With huge offset, *only* column 0 is visible for late rows.
        assert not mask[5, 1:6].any()

    def test_rows_sum_to_one(self):
        b, h, l, dh = 1, 1, 10, 4
        q, k, v = _rand(9, (b, h, l, dh)), _rand(10, (b, h, l, dh)), _rand(11, (b, h, l, dh))
        # Take v = identity-ish probe: attention output = weighted mean of v.
        out = np.asarray(attention.causal_attention(q, k, jnp.ones_like(v), 0))
        np.testing.assert_allclose(out, 1.0, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.sampled_from([1, 2, 4]),
        l=st.sampled_from([2, 4, 16, 33]),
        dh=st.sampled_from([2, 8]),
        o=st.integers(0, 4),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, b, h, l, dh, o, seed):
        q, k, v = (_rand(seed + i, (b, h, l, dh)) for i in range(3))
        out_p = attention.causal_attention(q, k, v, o)
        out_r = ref.causal_attention_ref(q, k, v, o)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), atol=2e-5)


# ---------------------------------------------------------------------------
# Affine update
# ---------------------------------------------------------------------------

class TestAffineUpdate:
    def test_matches_ref(self):
        z, y, s, g = (_rand(20 + i, (3, 16, 6)) for i in range(4))
        zp, rp = affine_update.affine_inverse_update(z, y, s, g)
        zr, rr = ref.affine_inverse_update_ref(z, y, s, g)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=1e-5)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rr), atol=1e-5)

    def test_first_token_passthrough(self):
        z, y, s, g = (_rand(30 + i, (2, 8, 4)) for i in range(4))
        zp, _ = affine_update.affine_inverse_update(z, y, s, g)
        np.testing.assert_allclose(np.asarray(zp)[:, 0], np.asarray(y)[:, 0], atol=1e-6)

    def test_residual_is_inf_norm(self):
        z = jnp.zeros((1, 4, 2))
        y = jnp.zeros((1, 4, 2))
        s = jnp.zeros((1, 4, 2))
        g = jnp.zeros((1, 4, 2)).at[0, 2, 1].set(-7.5)
        _, r = affine_update.affine_inverse_update(z, y, s, g)
        np.testing.assert_allclose(np.asarray(r), [7.5], atol=1e-6)

    def test_fixed_point_zero_residual(self):
        """If z_prev already solves the system, residual = 0."""
        y, s, g = (_rand(40 + i, (2, 8, 4)) for i in range(3))
        z_star, _ = ref.affine_inverse_update_ref(jnp.zeros_like(y), y, s, g)
        # s, g computed from z_prev in the real model, but as a pure kernel
        # test: applying the same (s, g) to z_star must reproduce z_star.
        zp, r = affine_update.affine_inverse_update(z_star, y, s, g)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(z_star), atol=1e-6)
        np.testing.assert_allclose(np.asarray(r), 0.0, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 4),
        l=st.sampled_from([1, 2, 16, 31]),
        d=st.sampled_from([1, 3, 12]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, b, l, d, seed):
        z, y, s, g = (_rand(seed + i, (b, l, d)) for i in range(4))
        zp, rp = affine_update.affine_inverse_update(z, y, s, g)
        zr, rr = ref.affine_inverse_update_ref(z, y, s, g)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=2e-5)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rr), atol=2e-5)

    def test_vmem_estimates_positive(self):
        assert affine_update.vmem_bytes_estimate(64, 12) > 0
        assert attention.vmem_bytes_estimate(64, 16) > 0
        assert attention.mxu_flops_estimate(8, 4, 64, 16) > 0


# ---------------------------------------------------------------------------
# Speculative-init extrapolation
# ---------------------------------------------------------------------------


class TestInitExtrapolate:
    def test_matches_ref(self):
        y, s, g = (_rand(90 + i, (3, 16, 6)) for i in range(3))
        zp = affine_update.init_extrapolate(y, s, g)
        zr = ref.init_extrapolate_ref(y, s, g)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=1e-5)

    def test_first_token_passthrough(self):
        y, s, g = (_rand(95 + i, (2, 8, 4)) for i in range(3))
        z0 = affine_update.init_extrapolate(y, s, g)
        np.testing.assert_allclose(np.asarray(z0)[:, 0], np.asarray(y)[:, 0], atol=1e-6)

    def test_equals_update_body_without_residual(self):
        """The extrapolation IS the Alg 1 body — same z' as the fused update
        kernel applied to any iterate (the body never reads z_prev except
        for the residual)."""
        z, y, s, g = (_rand(100 + i, (2, 8, 4)) for i in range(4))
        z0 = affine_update.init_extrapolate(y, s, g)
        z_next, _ = affine_update.affine_inverse_update(z, y, s, g)
        np.testing.assert_allclose(np.asarray(z0), np.asarray(z_next), atol=0)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 4),
        l=st.sampled_from([1, 2, 16, 31]),
        d=st.sampled_from([1, 3, 12]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, b, l, d, seed):
        y, s, g = (_rand(seed + i, (b, l, d)) for i in range(3))
        zp = affine_update.init_extrapolate(y, s, g)
        zr = ref.init_extrapolate_ref(y, s, g)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=2e-5)


# ---------------------------------------------------------------------------
# Windowed affine update (GS-Jacobi inner step)
# ---------------------------------------------------------------------------

class TestAffineUpdateWindow:
    @pytest.mark.parametrize("off,wlen", [(0, 16), (0, 4), (4, 4), (12, 4), (5, 7)])
    def test_matches_ref(self, off, wlen):
        z, y, s, g = (_rand(50 + i, (3, 16, 6)) for i in range(4))
        zp, rp = affine_update.affine_inverse_update_window(z, y, s, g, off, wlen)
        zr, rr = ref.affine_inverse_update_window_ref(z, y, s, g, off, wlen)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=1e-5)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rr), atol=1e-5)

    def test_full_window_equals_plain_update(self):
        """off=0, wlen=L degrades exactly to the unwindowed kernel."""
        z, y, s, g = (_rand(60 + i, (2, 8, 4)) for i in range(4))
        zw, rw = affine_update.affine_inverse_update_window(z, y, s, g, 0, 8)
        zp, rp = affine_update.affine_inverse_update(z, y, s, g)
        np.testing.assert_allclose(np.asarray(zw), np.asarray(zp), atol=0)
        np.testing.assert_allclose(np.asarray(rw), np.asarray(rp), atol=0)

    def test_positions_outside_window_frozen(self):
        z, y, s, g = (_rand(70 + i, (2, 12, 3)) for i in range(4))
        off, wlen = 4, 5
        zw, _ = affine_update.affine_inverse_update_window(z, y, s, g, off, wlen)
        zw = np.asarray(zw)
        zn = np.asarray(z)
        np.testing.assert_array_equal(zw[:, :off], zn[:, :off])
        np.testing.assert_array_equal(zw[:, off + wlen:], zn[:, off + wlen:])
        assert np.abs(zw[:, off:off + wlen] - zn[:, off:off + wlen]).max() > 1e-3

    def test_residual_covers_window_only(self):
        """A huge pending update outside the window must not inflate resid."""
        l = 8
        z = jnp.zeros((1, l, 2))
        y = jnp.zeros((1, l, 2))
        s = jnp.zeros((1, l, 2))
        # g drives position 6 far from its iterate; window is [1, 3).
        g = jnp.zeros((1, l, 2)).at[0, 6, 0].set(100.0).at[0, 2, 1].set(-3.0)
        _, r = affine_update.affine_inverse_update_window(z, y, s, g, 1, 2)
        np.testing.assert_allclose(np.asarray(r), [3.0], atol=1e-6)

    def test_first_token_passthrough_inside_window(self):
        z, y, s, g = (_rand(80 + i, (2, 8, 4)) for i in range(4))
        zw, _ = affine_update.affine_inverse_update_window(z, y, s, g, 0, 3)
        np.testing.assert_allclose(np.asarray(zw)[:, 0], np.asarray(y)[:, 0], atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 3),
        l=st.sampled_from([2, 7, 16, 31]),
        d=st.sampled_from([1, 3, 12]),
        frac=st.tuples(st.floats(0, 1), st.floats(0.01, 1)),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, b, l, d, frac, seed):
        off = min(int(frac[0] * l), l - 1)
        wlen = max(1, min(int(frac[1] * l), l - off))
        z, y, s, g = (_rand(seed + i, (b, l, d)) for i in range(4))
        zp, rp = affine_update.affine_inverse_update_window(z, y, s, g, off, wlen)
        zr, rr = ref.affine_inverse_update_window_ref(z, y, s, g, off, wlen)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=2e-5)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rr), atol=2e-5)
