//! **Table A4**: per-layer runtime breakdown, sequential vs SJD. Under SJD
//! the sequential layer 1 dominates total cost; Jacobi layers complete in a
//! fraction of the per-layer sequential time. "Other" = noise generation,
//! permutations, unpatchify. The extra "Marshal" row reports host↔device
//! traffic time from the engine stats — the component the device-resident
//! Value API shrinks (paper Table A4 buckets it under "Other").

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = if engine.manifest().model("tfafhq").is_ok() { "tfafhq" } else { "tf10" };
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let kk = sampler.meta.blocks;
    let reps = if quick() { 1 } else { 3 };

    let mut report = Report::new(format!("Table A4 — per-layer runtime breakdown ({model})"));
    let mut rows = Vec::new();

    let mut data: Vec<(String, Vec<f64>, f64, f64)> = Vec::new();
    for policy in [DecodePolicy::Sequential, DecodePolicy::Selective { seq_blocks: 1 }] {
        let label = policy.label();
        let _ = generate(&sampler, policy.clone(), 0.5, batch, 1)?; // warmup
        engine.reset_stats();
        let run = generate(&sampler, policy.clone(), 0.5, batch * reps, 42)?;
        // Sum marshal time across every artifact plus explicit transfers.
        let stats = engine.stats();
        let xfer = engine.transfer_stats();
        let marshal = (stats.values().map(|s| s.marshal_time).sum::<std::time::Duration>()
            + xfer.upload_time
            + xfer.sync_time)
            .as_secs_f64()
            / run.batches as f64;
        let per_layer: Vec<f64> =
            (0..kk).map(|p| mean_f64(&run.per_position_wall[p])).collect();
        let other = run.other_wall / run.batches as f64;
        data.push((label, per_layer, other, marshal));
    }

    for pos in 0..kk {
        let mut row = vec![format!("Layer {}", pos + 1)];
        for (_, per_layer, _, _) in &data {
            row.push(format!("{:.3}", per_layer[pos]));
        }
        rows.push(row);
    }
    let mut other_row = vec!["Other".to_string()];
    let mut marshal_row = vec!["Marshal (within the above)".to_string()];
    let mut total_row = vec!["Total".to_string()];
    for (_, per_layer, other, marshal) in &data {
        other_row.push(format!("{other:.3}"));
        marshal_row.push(format!("{marshal:.3}"));
        total_row.push(format!("{:.3}", per_layer.iter().sum::<f64>() + other));
    }
    rows.push(other_row);
    rows.push(marshal_row);
    rows.push(total_row);

    let header: Vec<String> = std::iter::once("Component".to_string())
        .chain(data.iter().map(|(l, _, _, _)| format!("{l} (s)")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    report.table(&header_refs, &rows);
    report.note("Paper shape: sequential layers all cost ≈ the same; under SJD layer 1 dominates and Jacobi layers are cheap. Marshal = host↔device traffic inside the layer/Other times; the device-resident Value API keeps it flat as batch grows.");
    report.finish();
    Ok(())
}
