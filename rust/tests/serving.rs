//! Serving-stack integration: batcher + router workers + HTTP server.
//!
//! Two tiers: hermetic tests over the shared mock backend
//! (`sjd::testkit::mockflow`) — bucket routing, padding accounting,
//! concurrent request handling, keep-alive — and artifact-driven end-to-end
//! tests over real TCP + PJRT that skip when artifacts are missing.

use sjd::coordinator::batcher::{Batcher, Priority, SubmitOpts, DEADLINE_EXPIRED_MSG, WORKER_FAILED_MSG};
use sjd::coordinator::fault::FaultPolicy;
use sjd::coordinator::jacobi::{InitStrategy, JacobiConfig, JacobiStats};
use sjd::coordinator::policy::{
    calibrate_chunks, BlockDecode, DecodePolicy, GovernorConfig, OverloadGovernor, PolicyTuner,
    TunerConfig,
};
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::coordinator::server::{PolicySource, Server, ServerConfig};
use sjd::metrics::Registry;
use sjd::runtime::FaultClass;
use sjd::tensor::Pcg64;
use sjd::testkit::fault::{FaultPlan, FaultyBackend};
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("SJD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built");
        None
    }
}

/// One-shot POST: asks the server to close the connection so the whole
/// response can be slurped with `read_to_string`.
fn post(addr: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// [`post`] with extra raw header lines (QoS: deadline / priority), each
/// ending in `\r\n`.
fn post_with(addr: &str, path: &str, extra_headers: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n{extra_headers}Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// One-shot GET (`Connection: close`, see [`post`]).
fn get(addr: &str, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// One HTTP response off a keep-alive connection (stream stays usable).
fn read_response(reader: &mut impl BufRead) -> String {
    let (head, body) = sjd::testkit::http::read_response(reader).expect("response");
    head + &String::from_utf8_lossy(&body)
}

/// Boot a single-worker router over the shared mock backend.
fn mock_router(
    buckets: &[usize],
    slot_delay: Duration,
    policy: DecodePolicy,
    batcher: &Batcher,
    registry: &Registry,
    ledger: &Arc<MockLedger>,
) -> Router {
    let buckets = buckets.to_vec();
    let ledger = ledger.clone();
    Router::start_with(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(), // = every bucket the mock claims lowered
            workers: 1,
            options: SampleOptions { policy, ..Default::default() },
            pipeline_depth: 1,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        move |_widx| Ok(MockServeBackend::new(&buckets, slot_delay, ledger.clone())),
    )
    .expect("mock router")
}

fn start_server(server: Server) -> (Arc<AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let addr = server.addr().to_string();
    let stop = server.stop_flag();
    let t = std::thread::spawn(move || server.run());
    for _ in 0..100 {
        if TcpStream::connect(&addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    (stop, t)
}

fn stop_server(
    addr: &str,
    stop: Arc<AtomicBool>,
    t: std::thread::JoinHandle<anyhow::Result<()>>,
) {
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    let _ = t.join();
}

// ---------------------------------------------------------------------------
// Hermetic mock-backend serving tests
// ---------------------------------------------------------------------------

#[test]
fn healthz_and_metrics_respond_while_decode_in_flight() {
    // Sequential policy + 25 ms per seqstep call ⇒ each n=1 decode takes
    // ~K·L·25 ms = 800 ms on the single worker. With connection handling on
    // the pool, /healthz and /metrics must answer mid-decode instead of
    // queueing behind the generations.
    let addr = "127.0.0.1:8501";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(2));
    let ledger = MockLedger::new();
    let router = mock_router(
        &[1],
        Duration::from_millis(25),
        DecodePolicy::Sequential,
        &batcher,
        &registry,
        &ledger,
    );
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads: 4, ..Default::default() },
    );
    let (stop, t) = start_server(server);

    let gen_done = [Arc::new(AtomicBool::new(false)), Arc::new(AtomicBool::new(false))];
    let mut gens = Vec::new();
    for (i, done) in gen_done.iter().enumerate() {
        let done = done.clone();
        gens.push(std::thread::spawn(move || {
            let resp = post(addr, "/generate", &format!("{{\"n\": 1, \"seed\": {i}}}"));
            done.store(true, Ordering::SeqCst);
            resp
        }));
    }

    // Probe while the first decode is provably still running.
    std::thread::sleep(Duration::from_millis(250));
    let t_probe = Instant::now();
    let h = get(addr, "/healthz");
    let m = get(addr, "/metrics");
    let probe_wall = t_probe.elapsed();
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");
    assert!(m.starts_with("HTTP/1.1 200"), "{m}");
    assert!(m.contains("sjd_http_requests"), "{m}");
    assert!(
        !gen_done[0].load(Ordering::SeqCst) && !gen_done[1].load(Ordering::SeqCst),
        "probes must return before the generations finish"
    );
    assert!(
        probe_wall < Duration::from_millis(500),
        "probe took {probe_wall:?} — serialized behind a decode?"
    );

    for g in gens {
        let resp = g.join().unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    }
    stop_server(addr, stop, t);
    router.shutdown();
}

#[test]
fn n1_generate_uses_bucket_1_with_zero_padding() {
    // The headline property: with buckets {1,2,4,8} lowered, a lone n=1
    // request decodes through the b1 artifacts and pads nothing.
    let addr = "127.0.0.1:8502";
    let registry = Registry::new();
    let batcher = Batcher::new(8, Duration::from_millis(10));
    let ledger = MockLedger::new();
    let router = mock_router(
        &[1, 2, 4, 8],
        Duration::ZERO,
        DecodePolicy::Selective { seq_blocks: 1 },
        &batcher,
        &registry,
        &ledger,
    );
    let server = Server::new(addr, batcher.clone(), registry.clone());
    let (stop, t) = start_server(server);

    let resp = post(addr, "/generate", r#"{"n": 1, "seed": 3}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("json body");
    assert_eq!(v.req_arr("images_png_b64").unwrap().len(), 1);

    assert_eq!(registry.counter("sjd_padded_slots").get(), 0, "n=1 must pad zero slots");
    assert_eq!(registry.counter("sjd_bucket_1_batches").get(), 1);
    assert!(ledger.count_containing("_b1") > 0, "decode must run the b1 artifacts");
    // Per-block convergence observability: one sjd_block_iters +
    // sjd_host_syncs sample per decoded block (mock flow has 4 blocks).
    assert_eq!(registry.histogram("sjd_block_iters").count(), 4);
    assert_eq!(registry.histogram("sjd_host_syncs").count(), 4);
    assert!(registry.histogram("sjd_host_syncs").snapshot().max >= 1);
    for b in [2usize, 4, 8] {
        assert_eq!(ledger.count_containing(&format!("_b{b}")), 0, "bucket {b} must stay idle");
    }
    stop_server(addr, stop, t);
    router.shutdown();
}

#[test]
fn three_slot_batch_rounds_up_to_bucket_4_with_one_pad() {
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(150));
    let ledger = MockLedger::new();
    let router = mock_router(
        &[1, 2, 4],
        Duration::ZERO,
        DecodePolicy::Selective { seq_blocks: 1 },
        &batcher,
        &registry,
        &ledger,
    );

    // 3 slots land together, the 4-slot deadline lapses, the worker picks
    // bucket 4 and pads exactly one slot.
    let handles: Vec<_> = (0..3).map(|i| batcher.submit(7, i).unwrap()).collect();
    for h in handles {
        let img = h.wait().expect("decoded image");
        assert_eq!(img.ndim(), 3);
    }
    assert_eq!(registry.counter("sjd_bucket_4_batches").get(), 1);
    assert_eq!(registry.counter("sjd_padded_slots").get(), 1);
    assert!(ledger.count_containing("_b4") > 0);
    assert_eq!(ledger.count_containing("_b2"), 0);

    // A lone follow-up slot drops to bucket 1 — no new padding.
    batcher.submit(8, 9).unwrap().wait().expect("decoded image");
    assert_eq!(registry.counter("sjd_bucket_1_batches").get(), 1);
    assert_eq!(registry.counter("sjd_padded_slots").get(), 1, "bucket 1 adds no padding");
    let fill = registry.histogram("sjd_batch_fill").snapshot();
    assert_eq!(fill.count, 2);
    assert_eq!(fill.max, 3, "batch fill records real slots, not the padded bucket");
    router.shutdown();
}

#[test]
fn keepalive_connection_serves_multiple_requests() {
    // No router needed: /healthz and /metrics don't touch the batcher.
    let addr = "127.0.0.1:8503";
    let registry = Registry::new();
    let server = Server::new(addr, Batcher::new(1, Duration::from_millis(5)), registry.clone());
    let (stop, t) = start_server(server);

    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = s.try_clone().unwrap();
    let mut reader = BufReader::new(s);
    // Two requests ride the HTTP/1.1 default keep-alive; the third asks for
    // close and the server must honor it.
    for _ in 0..2 {
        write!(writer, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let resp = read_response(&mut reader);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("Connection: keep-alive"), "{resp}");
    }
    write!(writer, "GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let resp = read_response(&mut reader);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("Connection: close"), "{resp}");
    let mut rest = String::new();
    reader.read_to_string(&mut rest).expect("server closes after Connection: close");
    assert!(rest.is_empty());

    assert_eq!(registry.counter("sjd_http_requests").get(), 3);
    assert_eq!(registry.counter("sjd_http_keepalive_reuses").get(), 2);
    stop_server(addr, stop, t);
}

#[test]
fn generate_after_shutdown_returns_503_not_500() {
    // Post-close submissions fail fast (Batcher::submit), and the HTTP
    // layer must classify them as 503 Service Unavailable — the server is
    // draining, the client did nothing wrong and a retry elsewhere is
    // correct — not a generic 500, and never a hang on a slot no worker
    // will ever decode.
    let addr = "127.0.0.1:8504";
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(5));
    let server = Server::new(addr, batcher.clone(), registry.clone());
    let (stop, t) = start_server(server);

    batcher.close(); // simulates router.shutdown() while the listener lives
    let resp = post(addr, "/generate", r#"{"n": 1}"#);
    assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("error body is JSON");
    assert!(v.get("error").is_some());
    let m = get(addr, "/metrics");
    assert!(m.contains("sjd_shed_total{reason=\"shutdown\"} 1"), "{m}");
    stop_server(addr, stop, t);
}

#[test]
fn pipelined_router_matches_monolithic_images() {
    // The stage-graph path (pipeline_depth 2: one engine per stage thread,
    // ≥2 batches in flight) must produce bit-identical images to the
    // monolithic worker for identical submissions.
    let run = |depth: usize| -> (Vec<Vec<f32>>, Registry) {
        let registry = Registry::new();
        let batcher = Batcher::new(1, Duration::from_millis(2));
        let ledger = MockLedger::new();
        let router = Router::start_with(
            RouterConfig {
                artifacts_dir: "unused-by-mock".into(),
                model: "mock".into(),
                buckets: Vec::new(),
                workers: 1,
                options: SampleOptions::default(),
                pipeline_depth: depth,
                stage_threads: 0,
                refill: false,
                tuner: None,
                warm_cap: 0,
                governor: None,
                fault: Default::default(),
                replicas: 1,
                devices: 1,
            },
            batcher.clone(),
            registry.clone(),
            move |_| Ok(MockServeBackend::new(&[1], Duration::ZERO, ledger.clone())),
        )
        .expect("router");
        let mut images = Vec::new();
        for seed in 0..4u64 {
            let img = batcher.submit(seed, seed * 3 + 1).unwrap().wait().expect("image");
            images.push(img.data().to_vec());
        }
        router.shutdown();
        (images, registry)
    };
    let (mono, _) = run(1);
    let (piped, registry) = run(2);
    assert_eq!(mono, piped, "pipelined decode must be bit-exact with monolithic");
    // The pipelined run exposes the stage-graph metrics (4 mock blocks ⇒
    // stages 0..=3, each touched by all 4 batches).
    assert_eq!(registry.histogram("sjd_stage_wait").count(), 16);
    assert_eq!(registry.gauge("sjd_stage_3_occupancy").get(), 0);
    assert_eq!(registry.histogram("sjd_decode_time").snapshot().count, 4);
    assert_eq!(registry.counter("sjd_bucket_1_batches").get(), 4);
}

/// Offline-vs-online agreement is compared in (windows, chunk) space — the
/// knobs the tuner adjusts.
fn windows_chunk(mode: &BlockDecode) -> (usize, usize) {
    match mode {
        BlockDecode::Sequential => (0, 0),
        BlockDecode::Jacobi => (1, 0),
        BlockDecode::Fused { chunk } => (1, *chunk),
        BlockDecode::GsJacobi { windows } => (*windows, 0),
        BlockDecode::GsFused { windows, chunk } => (*windows, *chunk),
    }
}

#[test]
fn tuned_router_converges_to_offline_calibration() {
    // Acceptance contract: a --tune'd serve run, with NO calibration file,
    // converges to within ±1 window/chunk of the offline `sjd calibrate
    // --chunks` answer on the mock flow.
    let kk = 4usize;
    let seq_len = 8usize;
    let (max_windows, s_max) = (8usize, 4usize);

    // Offline reference: the cmd_calibrate measurement loop (sequential
    // chain + per-block full-sequence Jacobi at the default τ), averaged
    // over several priors for a stable iteration estimate. Sequential walls
    // are pinned large: on a real accelerator sequential decode is the slow
    // baseline, and hermetic wall-clock noise must not flip blocks.
    let be = MockServeBackend::new(&[2], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 2).unwrap();
    let draws = 8u64;
    let mut mean_iters = vec![0f64; kk];
    for d in 0..draws {
        let mut rng = Pcg64::seed(100 + d);
        let mut h = sampler.sample_prior(&mut rng);
        for (pos, mean) in mean_iters.iter_mut().enumerate() {
            let k = kk - 1 - pos;
            let (_z, stats) = sampler.jacobi_decode(k, &h, &JacobiConfig::default(), 0).unwrap();
            assert!(stats.converged, "mock blocks converge at the default τ");
            *mean += stats.iterations as f64 / draws as f64;
            let (u, _) = sampler.sequential_decode_block(k, &h).unwrap();
            h = if k % 2 == 1 { sampler.reverse_tokens(&u).unwrap() } else { u };
        }
    }
    let jstats: Vec<JacobiStats> = mean_iters
        .iter()
        .enumerate()
        .map(|(pos, &m)| JacobiStats {
            block: kk - 1 - pos,
            iterations: m.round() as usize,
            wall: Duration::from_millis(1),
            residuals: vec![],
            converged: true,
            host_syncs: 0,
        })
        .collect();
    let seq_walls = vec![Duration::from_secs(1); kk];
    let offline = calibrate_chunks(&jstats, &seq_walls, seq_len, max_windows, s_max);

    // Online: a tuned router (stage-pipelined, depth 2) over live traffic.
    let tuner = Arc::new(PolicyTuner::new(
        kk,
        seq_len,
        DecodePolicy::UniformJacobi,
        TunerConfig { s_max, max_windows, alpha: 0.3, min_obs: 3, probe_every: 8, dwell: 2 },
    ));
    let registry = Registry::new();
    let batcher = Batcher::new(2, Duration::from_millis(100));
    let ledger = MockLedger::new();
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() },
            pipeline_depth: 2,
            stage_threads: 0,
            refill: false,
            tuner: Some(tuner.clone()),
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        move |_| Ok(MockServeBackend::new(&[2], Duration::ZERO, ledger.clone())),
    )
    .expect("tuned router");
    for round in 0..24u64 {
        let a = batcher.submit(round, 1000 + round * 2).unwrap();
        let b = batcher.submit(round, 1001 + round * 2).unwrap();
        a.wait().expect("image");
        b.wait().expect("image");
    }
    router.shutdown();

    let DecodePolicy::PerBlock { modes: tuned } = tuner.snapshot(2).expect("bucket 2 tuned")
    else {
        panic!("tuner snapshot is per-block");
    };
    let DecodePolicy::PerBlock { modes: want } = offline else { unreachable!() };
    for pos in 0..kk {
        let (w_off, c_off) = windows_chunk(&want[pos]);
        let (w_on, c_on) = windows_chunk(&tuned[pos]);
        assert!(
            w_off.abs_diff(w_on) <= 1,
            "pos {pos}: windows {w_on} vs offline {w_off} ({:?} vs {:?})",
            tuned[pos],
            want[pos]
        );
        assert!(
            c_off.abs_diff(c_on) <= 1,
            "pos {pos}: chunk {c_on} vs offline {c_off} ({:?} vs {:?})",
            tuned[pos],
            want[pos]
        );
    }
}

#[test]
fn tuned_router_reverts_unpaying_init_provider_to_zeros() {
    // Draft-then-refine can never pay on the mock flow: the coarse draft
    // pass costs at least as many position updates as it saves the refine
    // pass (triangular dependence makes zeros-init already optimal per
    // iteration). A --tune'd router must notice that from its own traces,
    // revert the bucket to zeros, and export the realized overspend.
    let tuner = Arc::new(
        PolicyTuner::new(
            4,
            8,
            DecodePolicy::UniformJacobi,
            TunerConfig { min_obs: 2, probe_every: 64, ..Default::default() },
        )
        .with_init(InitStrategy::Draft),
    );
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(2));
    let ledger = MockLedger::new();
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() },
            pipeline_depth: 1, // monolithic: the pipelined path demotes draft
            stage_threads: 0,
            refill: false,
            tuner: Some(tuner.clone()),
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        move |_| Ok(MockServeBackend::new(&[1], Duration::ZERO, ledger.clone())),
    )
    .expect("tuned router");
    for seed in 0..10u64 {
        batcher.submit(seed, seed).unwrap().wait().expect("image");
    }
    router.shutdown();

    // The draft decodes really speculated — and really overspent.
    assert!(
        registry.counter("sjd_spec_init_hits").get() > 0,
        "draft decodes must record speculative hits"
    );
    assert!(
        registry.counter("sjd_spec_wasted_updates").get() > 0,
        "draft overspend must surface as sjd_spec_wasted_updates"
    );
    // The bucket reverted: the tuner's /policy JSON reports it inactive
    // while still recording what the operator requested.
    let v = tuner.to_json();
    let init = v.get("init").expect("tuner json carries init state");
    assert_eq!(init.req_str("requested").unwrap(), "draft");
    let b = init.get("buckets").and_then(|b| b.get("1")).expect("bucket 1 init state");
    assert_eq!(b.get("active").and_then(|a| a.as_bool()), Some(false), "{v:?}");
    // And the serving decision follows: the bucket's next decode runs
    // zeros, not the provider.
    assert_eq!(tuner.init_for(1), InitStrategy::Zeros);
}

#[test]
fn policy_endpoint_serves_static_and_tuner_state() {
    // /policy with a static source answers the configured policy; with a
    // tuner attached it answers the live state; without either it is 404.
    let addr = "127.0.0.1:8506";
    let registry = Registry::new();
    let pol = DecodePolicy::GsJacobi { windows: 4 };
    let tuner = Arc::new(PolicyTuner::new(
        4,
        8,
        DecodePolicy::UniformJacobi,
        TunerConfig::default(),
    ));
    let _ = tuner.policy_for(2); // touch one bucket so state is non-empty
    let server = Server::with_config(
        addr,
        Batcher::new(1, Duration::from_millis(5)),
        registry.clone(),
        ServerConfig {
            policy: Some(PolicySource { configured: pol.to_json(), tuner: Some(tuner) }),
            ..Default::default()
        },
    );
    let (stop, t) = start_server(server);
    let resp = get(addr, "/policy");
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("policy body is JSON");
    assert_eq!(v.req_str("source").unwrap(), "tuner");
    assert!(v.get("buckets").is_some());
    stop_server(addr, stop, t);

    // Static fallback (no tuner).
    let addr = "127.0.0.1:8507";
    let server = Server::with_config(
        addr,
        Batcher::new(1, Duration::from_millis(5)),
        Registry::new(),
        ServerConfig {
            policy: Some(PolicySource { configured: pol.to_json(), tuner: None }),
            ..Default::default()
        },
    );
    let (stop, t) = start_server(server);
    let resp = get(addr, "/policy");
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).unwrap();
    assert_eq!(v.req_str("source").unwrap(), "static");
    assert_eq!(v.get("policy").and_then(|p| p.req_str("kind").ok()), Some("gs"));
    stop_server(addr, stop, t);

    // No source wired in → 404.
    let addr = "127.0.0.1:8508";
    let server = Server::new(addr, Batcher::new(1, Duration::from_millis(5)), Registry::new());
    let (stop, t) = start_server(server);
    let resp = get(addr, "/policy");
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
    stop_server(addr, stop, t);
}

// ---------------------------------------------------------------------------
// Continuous-batching chaos/soak harness + HTTP front-door robustness
// ---------------------------------------------------------------------------

/// Deterministic PCG-style stream for the chaos schedule — the test must
/// replay the same bursts/gaps every run (no OS entropy).
struct ChaosRng(u64);

impl ChaosRng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

#[test]
fn chaos_soak_every_slot_resolves_and_queues_drain() {
    // The serving chaos harness over the continuous (`refill: true`) stack:
    // bursty arrivals, clients vanishing mid-decode, and a shutdown racing
    // the refill drain. Invariants: every well-behaved request is answered
    // 200/500 (never a hang), every directly-submitted slot resolves, and
    // the queue is empty once the router is down.
    let addr = "127.0.0.1:8521";
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(5));
    let ledger = MockLedger::new();
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() },
            pipeline_depth: 1,
            stage_threads: 0,
            refill: true,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        {
            let ledger = ledger.clone();
            move |_| {
                Ok(MockServeBackend::new(&[1, 2, 4], Duration::from_micros(300), ledger.clone()))
            }
        },
    )
    .expect("refill router");
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads: 8, ..Default::default() },
    );
    let (stop, t) = start_server(server);

    let mut rng = ChaosRng(0x5eed);
    let mut clients = Vec::new();
    for _burst in 0..6 {
        // A Poisson-ish burst of well-behaved clients ...
        for _ in 0..(rng.next() % 3 + 1) {
            let seed = rng.next();
            clients.push(std::thread::spawn(move || {
                post(addr, "/generate", &format!("{{\"n\": {}, \"seed\": {seed}}}", seed % 2 + 1))
            }));
        }
        // ... plus one that submits a 4-slot request and vanishes without
        // reading the response — the handler's disconnect poll must cancel
        // the remaining slots so the wave sweeps them at a block boundary.
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(s, "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: 8\r\n\r\n{{\"n\":4}}")
            .unwrap();
        std::thread::sleep(Duration::from_millis(rng.next() % 10 + 1));
        drop(s); // mid-decode disconnect
        std::thread::sleep(Duration::from_millis(rng.next() % 20 + 5));
    }
    for c in clients {
        let resp = c.join().expect("client thread must not hang or panic");
        assert!(
            resp.starts_with("HTTP/1.1 200")
                || resp.starts_with("HTTP/1.1 500")
                || resp.starts_with("HTTP/1.1 503"),
            "every request resolves with a response: {resp}"
        );
    }

    // Shutdown-during-refill: slots land right before close; the stage-0
    // drain must still flush each one to a resolution (image or error).
    let direct: Vec<_> = (0..8).filter_map(|i| batcher.submit_slot(9000 + i, i).ok()).collect();
    assert!(!direct.is_empty());
    stop_server(addr, stop, t);
    router.shutdown();
    for h in &direct {
        assert!(
            h.done.wait_timeout(Duration::from_secs(30)).is_some(),
            "slot must resolve after shutdown, never hang"
        );
    }
    assert_eq!(batcher.queued(), 0, "queues must drain on close");
    assert!(batcher.submit(1, 1).is_err(), "closed batcher fails fast");
    // The fleet really decoded work, and only through lowered buckets.
    assert!(ledger.count_containing("_jstep") > 0);
    assert!(registry.counter("sjd_images_generated").get() > 0);
    assert_eq!(ledger.count_containing("_b8"), 0, "no unlowered bucket was touched");
}

#[test]
fn http_front_door_survives_partial_and_pipelined_requests() {
    // No router needed: these exercise the connection loop's defensive
    // paths. A panicked conn-pool thread would hang the server's drop/join,
    // so the test completing at all is part of the assertion.
    let addr = "127.0.0.1:8522";
    let registry = Registry::new();
    let server = Server::new(addr, Batcher::new(1, Duration::from_millis(5)), registry.clone());
    let (stop, t) = start_server(server);

    // Truncated request line then EOF: answered best-effort 400 / closed.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"POST /gene").unwrap();
    drop(s);

    // Mid-body disconnect: headers promise 100 bytes, 10 arrive, then EOF.
    // A benign transport death — nothing to answer, no thread panic.
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: 100\r\n\r\n0123456789")
        .unwrap();
    drop(s);

    // Header section over the byte cap: answered 400, not a silent reset.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut req = String::from("GET /healthz HTTP/1.1\r\nX-Big: ");
    req.push_str(&"a".repeat(64 << 10));
    req.push_str("\r\n\r\n");
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    assert!(out.starts_with("HTTP/1.1 400"), "{out}");

    // A fat-but-legal header section still under the cap: served normally.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut req = String::from("GET /healthz HTTP/1.1\r\nHost: t\r\nX-Big: ");
    req.push_str(&"a".repeat(32 << 10));
    req.push_str("\r\nConnection: close\r\n\r\n");
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    assert!(out.starts_with("HTTP/1.1 200"), "{out}");

    // Pipelined keep-alive: two requests in one write, two responses read
    // back off the same connection — the buffered-request path must not
    // park on an idle peek between them.
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = s.try_clone().unwrap();
    writer
        .write_all(
            b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n\
              GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
    let mut reader = BufReader::new(s);
    let first = read_response(&mut reader);
    assert!(first.starts_with("HTTP/1.1 200"), "{first}");
    let second = read_response(&mut reader);
    assert!(second.starts_with("HTTP/1.1 200"), "{second}");
    assert!(second.contains("sjd_http_requests"), "{second}");

    // The pool survived all of it: a plain request still answers, and the
    // malformed-framing counter moved.
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");
    assert!(registry.counter("sjd_http_errors").get() >= 1);
    stop_server(addr, stop, t);
}

// ---------------------------------------------------------------------------
// Overload & QoS: admission control, deadlines, priorities
// ---------------------------------------------------------------------------

#[test]
fn queue_full_sheds_429_with_retry_after() {
    // Admission control: with the queue at its cap and no worker draining,
    // a /generate must be shed *at submit* with 429 + Retry-After — fail
    // fast, never park the client behind a queue that cannot make its
    // deadline anyway.
    let addr = "127.0.0.1:8531";
    let registry = Registry::new();
    let batcher = Batcher::with_cap(1, Duration::from_millis(50), 2);
    batcher.bind_metrics(&registry);
    let server = Server::new(addr, batcher.clone(), registry.clone());
    let (stop, t) = start_server(server);

    // Fill the bounded queue directly (no router: nothing drains it).
    let _held: Vec<_> = (0..2).map(|i| batcher.submit(i, i).unwrap()).collect();
    assert_eq!(batcher.queued(), 2);

    let resp = post(addr, "/generate", r#"{"n": 1}"#);
    assert!(resp.starts_with("HTTP/1.1 429"), "{resp}");
    assert!(resp.contains("Retry-After:"), "429 must carry Retry-After: {resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("shed body is JSON");
    assert!(v.req_str("error").unwrap().contains("full"), "{body}");

    let m = get(addr, "/metrics");
    assert!(m.contains("sjd_shed_total{reason=\"queue_full\"} 1"), "{m}");
    assert!(m.contains("sjd_queue_cap 2"), "{m}");
    assert!(m.contains("sjd_queue_depth 2"), "{m}");
    stop_server(addr, stop, t);
}

#[test]
fn deadline_expired_request_answers_504() {
    // A request whose X-SJD-Deadline-Ms lapses while its slots sit in the
    // queue (no worker here) must resolve 504 Gateway Timeout at the
    // deadline — not block until shutdown.
    let addr = "127.0.0.1:8532";
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(5));
    let server = Server::new(addr, batcher.clone(), registry.clone());
    let (stop, t) = start_server(server);

    let t0 = Instant::now();
    let resp = post_with(addr, "/generate", "X-SJD-Deadline-Ms: 60\r\n", r#"{"n": 1}"#);
    let wall = t0.elapsed();
    assert!(resp.starts_with("HTTP/1.1 504"), "{resp}");
    assert!(wall < Duration::from_secs(10), "504 must arrive at the deadline, took {wall:?}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("timeout body is JSON");
    assert!(v.req_str("error").unwrap().contains(DEADLINE_EXPIRED_MSG), "{body}");
    assert!(registry.counter("sjd_deadline_expired").get() >= 1);

    // A malformed deadline header is a client error, not a served request.
    let bad = post_with(addr, "/generate", "X-SJD-Deadline-Ms: soon\r\n", r#"{"n": 1}"#);
    assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");
    stop_server(addr, stop, t);
}

#[test]
fn overload_chaos_soak_qos_statuses_and_bounded_queue() {
    // The overload chaos harness: a capped queue under ~2× oversubscription
    // with mixed priorities, deadlines, and mid-decode disconnects, over the
    // continuous (refill) stack. Invariants: every well-behaved request
    // resolves exactly once with a *classified* status — 200 (served), 429
    // (shed at admission), 503 (shutting down), 504 (deadline) — never a
    // bare 500 or a hang; the queue never exceeds its cap; the queue drains
    // on shutdown.
    let addr = "127.0.0.1:8533";
    let registry = Registry::new();
    let cap = 4usize;
    let batcher = Batcher::with_cap(4, Duration::from_millis(5), cap);
    batcher.bind_metrics(&registry);
    let ledger = MockLedger::new();
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() },
            pipeline_depth: 1,
            stage_threads: 0,
            refill: true,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        {
            let ledger = ledger.clone();
            move |_| {
                Ok(MockServeBackend::new(&[1, 2, 4], Duration::from_millis(1), ledger.clone()))
            }
        },
    )
    .expect("refill router");
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads: 16, ..Default::default() },
    );
    let (stop, t) = start_server(server);

    let mut rng = ChaosRng(0xD05_0DE);
    let mut clients = Vec::new();
    for _burst in 0..5 {
        for _ in 0..(rng.next() % 4 + 3) {
            let seed = rng.next();
            let kind = rng.next() % 4;
            clients.push(std::thread::spawn(move || {
                let body = format!("{{\"n\": {}, \"seed\": {seed}}}", seed % 2 + 1);
                match kind {
                    // Plain normal-priority request.
                    0 => post(addr, "/generate", &body),
                    // Latency-sensitive: high priority, generous deadline.
                    1 => post_with(
                        addr,
                        "/generate",
                        "X-SJD-Priority: high\r\nX-SJD-Deadline-Ms: 30000\r\n",
                        &body,
                    ),
                    // Tight deadline: may be served or 504, never hang.
                    2 => post_with(addr, "/generate", "X-SJD-Deadline-Ms: 4\r\n", &body),
                    // Explicit normal-priority spelling.
                    _ => post_with(addr, "/generate", "X-SJD-Priority: normal\r\n", &body),
                }
            }));
        }
        // One client that submits and vanishes without reading — its slots
        // are cancelled and swept at a block boundary like any other chaos.
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(s, "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: 8\r\n\r\n{{\"n\":2}}")
            .unwrap();
        std::thread::sleep(Duration::from_millis(rng.next() % 8 + 1));
        drop(s);
        // Admission control holds mid-storm: depth (gauge and queue) ≤ cap.
        assert!(batcher.queued() <= cap, "queue depth {} > cap {cap}", batcher.queued());
        let depth = registry.gauge("sjd_queue_depth").get();
        assert!(depth <= cap as i64, "sjd_queue_depth {depth} > cap {cap}");
        std::thread::sleep(Duration::from_millis(rng.next() % 12 + 3));
    }
    let mut served = 0usize;
    for c in clients {
        let resp = c.join().expect("client thread must not hang or panic");
        let status_ok = resp.starts_with("HTTP/1.1 200")
            || resp.starts_with("HTTP/1.1 429")
            || resp.starts_with("HTTP/1.1 503")
            || resp.starts_with("HTTP/1.1 504");
        assert!(status_ok, "overload responses must be classified: {resp}");
        if resp.starts_with("HTTP/1.1 200") {
            served += 1;
        }
    }
    assert!(served > 0, "a capped queue must still serve traffic under overload");

    // Deterministic deadline enforcement on the queue: a slot submitted
    // already-expired is resolved 504-style by the next drain's purge, and
    // counted once.
    let expired_before = registry.counter("sjd_deadline_expired").get();
    let h = batcher
        .submit_slot_opts(
            424242,
            7,
            SubmitOpts { deadline: Some(Instant::now()), priority: Priority::High },
        )
        .expect("submit with expired deadline is accepted, then swept");
    match h.done.wait_timeout(Duration::from_secs(10)) {
        Some(Err(e)) => assert!(e.contains(DEADLINE_EXPIRED_MSG), "{e}"),
        Some(Ok(_)) => panic!("expired slot must resolve as an error"),
        None => panic!("expired slot must resolve, not hang"),
    }
    assert!(registry.counter("sjd_deadline_expired").get() > expired_before);

    stop_server(addr, stop, t);
    router.shutdown();
    assert_eq!(batcher.queued(), 0, "queues must drain on close");
    assert_eq!(registry.gauge("sjd_queue_depth").get(), 0);
    assert!(registry.counter("sjd_images_generated").get() > 0);
    assert_eq!(ledger.count_containing("_b8"), 0, "no unlowered bucket was touched");
}

// ---------------------------------------------------------------------------
// Fault tolerance: retry, quarantine, worker respawn, degraded health
// ---------------------------------------------------------------------------

/// τ = 0 decode options for one policy — retry/reroute/respawn bit-exactness
/// is a τ = 0 property (the Jacobi fixed point does not depend on how many
/// times the road to it was re-driven).
fn tau0(policy: &DecodePolicy) -> SampleOptions {
    let mut o = SampleOptions { policy: policy.clone(), ..Default::default() };
    o.jacobi.tau = 0.0;
    o
}

/// Ground truth for the bit-exactness gates: a bucket-1 solo decode of the
/// same seed over a healthy backend — no faults, no retries, no reroutes.
fn fault_free_reference(policy: &DecodePolicy, seed: u64) -> Vec<f32> {
    let be = MockServeBackend::new(&[1, 2, 4], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 1).expect("solo sampler");
    let z = sampler.sample_prior_slots(&[seed]);
    let out = sampler.decode_tokens(z, &tau0(policy)).expect("solo decode");
    sampler.unpatchify(&out.tokens).expect("solo unpatchify")[0].data().to_vec()
}

/// Single-worker RouterConfig over the mock backend with an explicit fault
/// policy.
fn fault_config(refill: bool, options: SampleOptions, fault: FaultPolicy) -> RouterConfig {
    RouterConfig {
        artifacts_dir: "unused-by-mock".into(),
        model: "mock".into(),
        buckets: Vec::new(),
        workers: 1,
        options,
        pipeline_depth: 1,
        stage_threads: 0,
        refill,
        tuner: None,
        warm_cap: 0,
        governor: None,
        fault,
        replicas: 1,
        devices: 1,
    }
}

/// Test-speed recovery knobs: microsecond backoffs so retries are cheap, and
/// a probe interval far beyond the test horizon so a tripped quarantine
/// cannot silently heal mid-assertion.
fn fast_fault() -> FaultPolicy {
    FaultPolicy {
        backoff_base: Duration::from_micros(200),
        backoff_cap: Duration::from_millis(2),
        probe_interval: Duration::from_secs(120),
        ..Default::default()
    }
}

/// Backend factory that hands `plan` to the first engine built and a healthy
/// backend to every later one. Fault-plan call indices are per-instance, so
/// without this a supervised respawn would replay one-shot panic/hang rules
/// from index 0 and burn the whole restart budget on the same injected
/// fault.
fn faulty_once_factory(
    ledger: &Arc<MockLedger>,
    plan: FaultPlan,
) -> impl Fn(usize) -> anyhow::Result<FaultyBackend> + Send + Clone + 'static {
    let ledger = ledger.clone();
    let built = Arc::new(AtomicUsize::new(0));
    move |_widx| {
        let p = if built.fetch_add(1, Ordering::SeqCst) == 0 {
            plan.clone()
        } else {
            FaultPlan::none()
        };
        Ok(FaultyBackend::new(MockServeBackend::new(&[1, 2, 4], Duration::ZERO, ledger.clone()), p))
    }
}

#[test]
fn transient_faults_are_retried_and_bit_exact() {
    // Three injected transient faults across both step roles; every decode
    // must succeed anyway and the retries must be invisible in the output
    // bits. Slots are submitted one at a time so every decode runs at bucket
    // 1 and the per-artifact call indices are deterministic.
    let policy = DecodePolicy::Selective { seq_blocks: 1 };
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(2));
    let ledger = MockLedger::new();
    let plan = FaultPlan::none()
        .fail_once("seqstep", 0, FaultClass::Transient)
        .fail_once("jstep", 0, FaultClass::Transient)
        .fail_once("jstep", 2, FaultClass::Transient);
    let router = Router::start_with(
        fault_config(false, tau0(&policy), fast_fault()),
        batcher.clone(),
        registry.clone(),
        faulty_once_factory(&ledger, plan.clone()),
    )
    .expect("faulty router");

    for seed in [21u64, 22, 23] {
        let h = batcher.submit_slot(seed, seed).expect("submit");
        let img = h
            .done
            .wait_timeout(Duration::from_secs(30))
            .expect("slot must resolve")
            .expect("retried decode must succeed");
        let want = fault_free_reference(&policy, seed);
        assert_eq!(img.data(), &want[..], "seed {seed}: retries must be invisible in the bits");
    }
    assert_eq!(plan.injected(), 3, "all three armed faults must fire");
    assert_eq!(registry.counter("sjd_backend_retries").get(), 3);
    assert_eq!(
        registry.counter("sjd_worker_errors").get(),
        0,
        "no request may observe a retried transient fault"
    );
    assert!(!router.fleet().degraded());
    router.shutdown();
    assert_eq!(batcher.queued(), 0);
}

#[test]
fn poisoned_artifact_is_quarantined_and_rerouted() {
    // Every fused-step call fails with a Poison fault. The first two
    // requests fail honestly (no retry — poison is deterministic); the
    // second trips the artifact breaker, and from then on
    // `effective_block_mode` reroutes fused blocks through plain Jacobi —
    // which at τ = 0 lands on the same fixed point, so the degraded decodes
    // are bit-identical to healthy fused ones.
    let policy = DecodePolicy::Fused { chunk: 4 };
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(2));
    let ledger = MockLedger::new();
    let plan = FaultPlan::none().fail_n("jstep_fuse", 0, usize::MAX, FaultClass::Poison);
    let fault = FaultPolicy { quarantine_after: 2, ..fast_fault() };
    let router = Router::start_with(
        fault_config(false, tau0(&policy), fault),
        batcher.clone(),
        registry.clone(),
        faulty_once_factory(&ledger, plan.clone()),
    )
    .expect("faulty router");

    for seed in [41u64, 42] {
        let h = batcher.submit_slot(seed, seed).expect("submit");
        let res = h.done.wait_timeout(Duration::from_secs(30)).expect("slot must resolve");
        assert!(res.is_err(), "poisoned decode before quarantine must fail, not corrupt");
    }
    assert_eq!(registry.counter("sjd_artifact_quarantined").get(), 1, "breaker trips once");

    // Post-quarantine: the fused artifact reads as absent, blocks fall back
    // to Jacobi, decodes succeed and stay bit-exact with the *fused* solo
    // reference on a healthy backend.
    for seed in [43u64, 44] {
        let h = batcher.submit_slot(seed, seed).expect("submit");
        let img = h
            .done
            .wait_timeout(Duration::from_secs(30))
            .expect("slot must resolve")
            .expect("rerouted decode must succeed");
        let want = fault_free_reference(&policy, seed);
        assert_eq!(img.data(), &want[..], "seed {seed}: degraded reroute must be bit-exact");
    }
    assert!(plan.injected() >= 2, "the poison rule must actually fire");
    // Poison never costs a worker: same incarnation the whole way through.
    assert_eq!(registry.counter("sjd_worker_restarts").get(), 0);
    assert!(!router.fleet().degraded());
    router.shutdown();
}

#[test]
fn worker_panic_resolves_slot_500_then_respawns() {
    // A mid-decode panic: the in-flight request must resolve exactly once
    // as an HTTP 500 (the slot-drop completion guard — never a hang), the
    // supervisor must respawn the worker with a fresh engine, and the
    // respawned fleet must serve bit-exact decodes with /healthz back at
    // 200.
    let addr = "127.0.0.1:8543";
    let policy = DecodePolicy::UniformJacobi;
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(2));
    let ledger = MockLedger::new();
    let plan = FaultPlan::none().panic_at("jstep", 1);
    let router = Router::start_with(
        fault_config(false, tau0(&policy), fast_fault()),
        batcher.clone(),
        registry.clone(),
        faulty_once_factory(&ledger, plan.clone()),
    )
    .expect("faulty router");
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { fleet: Some(router.fleet()), ..Default::default() },
    );
    let (stop, t) = start_server(server);

    let resp = post(addr, "/generate", "{\"n\": 1, \"seed\": 51}");
    assert!(resp.starts_with("HTTP/1.1 500"), "panicked decode must 500, not hang: {resp}");
    assert!(resp.contains(WORKER_FAILED_MSG), "completion guard message expected: {resp}");
    assert_eq!(plan.injected(), 1);

    // The respawned incarnation (healthy backend) keeps serving, bit-exact.
    let h = batcher.submit_slot(52, 52).expect("submit after respawn");
    let img = h
        .done
        .wait_timeout(Duration::from_secs(30))
        .expect("post-respawn slot must resolve")
        .expect("post-respawn decode must succeed");
    assert_eq!(img.data(), &fault_free_reference(&policy, 52)[..]);

    assert!(registry.counter("sjd_worker_panics").get() >= 1);
    assert!(registry.counter("sjd_worker_restarts").get() >= 1);
    assert!(!router.fleet().degraded(), "respawn must restore the fleet");
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "healthy fleet must be 200: {h}");

    stop_server(addr, stop, t);
    router.shutdown();
    assert_eq!(batcher.queued(), 0);
}

#[test]
fn exhausted_restart_budget_degrades_healthz() {
    // A permanently device-lost worker with a zero restart budget retires;
    // the fleet goes degraded and /healthz flips to 503 so orchestration
    // stops routing new traffic here.
    let addr = "127.0.0.1:8544";
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(2));
    let ledger = MockLedger::new();
    // Every backend call fails DeviceLost — permanent hardware death.
    let plan = FaultPlan::none().fail_n("", 0, usize::MAX, FaultClass::DeviceLost);
    let fault = FaultPolicy { worker_restarts: 0, ..fast_fault() };
    let router = Router::start_with(
        fault_config(false, tau0(&DecodePolicy::UniformJacobi), fault),
        batcher.clone(),
        registry.clone(),
        {
            let ledger = ledger.clone();
            move |_| {
                Ok(FaultyBackend::new(
                    MockServeBackend::new(&[1, 2, 4], Duration::ZERO, ledger.clone()),
                    plan.clone(),
                ))
            }
        },
    )
    .expect("dying router");
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { fleet: Some(router.fleet()), ..Default::default() },
    );
    let (stop, t) = start_server(server);

    // The request that kills the worker still resolves — exactly once, as
    // an error — before the worker exits.
    let resp = post(addr, "/generate", "{\"n\": 1, \"seed\": 61}");
    assert!(resp.starts_with("HTTP/1.1 500"), "device-lost decode must 500: {resp}");

    let mut h = String::new();
    for _ in 0..150 {
        h = get(addr, "/healthz");
        if h.starts_with("HTTP/1.1 503") {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(h.starts_with("HTTP/1.1 503"), "degraded fleet must answer non-200: {h}");
    assert!(h.contains("degraded: 0/1"), "degradation detail expected: {h}");
    assert!(router.fleet().degraded());
    assert_eq!(registry.counter("sjd_worker_restarts").get(), 0, "budget was zero");

    stop_server(addr, stop, t);
    router.shutdown();
    assert_eq!(batcher.queued(), 0);
}

#[test]
fn fault_chaos_soak_classified_statuses_and_bit_exact_recovery() {
    // Chaos soak over the full continuous + elastic stack with a seeded
    // random transient-fault plan shared by every pipeline stage.
    // Invariants: every request resolves exactly once with a classified
    // status (200/429/500/503/504 — never a hang), faults genuinely fire
    // and are retried, decodes that survive the chaos are bit-identical to
    // fault-free solo references (τ = 0, fidelity budget 0 keeps the
    // governor ladder bit-exact), and the queues drain on shutdown.
    let addr = "127.0.0.1:8545";
    let policy = DecodePolicy::UniformJacobi;
    let registry = Registry::new();
    let cap = 8usize;
    let batcher = Batcher::with_cap(4, Duration::from_millis(5), cap);
    batcher.bind_metrics(&registry);
    let ledger = MockLedger::new();
    // Transient-only plans are safe to replay on every stage backend — the
    // retry layer absorbs each injection. The extra index-0 rule guarantees
    // the plan fires on the very first step call.
    let plan = FaultPlan::random(0xFA57, 0.05, 64).fail_once("jstep", 0, FaultClass::Transient);
    let mut cfg = fault_config(true, tau0(&policy), fast_fault());
    cfg.governor = Some(Arc::new(OverloadGovernor::new(
        4,
        GovernorConfig { queue_high: 4.0, fidelity_budget: 0.0, s_max: 4, ..Default::default() },
        &registry,
    )));
    let router = Router::start_with(cfg, batcher.clone(), registry.clone(), {
        let ledger = ledger.clone();
        let plan = plan.clone();
        move |_| {
            Ok(FaultyBackend::new(
                MockServeBackend::new(&[1, 2, 4], Duration::from_micros(200), ledger.clone()),
                plan.clone(),
            ))
        }
    })
    .expect("chaos router");
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads: 12, fleet: Some(router.fleet()), ..Default::default() },
    );
    let (stop, t) = start_server(server);

    let mut rng = ChaosRng(0xFA_057);
    let mut clients = Vec::new();
    for _burst in 0..4 {
        for _ in 0..(rng.next() % 4 + 2) {
            let seed = rng.next();
            let kind = rng.next() % 3;
            clients.push(std::thread::spawn(move || {
                let body = format!("{{\"n\": {}, \"seed\": {seed}}}", seed % 2 + 1);
                match kind {
                    0 => post(addr, "/generate", &body),
                    1 => post_with(
                        addr,
                        "/generate",
                        "X-SJD-Priority: high\r\nX-SJD-Deadline-Ms: 30000\r\n",
                        &body,
                    ),
                    // Tight deadline under injected faults: served or 504,
                    // never a hang, never silent corruption.
                    _ => post_with(addr, "/generate", "X-SJD-Deadline-Ms: 5\r\n", &body),
                }
            }));
        }
        std::thread::sleep(Duration::from_millis(rng.next() % 12 + 3));
    }
    let mut served = 0usize;
    for c in clients {
        let resp = c.join().expect("client thread must not hang or panic");
        let classified = ["200", "429", "500", "503", "504"]
            .iter()
            .any(|s| resp.starts_with(&format!("HTTP/1.1 {s}")));
        assert!(classified, "chaos responses must be classified: {resp}");
        if resp.starts_with("HTTP/1.1 200") {
            served += 1;
        }
    }
    assert!(served > 0, "the fleet must keep serving under injected faults");

    // Recovery bit-exactness: decodes that ran *through* retried transient
    // faults must equal their fault-free solo references.
    let seeds = [71u64, 72, 73, 74];
    let handles: Vec<_> =
        seeds.iter().map(|&s| batcher.submit_slot(s, s).expect("submit")).collect();
    for (i, h) in handles.iter().enumerate() {
        match h.done.wait_timeout(Duration::from_secs(30)).expect("slot must resolve") {
            Ok(img) => {
                let want = fault_free_reference(&policy, seeds[i]);
                assert_eq!(
                    img.data(),
                    &want[..],
                    "seed {}: recovery must be bit-exact",
                    seeds[i]
                );
            }
            // Retry-budget exhaustion inside a dense injected burst is an
            // honest error — allowed; silent corruption is not.
            Err(e) => assert!(!e.is_empty()),
        }
    }

    assert!(plan.injected() > 0, "the chaos plan must actually fire");
    assert!(
        registry.counter("sjd_backend_retries").get() >= 1,
        "transient faults must be retried, not surfaced"
    );
    stop_server(addr, stop, t);
    router.shutdown();
    assert_eq!(batcher.queued(), 0, "queues must drain on shutdown");
    assert_eq!(registry.gauge("sjd_queue_depth").get(), 0);
}

// ---------------------------------------------------------------------------
// Artifact-driven end-to-end tests (skip without artifacts)
// ---------------------------------------------------------------------------

#[test]
fn serve_generate_and_metrics_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let addr = "127.0.0.1:8497";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(5));
    let router = Router::start(
        RouterConfig {
            artifacts_dir: dir,
            model: "tf10".into(),
            buckets: vec![1],
            workers: 1,
            options: SampleOptions::default(),
            pipeline_depth: 1,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
    )
    .expect("router");

    let server = Server::new(addr, batcher, registry.clone());
    let (stop, t) = start_server(server);

    // Health.
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");

    // Generate 2 images.
    let resp = post(addr, "/generate", r#"{"n": 2, "seed": 5}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("json body");
    let imgs = v.req_arr("images_png_b64").unwrap();
    assert_eq!(imgs.len(), 2);
    // Base64 payloads decode to PNG magic.
    let b64 = imgs[0].as_str().unwrap();
    assert!(b64.len() > 100);
    assert!(b64.starts_with("iVBOR"), "not a PNG payload: {}", &b64[..16]);

    // Determinism: same seed → identical payloads.
    let resp2 = post(addr, "/generate", r#"{"n": 2, "seed": 5}"#);
    let body2 = resp2.split("\r\n\r\n").nth(1).unwrap();
    let v2 = sjd::jsonx::parse(body2).unwrap();
    assert_eq!(
        v.req_arr("images_png_b64").unwrap()[0],
        v2.req_arr("images_png_b64").unwrap()[0],
        "same seed must reproduce the same image"
    );

    // Metrics advanced.
    let m = get(addr, "/metrics");
    assert!(m.contains("sjd_images_generated"), "{m}");
    assert!(m.contains("sjd_http_requests"));
    assert!(m.contains("sjd_padded_slots"));

    // Bad request handled.
    let bad = post(addr, "/generate", "{invalid json");
    assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");
    let nf = get(addr, "/nope");
    assert!(nf.starts_with("HTTP/1.1 404"));

    // Shutdown.
    stop_server(addr, stop, t);
    router.shutdown();
}

#[test]
fn server_answers_malformed_requests_without_backend() {
    // The HTTP front end's defensive paths need no artifacts: header-cap
    // violations and bad JSON must get a 400 response (not a silent
    // connection reset), with a body that is itself valid JSON.
    let addr = "127.0.0.1:8499";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(5));
    let server = Server::new(addr, batcher, registry);
    let (stop, t) = start_server(server);

    // Header flood → answered 400.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut req = String::from("GET /healthz HTTP/1.1\r\n");
    for i in 0..200 {
        req.push_str(&format!("X-H{i}: v\r\n"));
    }
    req.push_str("\r\n");
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    assert!(out.starts_with("HTTP/1.1 400"), "{out}");

    // Bad JSON body → 400, and the error body parses as JSON.
    let resp = post(addr, "/generate", "{invalid json");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    assert!(sjd::jsonx::parse(body).is_ok(), "error body must be valid JSON: {body}");

    // Well-formed requests still served.
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");

    stop_server(addr, stop, t);
}

#[test]
fn batcher_groups_concurrent_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let registry = Registry::new();
    // Batch of 8 with generous wait: 8 concurrent submissions form 1 batch.
    let batcher = Batcher::new(8, Duration::from_millis(500));
    let router = Router::start(
        RouterConfig {
            artifacts_dir: dir,
            model: "tf10".into(),
            buckets: vec![8],
            workers: 1,
            options: SampleOptions::default(),
            pipeline_depth: 1,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
    )
    .expect("router");

    let handles: Vec<_> = (0..8).map(|i| batcher.submit(i, 9).unwrap()).collect();
    for h in handles {
        let img = h.wait().expect("decoded image");
        assert_eq!(img.ndim(), 3);
    }
    // One full batch, decoded via the 8-bucket with no padding.
    let snap = registry.histogram("sjd_batch_fill").snapshot();
    assert_eq!(snap.count, 1);
    assert!(snap.max == 8, "batch fill {}", snap.max);
    assert_eq!(registry.counter("sjd_padded_slots").get(), 0);
    router.shutdown();
}
