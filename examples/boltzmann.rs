//! Boltzmann-distribution sampling with MAF (paper §E.3, Table A5):
//! sequential vs all-layer Jacobi decoding on the 8×8 Ising model at T = 3.0,
//! with physics observables validated against a Metropolis MCMC reference.
//!
//! ```bash
//! cargo run --release --example boltzmann [artifacts]
//! ```

use anyhow::Result;
use sjd::coordinator::maf::{MafMode, MafSampler};
use sjd::physics::IsingModel;
use sjd::runtime::Engine;
use sjd::tensor::Pcg64;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let engine = Engine::new(&artifacts)?;
    let sampler = MafSampler::new(&engine, "maf_ising", 256)?;
    let model = IsingModel::new(8, 3.0);
    println!(
        "maf_ising: {} layers over {} dims (8×8 lattice, T = 3.0)",
        sampler.meta.blocks, sampler.meta.seq_len
    );

    // Ground truth #1: MCMC reference exported at build time.
    let ref_meta = engine.manifest().datasets.get("ising_ref");
    if let Some(m) = ref_meta {
        let e = m.extra.get("energy_per_site").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let mag = m.extra.get("abs_magnetization").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        println!("MCMC reference (build-time): E/site {e:.4}, |M| {mag:.4}");
    }
    // Ground truth #2: fresh Metropolis run in rust.
    let mut rng = Pcg64::seed(5);
    let mc = model.metropolis_stats(64, 150, &mut rng);
    println!(
        "MCMC reference (rust):       E/site {:.4}, |M| {:.4}",
        mc.energy_per_site, mc.abs_magnetization
    );

    let cfg = sjd::coordinator::maf::maf_config(0.05);
    let batches = 4;

    for (mode, label) in [(MafMode::Sequential, "Sequential"), (MafMode::Jacobi, "Ours (Jacobi)")] {
        let mut rng = Pcg64::seed(77);
        let mut wall = 0.0;
        let mut evals = 0usize;
        let mut all = Vec::new();
        for _ in 0..batches {
            let out = sampler.sample(mode, &cfg, &mut rng)?;
            wall += out.total_wall.as_secs_f64();
            evals += out.made_evals();
            all.extend_from_slice(out.samples.as_f32()?);
        }
        let stats = model.stats_from_continuous(&all);
        println!(
            "{label:>14}: {wall:.2}s ({evals} MADE evals) | E/site {:.4} | |M| {:.4}",
            stats.energy_per_site, stats.abs_magnetization
        );
    }
    Ok(())
}
