//! The full sampling pipeline: prior noise → per-block decode (sequential or
//! Jacobi per the policy) → unpatchify → images.
//!
//! ## Artifact ABI (must match `python/compile/aot.py`)
//!
//! All per-block artifacts operate in **AR domain** — the token order the
//! block's causal transformer sees. The flow composition
//! `h_{k+1} = A_k(P_k h_k)` (encode) / `h_k = P_k(A_k^{-1}(h_{k+1}))`
//! (decode) applies the inter-block permutation `P_k` (token reversal for
//! odd `k`) **in rust**, keeping the artifacts uniform:
//!
//! * `{m}_block_fwd_b{B}`   : `(k, u[B,L,D]) → v[B,L,D]` — `v = A_k(u)`
//! * `{m}_block_jstep_b{B}` : `(k, z_t[B,L,D], y[B,L,D], o) → (z', resid[B])`
//!   — one parallel Jacobi update of `A_k(z) = y`, with the `o`-nearest
//!   dependency mask of eq 6 (`o = 0` ⇒ exact update).
//! * `{m}_block_jstep_win_b{B}` : `(k, z_t[B,L,D], y[B,L,D], off, len) →
//!   (z', resid[B])` — the windowed GS-Jacobi inner step: positions outside
//!   `[off, off+len)` are copied through from `z_t` and the residual covers
//!   the window only — always the exact (`o = 0`) update. **Optional**:
//!   probed via `Backend::has_artifact`; when absent, or when `mask_o > 0`
//!   (the masked eq-6 decode has a different fixed point the windowed
//!   artifact cannot express), GS-Jacobi block modes fall back to
//!   full-sequence Jacobi.
//! * `{m}_block_jstep_fuse_b{B}` : `(k, z_t[B,L,D], y[B,L,D], steps) →
//!   (z', resid_hist[S,B])` — up to `steps` fused Jacobi updates in one
//!   dispatch, residual history row per update (−1 sentinel on rows past
//!   `steps`; `steps` clamps to the lowered `S`). Drives the chunked decode
//!   of `jacobi_decode_block_fused_v`: one `[S,B]` sync per chunk replaces
//!   per-iteration `[B]` syncs. Exact (`o = 0`) update only. **Optional**
//!   with the same fallback rule as the windowed step: absent artifact or
//!   `mask_o > 0` degrades [`BlockDecode::Fused`] to plain Jacobi.
//! * `{m}_block_jstep_win_fuse_b{B}` : `(k, z_t, y, steps, off, len) →
//!   (z', resid_hist[S,B])` — the fused windowed step
//!   (`gs_jacobi_decode_block_fused_v`). **Optional**:
//!   [`BlockDecode::GsFused`] degrades to per-iteration GS-Jacobi (which
//!   itself degrades to plain Jacobi if the windowed step is absent too).
//! * `{m}_block_seqstep_b{B}`: `(k, u_prev[B,D], v_tok[B,D], pos,
//!   kv_k[NL,B,L,Dm], kv_v[NL,B,L,Dm]) → (u_pos[B,D], kv_k', kv_v')`
//!   — one sequential token with KV cache.
//! * `{m}_fwd_b{B}`         : `(x[B,H,W,C]) → (z[B,L,D], logdet[B])` —
//!   full encode (python applies its own permutations; cross-checked against
//!   the rust composition in integration tests).
//! * `{m}_reverse_b{B}`     : `(t[B,L,D]) → t_rev[B,L,D]` — **optional**
//!   device-side token reversal (the gather for `P_k`). Probed via
//!   `Backend::has_artifact`; absent ⇒ the host fallback below.
//! * `{m}_slot_gather_b{B}` : `(t[B,L,D], idx[B]i32) → t[idx][B,L,D]` —
//!   **optional** device-side batch-row gather for continuous batching's
//!   slot remap (compact cancelled slots, straggler merge, bucket
//!   migration). Same untupled single-output pattern as the reversal
//!   gather; absent ⇒ host row permute fallback.
//!
//! ## Value lifecycle (device residency)
//!
//! The decode hot paths run on the value-based backend API
//! (`crate::runtime::Backend::call_v`); see the `runtime` module docs for the
//! full rules. What lives where during `decode_tokens`:
//!
//! * The latent `z` is uploaded **once** at the top; block outputs chain
//!   device→device across all K blocks; final tokens sync to host **once** at
//!   the end.
//! * Jacobi blocks keep the iterate and `y` on device; per iteration only
//!   the `[B]` residual crosses for the τ test (`jacobi_decode_block_v`).
//!   GS-Jacobi blocks inherit the same contract (`gs_jacobi_decode_block_v`).
//!   Fused blocks sync one `[S,B]` residual history per *chunk* instead —
//!   `⌈iterations/S⌉` syncs per block (`jacobi_decode_block_fused_v`).
//! * Scalar loop constants (`k`, `mask_o`, window offsets/lengths, chunk
//!   sizes) are pinned through the pool's once-per-value cache
//!   (`BufferPool::device_scalar_i32`) — repeated blocks, windows and
//!   requests re-use the same device scalars instead of re-uploading.
//! * Sequential blocks keep `u_prev` and both KV caches (the largest tensors
//!   in the system) device-resident across all L token steps; the initial
//!   zero caches come from the pool's one-time-upload cache. Per token only
//!   the `[B,D]` input slice goes up and the `[B,D]` output token comes down
//!   (needed to assemble `u` — there is no device-side scatter artifact).
//! * **Forced sync points** (documented, deliberate): (1) a sequential block
//!   whose input arrived device-resident syncs it once up front to gather
//!   per-token slices; (2) odd-`k` token reversal when the model lacks the
//!   `{m}_reverse_b{B}` artifact — fetch, permute on host, re-upload on next
//!   use.
//! * Device handles are `Rc`-based and thread-pinned to the engine that
//!   minted them — a `Sampler` and its values stay on one worker thread;
//!   everything returned to other threads (`SampleOutput::tokens`, images)
//!   is host data.

use super::jacobi::{
    gs_jacobi_decode_block_fused_v, gs_jacobi_decode_block_v, jacobi_decode_block_fused_v,
    jacobi_decode_block_v_init, GsJacobiStats, InitStrategy, JacobiConfig, JacobiStats,
};
use super::policy::{BlockDecode, DecodePolicy, DEFAULT_FUSE_CHUNK};
use super::state::BufferPool;
use crate::runtime::{Backend, HostTensor, ModelMeta, Value};
use crate::tensor::{Pcg64, Tensor};
use anyhow::{bail, Context, Result};
use std::time::{Duration, Instant};

/// Options for one sampling run.
#[derive(Clone, Debug)]
pub struct SampleOptions {
    pub policy: DecodePolicy,
    pub jacobi: JacobiConfig,
    /// eq-6 dependency mask offset applied to Jacobi blocks (0 = exact).
    pub mask_o: usize,
    /// Use the scan-fused sequential artifact (`block_seqfull`) instead of
    /// per-token `block_seqstep` calls — the §Perf "XLA-fused sequential"
    /// ablation, a stronger-than-paper baseline.
    pub fused_sequential: bool,
    pub seed: u64,
}

impl Default for SampleOptions {
    fn default() -> Self {
        SampleOptions {
            policy: DecodePolicy::Selective { seq_blocks: 1 },
            jacobi: JacobiConfig::default(),
            mask_o: 0,
            fused_sequential: false,
            seed: 0,
        }
    }
}

/// Per-block trace of one sampling run.
#[derive(Clone, Debug)]
pub struct BlockTrace {
    /// Block index `k` (flow order).
    pub block: usize,
    /// Decode position (0 = first block applied to noise).
    pub position: usize,
    pub used_jacobi: bool,
    /// Sequential steps, Jacobi iterations, or GS-Jacobi jstep_win calls.
    pub steps: usize,
    /// Positions written while decoding this block: `L` for sequential,
    /// `iterations × L` for full-sequence Jacobi, Σ `iterations × len` per
    /// window for GS-Jacobi — the work metric `benches/gs_windows.rs`
    /// compares across policies.
    pub position_updates: usize,
    /// Blocking host syncs this block's decode performed: `L` per-token
    /// fetches for sequential (1 for the scan-fused ablation), one `[B]`
    /// residual per iteration for per-iteration Jacobi/GS, one `[S,B]`
    /// history per chunk (`⌈iterations/S⌉`) for the fused drivers — the
    /// latency cost the fused path exists to shrink; exported per block as
    /// the `sjd_host_syncs` histogram by the serving router.
    pub host_syncs: usize,
    pub wall: Duration,
    pub jacobi: Option<JacobiStats>,
    /// Present when this block decoded via windowed GS-Jacobi.
    pub gs: Option<GsJacobiStats>,
    /// The init strategy that governed this block's z⁰ (the requested
    /// `--init` provider, or Zeros) — recorded so the tuner can separate
    /// baseline decodes from provider decodes when judging payoff.
    pub init: InitStrategy,
    /// A speculative provider actually supplied this block's z⁰ (warm-cache
    /// hit, projection applied, draft state reused) — exported as the
    /// `sjd_spec_init_hits` counter by the serving router.
    pub spec_hit: bool,
    /// Position-updates spent *producing* this block's speculation (its
    /// share of the draft pass, or the one projected update) — added on top
    /// of [`BlockTrace::position_updates`] when judging whether the
    /// provider paid, so speculation that merely moves work around cannot
    /// masquerade as savings.
    pub spec_cost_updates: usize,
}

/// Result of one sampling run.
#[derive(Clone, Debug)]
pub struct SampleOutput {
    /// Final tokens (B, L, D) in flow domain (h_0).
    pub tokens: HostTensor,
    pub traces: Vec<BlockTrace>,
    pub total_wall: Duration,
    /// Wall time outside block decodes (noise gen, permutation, unpatchify) —
    /// the paper's Table A4 "Other" row.
    pub other_wall: Duration,
}

impl SampleOutput {
    pub fn total_jacobi_iters(&self) -> usize {
        self.traces.iter().filter(|t| t.used_jacobi).map(|t| t.steps).sum()
    }

    /// Total positions written across all block decodes (see
    /// [`BlockTrace::position_updates`]).
    pub fn total_position_updates(&self) -> usize {
        self.traces.iter().map(|t| t.position_updates).sum()
    }

    /// Total blocking host syncs across all block decodes (see
    /// [`BlockTrace::host_syncs`]) — what `benches/jstep_fusion.rs` compares
    /// between the per-iteration and fused-chunked paths.
    pub fn total_host_syncs(&self) -> usize {
        self.traces.iter().map(|t| t.host_syncs).sum()
    }

    /// Total position updates **including** speculation cost — the honest
    /// cross-provider comparison metric (`benches/spec_init.rs` gates on
    /// this, not on the refine cost alone).
    pub fn total_updates_with_spec(&self) -> usize {
        self.traces.iter().map(|t| t.position_updates + t.spec_cost_updates).sum()
    }

    /// Blocks whose z⁰ came from a speculative provider (see
    /// [`BlockTrace::spec_hit`]).
    pub fn spec_hits(&self) -> usize {
        self.traces.iter().filter(|t| t.spec_hit).count()
    }
}

/// The bucket-selection law: the smallest bucket covering `n`, falling back
/// to the largest for an oversized batch; `None` only on an empty bucket
/// set. [`SamplerSet::select`] and the pipelined router feeder both route
/// through this single definition, so padding accounting, tuner bucket keys
/// and the stage samplers can never disagree on which bucket a batch uses.
pub fn covering_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n).or_else(|| buckets.last().copied())
}

/// A set of [`Sampler`]s for one model, one per lowered batch bucket,
/// ordered ascending. Serving workers route each formed batch to the
/// smallest bucket that covers it, so an `n=1` request is decoded by the
/// `b1` artifacts instead of being padded up to the largest lowered batch
/// (see `coordinator::router` for the padding accounting).
pub struct SamplerSet<'e, B: Backend> {
    samplers: Vec<Sampler<'e, B>>,
    buckets: Vec<usize>,
}

impl<'e, B: Backend> SamplerSet<'e, B> {
    /// Build one sampler per bucket. An empty `buckets` means every batch
    /// size the model's artifacts were lowered for (`ModelMeta::batch_sizes`);
    /// an explicit bucket that was never lowered fails fast here rather than
    /// at decode time.
    pub fn new(engine: &'e B, model: &str, buckets: &[usize]) -> Result<Self> {
        let mut want: Vec<usize> = if buckets.is_empty() {
            engine.model_meta(model)?.batch_sizes
        } else {
            buckets.to_vec()
        };
        want.sort_unstable();
        want.dedup();
        if want.is_empty() {
            bail!("model '{model}' has no lowered batch sizes to serve");
        }
        let samplers = want
            .iter()
            .map(|&b| Sampler::new(engine, model, b))
            .collect::<Result<Vec<_>>>()?;
        Ok(SamplerSet { samplers, buckets: want })
    }

    /// Available bucket sizes, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    /// The largest bucket — what the batcher should form batches up to.
    pub fn max_bucket(&self) -> usize {
        self.samplers.last().expect("non-empty set").batch
    }

    /// Model metadata (shared by every bucket's sampler).
    pub fn meta(&self) -> &ModelMeta {
        &self.samplers[0].meta
    }

    /// Apply a warm-start cache bound to every bucket's sampler (see
    /// [`Sampler::set_warm_cap`]); `0` leaves the built-in default.
    pub fn set_warm_cap(&self, cap: usize) {
        if cap == 0 {
            return;
        }
        for s in &self.samplers {
            s.set_warm_cap(cap);
        }
    }

    /// The sampler for the smallest bucket with `batch >= n` — falling back
    /// to the largest bucket for an oversized batch (the batcher caps batch
    /// size at [`Self::max_bucket`], so that fallback only triggers on a
    /// misconfigured batcher; decode then drops the overflow images).
    /// Selection goes through [`covering_bucket`], the shared law.
    pub fn select(&self, n: usize) -> &Sampler<'e, B> {
        let bucket = covering_bucket(&self.buckets, n).expect("non-empty set");
        self.samplers
            .iter()
            .find(|s| s.batch == bucket)
            .expect("bucket comes from this set")
    }
}

/// Model sampler bound to an execution backend + a lowered batch size.
pub struct Sampler<'e, B: Backend> {
    engine: &'e B,
    pub meta: ModelMeta,
    pub batch: usize,
    art_fwd: String,
    art_block_fwd: String,
    art_jstep: String,
    art_jstep_win: String,
    art_jstep_fuse: String,
    art_jstep_win_fuse: String,
    art_seqstep: String,
    art_seqfull: String,
    art_reverse: String,
    art_init_proj: String,
    art_slot_gather: String,
    pool: BufferPool,
}

impl<'e, B: Backend> Sampler<'e, B> {
    pub fn new(engine: &'e B, model: &str, batch: usize) -> Result<Self> {
        let meta = engine.model_meta(model)?;
        if !meta.batch_sizes.contains(&batch) {
            bail!(
                "model '{model}' has no artifacts for batch {batch} (available: {:?})",
                meta.batch_sizes
            );
        }
        Ok(Sampler {
            engine,
            meta,
            batch,
            art_fwd: format!("{model}_fwd_b{batch}"),
            art_block_fwd: format!("{model}_block_fwd_b{batch}"),
            art_jstep: format!("{model}_block_jstep_b{batch}"),
            art_jstep_win: format!("{model}_block_jstep_win_b{batch}"),
            art_jstep_fuse: format!("{model}_block_jstep_fuse_b{batch}"),
            art_jstep_win_fuse: format!("{model}_block_jstep_win_fuse_b{batch}"),
            art_seqstep: format!("{model}_block_seqstep_b{batch}"),
            art_seqfull: format!("{model}_block_seqfull_b{batch}"),
            art_reverse: format!("{model}_reverse_b{batch}"),
            art_init_proj: format!("{model}_init_proj_b{batch}"),
            art_slot_gather: format!("{model}_slot_gather_b{batch}"),
            pool: BufferPool::new(),
        })
    }

    pub fn engine(&self) -> &B {
        self.engine
    }

    pub fn jstep_artifact(&self) -> &str {
        &self.art_jstep
    }

    pub fn jstep_win_artifact(&self) -> &str {
        &self.art_jstep_win
    }

    /// Whether the model ships the windowed GS-Jacobi step artifact (older
    /// artifact dirs predate it; GS block modes then fall back to
    /// full-sequence Jacobi).
    pub fn has_gs_artifact(&self) -> bool {
        self.engine.has_artifact(&self.art_jstep_win)
    }

    pub fn jstep_fuse_artifact(&self) -> &str {
        &self.art_jstep_fuse
    }

    /// Whether the model ships the fused multi-step Jacobi artifact;
    /// [`BlockDecode::Fused`] falls back to plain per-iteration Jacobi
    /// without it.
    pub fn has_fuse_artifact(&self) -> bool {
        self.engine.has_artifact(&self.art_jstep_fuse)
    }

    /// Whether the model ships the fused multi-step *windowed* artifact;
    /// [`BlockDecode::GsFused`] falls back to per-iteration GS-Jacobi
    /// without it.
    pub fn has_gs_fuse_artifact(&self) -> bool {
        self.engine.has_artifact(&self.art_jstep_win_fuse)
    }

    pub fn init_proj_artifact(&self) -> &str {
        &self.art_init_proj
    }

    /// Whether the model ships the speculative-init projection artifact
    /// (`{m}_init_proj_b{B}`); [`InitStrategy::Proj`] falls back to the
    /// Zeros init without it.
    pub fn has_init_proj_artifact(&self) -> bool {
        self.engine.has_artifact(&self.art_init_proj)
    }

    /// Bound the warm-start z⁰ cache (the `N` of `--init warm:N`).
    pub fn set_warm_cap(&self, cap: usize) {
        self.pool.set_warm_cap(cap);
    }

    /// Device-side speculative z⁰ projection for block `k` of `A_k(z) = y`:
    /// one `{m}_init_proj_b{B}` call — a cheap truncated-conditioner update
    /// evaluated at `z = y`. Input and output both stay device-resident
    /// (the artifact is lowered `untupled`, so its result is a chainable
    /// device leaf); a host `y` is uploaded once and the uploaded handle is
    /// what the caller should keep feeding the decode.
    pub fn project_init_v(&self, k: usize, y: &Value) -> Result<Value> {
        let k_scalar =
            self.pool.device_scalar_i32(k as i32, |t| self.engine.to_device(t))?;
        let outs = self
            .engine
            .call_v(&self.art_init_proj, &[k_scalar, y.clone()])
            .with_context(|| format!("init_proj block {k}"))?;
        outs.into_iter().next().context("init_proj output")
    }

    /// Draw the prior `z_K ~ N(0, I)` in token space.
    pub fn sample_prior(&self, rng: &mut Pcg64) -> HostTensor {
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        let t = Tensor::randn(&[b, l, d], rng);
        HostTensor::f32(&[b, l, d], t.into_data())
    }

    /// Draw the prior with **one RNG stream per slot**: row `i` comes from
    /// `Pcg64::seed_stream(seeds[i], 1)` drawing a `[1, L, D]` block — the
    /// exact draw sequence a solo `b=1` decode of that request performs, so
    /// a slot's noise (and hence its τ=0 output, Prop 3.2) is a pure
    /// function of its own seed, independent of batch position, padding, or
    /// which batches it later rides through under refill/migration. Rows
    /// past `seeds.len()` (padding up to the bucket) are zeros — their
    /// output is discarded, and zeros keep the pad rows' Jacobi residuals
    /// trivially convergent.
    ///
    /// Panics if `seeds.len() > self.batch` (the caller routes through
    /// [`covering_bucket`], which guarantees coverage).
    pub fn sample_prior_slots(&self, seeds: &[u64]) -> HostTensor {
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        assert!(seeds.len() <= b, "{} slot seeds exceed bucket {b}", seeds.len());
        let mut data = vec![0.0f32; b * l * d];
        for (i, &seed) in seeds.iter().enumerate() {
            let mut rng = Pcg64::seed_stream(seed, 1);
            let row = Tensor::randn(&[1, l, d], &mut rng);
            data[i * l * d..(i + 1) * l * d].copy_from_slice(row.data());
        }
        HostTensor::f32(&[b, l, d], data)
    }

    /// Token reversal along the sequence axis — the inter-block permutation.
    pub fn reverse_tokens(&self, t: &HostTensor) -> Result<HostTensor> {
        let shape = t.shape().to_vec();
        if shape.len() != 3 {
            bail!("reverse_tokens expects (B, L, D), got {shape:?}");
        }
        let (b, l, d) = (shape[0], shape[1], shape[2]);
        let src = t.as_f32()?;
        let mut out = vec![0.0f32; src.len()];
        for bi in 0..b {
            for li in 0..l {
                let s = (bi * l + li) * d;
                let dst = (bi * l + (l - 1 - li)) * d;
                out[dst..dst + d].copy_from_slice(&src[s..s + d]);
            }
        }
        Ok(HostTensor::f32(&shape, out))
    }

    /// Token reversal on a [`Value`]: a device-resident input uses the
    /// model's device-side gather artifact when available (no host traffic);
    /// otherwise — host input, or no such artifact — the documented host
    /// path (fetch if needed → permute → the next call re-uploads).
    pub fn reverse_tokens_v(&self, t: &Value) -> Result<Value> {
        if t.is_device() && self.engine.has_artifact(&self.art_reverse) {
            let outs = self.engine.call_v(&self.art_reverse, &[t.clone()])?;
            return outs.into_iter().next().context("reverse output");
        }
        let host = match t {
            Value::Host(h) => self.reverse_tokens(h)?,
            Value::Device(_) => self.reverse_tokens(&self.engine.to_host(t.clone())?)?,
        };
        Ok(Value::Host(host))
    }

    /// Whether the model ships the slot-remap gather artifact
    /// (`{m}_slot_gather_b{B}`); without it [`Sampler::gather_slots_v`]
    /// falls back to a host row permute.
    pub fn has_slot_gather_artifact(&self) -> bool {
        self.engine.has_artifact(&self.art_slot_gather)
    }

    /// Slot remap: reorder/compact the batch rows of `t` ([B, L, D]) so row
    /// `i` of the output is row `idx[i]` of the input — the continuous
    /// batching handoff's gather (drop cancelled slots, close holes before a
    /// bucket migration or straggler merge). Uses the device-side
    /// `{m}_slot_gather_b{B}` artifact when lowered (same untupled pattern
    /// as the reversal gather: the result is a chainable device leaf);
    /// otherwise the documented host path. `idx` entries may repeat (pad
    /// rows duplicate a live row) and must be `< B`.
    pub fn gather_slots_v(&self, t: &Value, idx: &[i32]) -> Result<Value> {
        if idx.len() != self.batch {
            bail!("slot gather wants {} indices for bucket {}", idx.len(), self.batch);
        }
        if self.engine.has_artifact(&self.art_slot_gather) {
            let idx_t = HostTensor::i32(&[self.batch], idx.to_vec());
            let outs = self.engine.call_v(&self.art_slot_gather, &[t.clone(), Value::Host(idx_t)])?;
            return outs.into_iter().next().context("slot_gather output");
        }
        let host = match t {
            Value::Host(h) => h.clone(),
            Value::Device(_) => self.engine.to_host(t.clone())?,
        };
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        let src = host.as_f32()?;
        let mut out = vec![0.0f32; b * l * d];
        for (i, &s) in idx.iter().enumerate() {
            let s = s as usize;
            if s >= b {
                bail!("slot gather index {s} out of range for bucket {b}");
            }
            out[i * l * d..(i + 1) * l * d].copy_from_slice(&src[s * l * d..(s + 1) * l * d]);
        }
        Ok(Value::Host(HostTensor::f32(&[b, l, d], out)))
    }

    /// Decode one block sequentially with the KV cache (paper's baseline
    /// path), keeping `u_prev` and both KV caches device-resident across all
    /// L steps. Returns `u = A_k^{-1}(v)` and the number of steps (= L).
    ///
    /// The per-token gather `v[:, pos, :]` is host-side, so a device-resident
    /// `v` costs one up-front sync; after that only `[B, D]` slices (plus the
    /// `pos` scalar) cross the boundary per step, and the `[NL, B, L, Dm]`
    /// caches never do.
    pub fn sequential_decode_block_v(&self, k: usize, v: &Value) -> Result<(Value, usize)> {
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        let (nl, dm) = (self.meta.layers_per_block, self.meta.model_dim);
        let synced;
        let v_host: &HostTensor = match v {
            Value::Host(t) => t,
            Value::Device(_) => {
                synced = self.engine.to_host(v.clone())?;
                &synced
            }
        };
        let v_data = v_host.as_f32()?;

        let mut kv_k =
            self.pool.device_zeroed(&[nl, b, l, dm], |t| self.engine.to_device(t))?;
        let mut kv_v =
            self.pool.device_zeroed(&[nl, b, l, dm], |t| self.engine.to_device(t))?;
        let mut u_prev = self.pool.device_zeroed(&[b, d], |t| self.engine.to_device(t))?;
        // The block index repeats across requests: pin it once per value.
        let k_scalar =
            self.pool.device_scalar_i32(k as i32, |t| self.engine.to_device(t))?;
        let mut u_out = vec![0.0f32; b * l * d];

        for pos in 0..l {
            // Gather v[:, pos, :].
            let mut v_tok = vec![0.0f32; b * d];
            for bi in 0..b {
                let s = (bi * l + pos) * d;
                v_tok[bi * d..(bi + 1) * d].copy_from_slice(&v_data[s..s + d]);
            }
            let outs = self
                .engine
                .call_v(
                    &self.art_seqstep,
                    &[
                        k_scalar.clone(),
                        u_prev,
                        Value::Host(HostTensor::f32(&[b, d], v_tok)),
                        Value::Host(HostTensor::scalar_i32(pos as i32)),
                        kv_k,
                        kv_v,
                    ],
                )
                .with_context(|| format!("seqstep block {k} pos {pos}"))?;
            let mut it = outs.into_iter();
            let u_tok = it.next().context("u token")?;
            kv_k = it.next().context("kv_k")?;
            kv_v = it.next().context("kv_v")?;
            // Only the [B, D] token syncs, for output assembly; u_prev chains
            // the same handle device→device into the next step.
            let u_host = self.engine.to_host(u_tok.clone())?;
            let u_data = u_host.as_f32()?;
            for bi in 0..b {
                let dstoff = (bi * l + pos) * d;
                u_out[dstoff..dstoff + d].copy_from_slice(&u_data[bi * d..(bi + 1) * d]);
            }
            u_prev = u_tok;
        }
        Ok((Value::Host(HostTensor::f32(&[b, l, d], u_out)), l))
    }

    /// Host-tensor wrapper over [`Sampler::sequential_decode_block_v`].
    pub fn sequential_decode_block(&self, k: usize, v: &HostTensor) -> Result<(HostTensor, usize)> {
        let (u, steps) = self.sequential_decode_block_v(k, &Value::Host(v.clone()))?;
        Ok((self.engine.to_host(u)?, steps))
    }

    /// Whole-block sequential inverse as a single scan-fused artifact call
    /// (§Perf ablation — no per-token call/marshal overhead).
    pub fn sequential_decode_block_fused(&self, k: usize, v: &HostTensor) -> Result<HostTensor> {
        let outs = self
            .engine
            .call(&self.art_seqfull, &[HostTensor::scalar_i32(k as i32), v.clone()])?;
        Ok(outs.into_iter().next().expect("seqfull output"))
    }

    /// Decode one block via the paper's eq-6 masked update iterated to its
    /// fixed point (`o > 0` ⇒ approximate masked inference; `o = 0` ⇒ exact
    /// Jacobi decode of `A_k(z) = y`). Host-tensor convenience wrapper.
    pub fn jacobi_decode(
        &self,
        k: usize,
        v: &HostTensor,
        cfg: &JacobiConfig,
        mask_o: usize,
    ) -> Result<(HostTensor, JacobiStats)> {
        let (u, stats) = self.jacobi_decode_v(k, &Value::Host(v.clone()), cfg, mask_o)?;
        Ok((self.engine.to_host(u)?, stats))
    }

    /// Value-based Jacobi decode: `v` stays (or becomes) device-resident and
    /// the returned iterate is still on device — the block-chaining hot path.
    /// The default Zeros init draws `z⁰` from the pool's device-zero cache
    /// (one upload per shape per sampler, not one per block).
    pub fn jacobi_decode_v(
        &self,
        k: usize,
        v: &Value,
        cfg: &JacobiConfig,
        mask_o: usize,
    ) -> Result<(Value, JacobiStats)> {
        self.jacobi_decode_seeded_v(k, v, cfg, mask_o, None)
    }

    /// [`Sampler::jacobi_decode_v`] with an explicit speculative z⁰ —
    /// `Some` wins over the strategy-resolved init, `None` is the plain
    /// path. Every init provider (projection, draft state, warm-cache hit,
    /// cross-stage pipeline edge) threads through here.
    pub fn jacobi_decode_seeded_v(
        &self,
        k: usize,
        v: &Value,
        cfg: &JacobiConfig,
        mask_o: usize,
        z0: Option<Value>,
    ) -> Result<(Value, JacobiStats)> {
        let z0 = self.resolve_z0(cfg, z0)?;
        jacobi_decode_block_v_init(
            self.engine,
            &self.art_jstep,
            k,
            v,
            self.meta.seq_len,
            cfg,
            mask_o,
            z0,
            Some(&self.pool),
        )
    }

    /// Value-based **fused chunked** Jacobi decode (see
    /// `jacobi::jacobi_decode_block_fused_v`): per-iteration semantics of
    /// [`Sampler::jacobi_decode_v`] with host syncs per block cut from
    /// `iterations` to `⌈iterations/S⌉`. `chunk` seeds the first chunk
    /// (calibrated per-block via `sjd calibrate --chunks`). Always the
    /// exact `o = 0` decode; callers gate on
    /// [`Sampler::has_fuse_artifact`] and `mask_o == 0` (see
    /// [`Sampler::decode_tokens`]'s fallback).
    pub fn jacobi_decode_fused_v(
        &self,
        k: usize,
        v: &Value,
        chunk: usize,
        cfg: &JacobiConfig,
    ) -> Result<(Value, JacobiStats)> {
        self.jacobi_decode_fused_seeded_v(k, v, chunk, cfg, None)
    }

    /// [`Sampler::jacobi_decode_fused_v`] with an explicit speculative z⁰
    /// (see [`Sampler::jacobi_decode_seeded_v`]).
    pub fn jacobi_decode_fused_seeded_v(
        &self,
        k: usize,
        v: &Value,
        chunk: usize,
        cfg: &JacobiConfig,
        z0: Option<Value>,
    ) -> Result<(Value, JacobiStats)> {
        let z0 = self.resolve_z0(cfg, z0)?;
        jacobi_decode_block_fused_v(
            self.engine,
            &self.art_jstep_fuse,
            k,
            v,
            self.meta.seq_len,
            cfg,
            z0,
            Some(&self.pool),
            chunk,
        )
    }

    /// The pooled device-zero z⁰ for the default Zeros init (one upload per
    /// shape per sampler), shared by every Jacobi-family decode entry.
    fn pooled_zero_init(&self, cfg: &JacobiConfig) -> Result<Option<Value>> {
        if cfg.init != InitStrategy::Zeros {
            return Ok(None);
        }
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        Ok(Some(self.pool.device_zeroed(&[b, l, d], |t| self.engine.to_device(t))?))
    }

    /// A provider-supplied z⁰ wins; otherwise fall back to the
    /// strategy-resolved init ([`Sampler::pooled_zero_init`] for Zeros, the
    /// drivers' own handling for the rest).
    fn resolve_z0(&self, cfg: &JacobiConfig, z0: Option<Value>) -> Result<Option<Value>> {
        match z0 {
            Some(z) => Ok(Some(z)),
            None => self.pooled_zero_init(cfg),
        }
    }

    /// Value-based windowed GS-Jacobi decode (see
    /// `jacobi::gs_jacobi_decode_block_v`): sweep `windows` windows in order,
    /// iterating the windowed jstep inside each. Residency contract matches
    /// [`Sampler::jacobi_decode_v`]: `v` uploads at most once, the iterate
    /// stays device-resident, the default Zeros init draws from the pool's
    /// device-zero cache.
    pub fn gs_jacobi_decode_v(
        &self,
        k: usize,
        v: &Value,
        windows: usize,
        cfg: &JacobiConfig,
    ) -> Result<(Value, GsJacobiStats)> {
        self.gs_jacobi_decode_seeded_v(k, v, windows, cfg, None)
    }

    /// [`Sampler::gs_jacobi_decode_v`] with an explicit speculative z⁰
    /// (see [`Sampler::jacobi_decode_seeded_v`]).
    pub fn gs_jacobi_decode_seeded_v(
        &self,
        k: usize,
        v: &Value,
        windows: usize,
        cfg: &JacobiConfig,
        z0: Option<Value>,
    ) -> Result<(Value, GsJacobiStats)> {
        let z0 = self.resolve_z0(cfg, z0)?;
        gs_jacobi_decode_block_v(
            self.engine,
            &self.art_jstep_win,
            k,
            v,
            self.meta.seq_len,
            windows,
            cfg,
            z0,
            Some(&self.pool),
        )
    }

    /// Value-based **fused chunked** windowed GS-Jacobi decode (see
    /// `jacobi::gs_jacobi_decode_block_fused_v`): sweep semantics of
    /// [`Sampler::gs_jacobi_decode_v`], inner loops chunked through the
    /// `{m}_block_jstep_win_fuse_b{B}` artifact with `chunk` seeding each
    /// window's scheduler. Same residency and fallback rules as
    /// [`Sampler::jacobi_decode_fused_v`].
    pub fn gs_jacobi_decode_fused_v(
        &self,
        k: usize,
        v: &Value,
        windows: usize,
        chunk: usize,
        cfg: &JacobiConfig,
    ) -> Result<(Value, GsJacobiStats)> {
        self.gs_jacobi_decode_fused_seeded_v(k, v, windows, chunk, cfg, None)
    }

    /// [`Sampler::gs_jacobi_decode_fused_v`] with an explicit speculative
    /// z⁰ (see [`Sampler::jacobi_decode_seeded_v`]).
    pub fn gs_jacobi_decode_fused_seeded_v(
        &self,
        k: usize,
        v: &Value,
        windows: usize,
        chunk: usize,
        cfg: &JacobiConfig,
        z0: Option<Value>,
    ) -> Result<(Value, GsJacobiStats)> {
        let z0 = self.resolve_z0(cfg, z0)?;
        gs_jacobi_decode_block_fused_v(
            self.engine,
            &self.art_jstep_win_fuse,
            k,
            v,
            self.meta.seq_len,
            windows,
            cfg,
            z0,
            Some(&self.pool),
            chunk,
        )
    }

    /// Host-tensor convenience wrapper over [`Sampler::gs_jacobi_decode_v`].
    pub fn gs_jacobi_decode(
        &self,
        k: usize,
        v: &HostTensor,
        windows: usize,
        cfg: &JacobiConfig,
    ) -> Result<(HostTensor, GsJacobiStats)> {
        let (u, stats) = self.gs_jacobi_decode_v(k, &Value::Host(v.clone()), windows, cfg)?;
        Ok((self.engine.to_host(u)?, stats))
    }

    /// Ground-truth single-block forward `v = A_k(u)` (AR domain).
    pub fn block_forward(&self, k: usize, u: &HostTensor) -> Result<HostTensor> {
        let outs = self
            .engine
            .call(&self.art_block_fwd, &[HostTensor::scalar_i32(k as i32), u.clone()])?;
        Ok(outs.into_iter().next().expect("block_fwd output"))
    }

    /// Full encode `x → (z, logdet)` via the python-composed artifact.
    pub fn encode(&self, images: &HostTensor) -> Result<(HostTensor, HostTensor)> {
        let outs = self.engine.call(&self.art_fwd, &[images.clone()])?;
        let mut it = outs.into_iter();
        let z = it.next().expect("z");
        let logdet = it.next().expect("logdet");
        Ok((z, logdet))
    }

    /// Resolve the decode mode the block at decode position `pos` will
    /// actually run: the policy's mode pushed through the degradation chain
    /// for optional artifacts and masked decodes (every fused/windowed
    /// artifact computes the exact `o = 0` update only, and `mask_o`
    /// semantics must not depend on which artifacts happen to be lowered):
    ///
    /// * `GsFused → GsJacobi` when the fused windowed step is absent;
    /// * `Fused → Jacobi` when the fused step is absent;
    /// * `GsJacobi → Jacobi` when the windowed step is absent;
    /// * any of them `→ Jacobi` when an eq-6 mask is requested.
    ///
    /// The chain is per-sampler, so partially lowered buckets route
    /// per-block to the best mode *they* have while richer buckets keep
    /// their fused paths.
    pub fn effective_block_mode(&self, mode: BlockDecode, mask_o: usize) -> BlockDecode {
        let mut mode = mode;
        if mask_o != 0 && mode != BlockDecode::Sequential {
            mode = BlockDecode::Jacobi;
        }
        if let BlockDecode::GsFused { windows, .. } = mode {
            if !self.has_gs_fuse_artifact() {
                mode = BlockDecode::GsJacobi { windows };
            }
        }
        if matches!(mode, BlockDecode::Fused { .. }) && !self.has_fuse_artifact() {
            mode = BlockDecode::Jacobi;
        }
        if matches!(mode, BlockDecode::GsJacobi { .. }) && !self.has_gs_artifact() {
            mode = BlockDecode::Jacobi;
        }
        mode
    }

    /// Decode the single block at decode position `pos` (block
    /// `k = K−1−pos`) and apply its inter-block permutation: `v` is the
    /// block input `h_{k+1}`, the result is `h_k = P_k(A_k^{-1}(v))` plus
    /// the block's trace. This is one **stage** of the decode stage graph
    /// (`coordinator::pipeline`); [`Sampler::decode_tokens`] is the thin
    /// driver that folds a batch through all `K` of them in order.
    ///
    /// Residency: `v` may be host or device; the output chains
    /// device-resident wherever the decode path and the reversal support it
    /// (see the module docs). `BlockTrace::wall` covers the block decode
    /// only — the permutation is accounted to `SampleOutput::other_wall`,
    /// exactly as the monolithic loop always did.
    pub fn decode_block_at(
        &self,
        pos: usize,
        v: &Value,
        opts: &SampleOptions,
    ) -> Result<(Value, BlockTrace)> {
        self.decode_block_at_init(pos, v, opts, None)
    }

    /// [`Sampler::decode_block_at`] with an externally supplied speculative
    /// z⁰ — the pipeline's cross-stage init edge and the draft-then-refine
    /// driver enter here. `Some` wins over the strategy-resolved provider.
    pub fn decode_block_at_init(
        &self,
        pos: usize,
        v: &Value,
        opts: &SampleOptions,
        z0: Option<Value>,
    ) -> Result<(Value, BlockTrace)> {
        let (u, trace) = self.decode_block_inner(pos, v, opts, z0)?;
        let k = self.meta.blocks - 1 - pos;
        // h_k = P_k(u): reversal for odd k.
        let z = if k % 2 == 1 { self.reverse_tokens_v(&u)? } else { u };
        Ok((z, trace))
    }

    /// The un-permuted block decode: returns `u = A_k^{-1}(v)` *before* the
    /// inter-block permutation, which is exactly the state the speculative
    /// providers traffic in (a warm-cache entry or a draft state seeds the
    /// next decode's iterate, whose fixed point is `u`, not `P_k u`).
    fn decode_block_inner(
        &self,
        pos: usize,
        v: &Value,
        opts: &SampleOptions,
        ext_z0: Option<Value>,
    ) -> Result<(Value, BlockTrace)> {
        let kk = self.meta.blocks;
        debug_assert!(pos < kk);
        let k = kk - 1 - pos; // block index in flow order
        let t0 = Instant::now();
        let mode = self.effective_block_mode(opts.policy.block_mode(pos, kk), opts.mask_o);
        let mut cfg = opts.jacobi.clone();
        cfg.seed = opts.seed.wrapping_add(pos as u64);

        // Resolve the speculative z⁰ before the decode dispatch: an external
        // seed (pipeline edge / draft driver) wins, then the provider named
        // by the init strategy. Everything here stays device-resident — the
        // projection artifact chains device→device, warm entries are stored
        // device handles, and a host `v` is uploaded exactly once and reused
        // for both the projection and the decode itself.
        let is_jacobi_mode = mode != BlockDecode::Sequential;
        let mut spec_hit = false;
        let mut spec_cost = 0usize;
        let v_up;
        let v: &Value = if is_jacobi_mode
            && ext_z0.is_none()
            && cfg.init == InitStrategy::Proj
            && self.has_init_proj_artifact()
        {
            match v {
                Value::Device(_) => v,
                Value::Host(h) => {
                    v_up = self.engine.to_device(h)?;
                    &v_up
                }
            }
        } else {
            v
        };
        let z0 = if !is_jacobi_mode {
            None
        } else {
            match ext_z0 {
                Some(z) => {
                    spec_hit = true;
                    Some(z)
                }
                None => match cfg.init {
                    InitStrategy::Proj if self.has_init_proj_artifact() => {
                        // One projected update: L positions written once.
                        spec_hit = true;
                        spec_cost = self.meta.seq_len;
                        Some(self.project_init_v(k, v)?)
                    }
                    InitStrategy::Warm => match self.pool.warm_get(opts.seed, pos) {
                        Some(z) => {
                            spec_hit = true;
                            Some(z)
                        }
                        None => None, // cold: fall through to the Zeros init
                    },
                    _ => None,
                },
            }
        };

        let jacobi_trace = |stats: JacobiStats, wall: Duration| BlockTrace {
            block: k,
            position: pos,
            used_jacobi: true,
            steps: stats.iterations,
            position_updates: stats.iterations * self.meta.seq_len,
            host_syncs: stats.host_syncs,
            wall,
            jacobi: Some(stats),
            gs: None,
            init: cfg.init,
            spec_hit,
            spec_cost_updates: spec_cost,
        };
        let gs_trace = |stats: GsJacobiStats, wall: Duration| BlockTrace {
            block: k,
            position: pos,
            used_jacobi: true,
            steps: stats.iterations,
            position_updates: stats.position_updates,
            host_syncs: stats.host_syncs,
            wall,
            jacobi: None,
            gs: Some(stats),
            init: cfg.init,
            spec_hit,
            spec_cost_updates: spec_cost,
        };
        let (u, trace) = match mode {
            BlockDecode::Jacobi => {
                let (u, stats) = self.jacobi_decode_seeded_v(k, v, &cfg, opts.mask_o, z0)?;
                let trace = jacobi_trace(stats, t0.elapsed());
                (u, trace)
            }
            BlockDecode::Fused { chunk } => {
                let (u, stats) = self.jacobi_decode_fused_seeded_v(k, v, chunk, &cfg, z0)?;
                let trace = jacobi_trace(stats, t0.elapsed());
                (u, trace)
            }
            BlockDecode::GsJacobi { windows } => {
                let (u, stats) = self.gs_jacobi_decode_seeded_v(k, v, windows, &cfg, z0)?;
                let trace = gs_trace(stats, t0.elapsed());
                (u, trace)
            }
            BlockDecode::GsFused { windows, chunk } => {
                let (u, stats) =
                    self.gs_jacobi_decode_fused_seeded_v(k, v, windows, chunk, &cfg, z0)?;
                let trace = gs_trace(stats, t0.elapsed());
                (u, trace)
            }
            BlockDecode::Sequential => {
                let (u, steps, host_syncs) = if opts.fused_sequential {
                    let v_host = match v {
                        Value::Host(t) => t.clone(),
                        Value::Device(_) => self.engine.to_host(v.clone())?,
                    };
                    (
                        Value::Host(self.sequential_decode_block_fused(k, &v_host)?),
                        self.meta.seq_len,
                        1,
                    )
                } else {
                    // One [B, D] token fetch per position (see
                    // sequential_decode_block_v).
                    let (u, steps) = self.sequential_decode_block_v(k, v)?;
                    (u, steps, self.meta.seq_len)
                };
                let wall = t0.elapsed();
                (
                    u,
                    BlockTrace {
                        block: k,
                        position: pos,
                        used_jacobi: false,
                        steps,
                        position_updates: self.meta.seq_len,
                        host_syncs,
                        wall,
                        jacobi: None,
                        gs: None,
                        init: cfg.init,
                        spec_hit: false,
                        spec_cost_updates: 0,
                    },
                )
            }
        };
        // Warm-start upkeep: a converged, device-resident iterate is the
        // perfect z⁰ for the next decode of the same (seed, position) — one
        // resid-0 verify iteration instead of a full solve.
        if is_jacobi_mode && cfg.init == InitStrategy::Warm {
            let converged = trace
                .jacobi
                .as_ref()
                .map(|s| s.converged)
                .or_else(|| trace.gs.as_ref().map(|s| s.converged))
                .unwrap_or(false);
            if converged && u.is_device() {
                self.pool.warm_put(opts.seed, pos, u.clone());
            }
        }
        Ok((u, trace))
    }

    /// Full decode: latent tokens (B, L, D) → data tokens h_0 (B, L, D),
    /// following the configured policy — a thin driver folding the batch
    /// through [`Sampler::decode_block_at`] for every decode position. This
    /// is the single-in-flight serving path: the latent is uploaded once,
    /// block outputs chain device→device across all K blocks, and the
    /// tokens come back to the host once at the end (see the module docs
    /// for the full residency map). The stage-graph pipeline
    /// (`coordinator::pipeline`) walks the same per-block stages with ≥2
    /// batches in flight.
    pub fn decode_tokens(&self, z_latent: HostTensor, opts: &SampleOptions) -> Result<SampleOutput> {
        if opts.jacobi.init == InitStrategy::Draft {
            return self.decode_tokens_draft(z_latent, opts);
        }
        let t_start = Instant::now();
        let kk = self.meta.blocks;
        let mut traces = Vec::with_capacity(kk);
        let mut decode_wall = Duration::ZERO;
        // Start host-side: the first block uploads it if (and only if) its
        // decode path runs on device — a sequential first block reads it
        // directly, with no wasted round trip.
        let mut z: Value = Value::Host(z_latent);

        for pos in 0..kk {
            let (z_next, trace) = self.decode_block_at(pos, &z, opts)?;
            decode_wall += trace.wall;
            traces.push(trace);
            z = z_next;
        }

        let tokens = self.engine.to_host(z)?;
        let total_wall = t_start.elapsed();
        Ok(SampleOutput {
            tokens,
            traces,
            total_wall,
            other_wall: total_wall.saturating_sub(decode_wall),
        })
    }

    /// Draft-then-refine decode ([`InitStrategy::Draft`]): a cheap draft
    /// pass — the fused family at a coarse chunk with a relaxed τ — produces
    /// a full-sequence guess, whose per-block converged states then seed the
    /// exact refine pass as z⁰. Prop 3.2 makes the refine output bit-equal
    /// to a Zeros decode at τ = 0 regardless of draft quality; the draft
    /// states stay device-resident end to end (pre-permutation `u`, exactly
    /// the refine iterate's fixed-point frame).
    ///
    /// Accounting stays honest and the trace vector stays length K: each
    /// refine trace absorbs its position's draft cost
    /// ([`BlockTrace::spec_cost_updates`], draft host syncs folded into
    /// [`BlockTrace::host_syncs`]) — a draft pass that doesn't shrink
    /// refine work shows up as negative savings, which is what lets the
    /// tuner revert a bucket to Zeros.
    fn decode_tokens_draft(&self, z_latent: HostTensor, opts: &SampleOptions) -> Result<SampleOutput> {
        let t_start = Instant::now();
        let kk = self.meta.blocks;

        // Draft pass: Zeros-from-pool init, coarse fused chunks, relaxed τ.
        let mut draft_opts = opts.clone();
        draft_opts.jacobi.init = InitStrategy::Zeros;
        draft_opts.jacobi.tau = (opts.jacobi.tau * 4.0).max(0.5);
        draft_opts.policy = DecodePolicy::Fused { chunk: DEFAULT_FUSE_CHUNK };
        let mut drafts: Vec<Option<Value>> = Vec::with_capacity(kk);
        let mut draft_traces = Vec::with_capacity(kk);
        let mut decode_wall = Duration::ZERO;
        let mut z: Value = Value::Host(z_latent.clone());
        for pos in 0..kk {
            let (u, trace) = self.decode_block_inner(pos, &z, &draft_opts, None)?;
            decode_wall += trace.wall;
            draft_traces.push(trace);
            let k = kk - 1 - pos;
            drafts.push(Some(u.clone()));
            z = if k % 2 == 1 { self.reverse_tokens_v(&u)? } else { u };
        }

        // Refine pass: the exact policy/τ, seeded per block from the draft.
        let mut traces = Vec::with_capacity(kk);
        let mut z: Value = Value::Host(z_latent);
        for pos in 0..kk {
            let z0 = drafts[pos].take();
            let (u, mut trace) = self.decode_block_inner(pos, &z, opts, z0)?;
            decode_wall += trace.wall;
            trace.init = InitStrategy::Draft;
            trace.spec_hit = trace.used_jacobi;
            trace.spec_cost_updates = draft_traces[pos].position_updates;
            trace.host_syncs += draft_traces[pos].host_syncs;
            trace.wall += draft_traces[pos].wall;
            traces.push(trace);
            let k = kk - 1 - pos;
            z = if k % 2 == 1 { self.reverse_tokens_v(&u)? } else { u };
        }

        let tokens = self.engine.to_host(z)?;
        let total_wall = t_start.elapsed();
        Ok(SampleOutput {
            tokens,
            traces,
            total_wall,
            other_wall: total_wall.saturating_sub(decode_wall),
        })
    }

    /// Sample a batch of images.
    pub fn sample_images(&self, opts: &SampleOptions, rng: &mut Pcg64) -> Result<(Vec<Tensor>, SampleOutput)> {
        let z = self.sample_prior(rng);
        let out = self.decode_tokens(z, opts)?;
        let images = self.unpatchify(&out.tokens)?;
        Ok((images, out))
    }

    /// Tokens (B, L, D) → per-sample (H, W, C) tensors.
    ///
    /// Inverse of python's
    /// `x.reshape(B, H/P, P, W/P, P, C).transpose(0,1,3,2,4,5).reshape(B, L, D)`.
    pub fn unpatchify(&self, tokens: &HostTensor) -> Result<Vec<Tensor>> {
        let [h, w, c] = self.meta.image_hwc.context("model has no image geometry")?;
        let p = self.meta.patch;
        let (b, l, d) = (self.batch, self.meta.seq_len, self.meta.token_dim);
        debug_assert_eq!(l, (h / p) * (w / p));
        debug_assert_eq!(d, p * p * c);
        let data = tokens.as_f32()?;
        let gw = w / p;
        let mut out = Vec::with_capacity(b);
        for bi in 0..b {
            let mut img = vec![0.0f32; h * w * c];
            for li in 0..l {
                let (py, px) = (li / gw, li % gw);
                let tok = &data[(bi * l + li) * d..(bi * l + li + 1) * d];
                for dy in 0..p {
                    for dx in 0..p {
                        for ch in 0..c {
                            let v = tok[(dy * p + dx) * c + ch];
                            img[((py * p + dy) * w + (px * p + dx)) * c + ch] = v;
                        }
                    }
                }
            }
            out.push(Tensor::new(&[h, w, c], img)?);
        }
        Ok(out)
    }

    /// Images (list of (H, W, C) tensors) → tokens (B, L, D); exact inverse
    /// of [`Self::unpatchify`].
    pub fn patchify(&self, images: &[Tensor]) -> Result<HostTensor> {
        let [h, w, c] = self.meta.image_hwc.context("model has no image geometry")?;
        let p = self.meta.patch;
        let (b, l, d) = (images.len(), self.meta.seq_len, self.meta.token_dim);
        let gw = w / p;
        let mut out = vec![0.0f32; b * l * d];
        for (bi, img) in images.iter().enumerate() {
            if img.shape() != [h, w, c] {
                bail!("image {bi} has shape {:?}, expected ({h},{w},{c})", img.shape());
            }
            for li in 0..l {
                let (py, px) = (li / gw, li % gw);
                for dy in 0..p {
                    for dx in 0..p {
                        for ch in 0..c {
                            out[(bi * l + li) * d + (dy * p + dx) * c + ch] =
                                img.at(&[py * p + dy, px * p + dx, ch]);
                        }
                    }
                }
            }
        }
        Ok(HostTensor::f32(&[b, l, d], out))
    }

    /// Images stacked as one (B, H, W, C) HostTensor (for the fwd artifact).
    pub fn stack_images(&self, images: &[Tensor]) -> Result<HostTensor> {
        let [h, w, c] = self.meta.image_hwc.context("no image geometry")?;
        let mut data = Vec::with_capacity(images.len() * h * w * c);
        for img in images {
            if img.shape() != [h, w, c] {
                bail!("bad image shape {:?}", img.shape());
            }
            data.extend_from_slice(img.data());
        }
        Ok(HostTensor::f32(&[images.len(), h, w, c], data))
    }
}
