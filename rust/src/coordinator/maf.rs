//! MAF decode driver (paper §E.3): MLP-MADE flows where no KV cache applies,
//! so *all* layers use Jacobi decoding in the accelerated path, and the
//! sequential baseline is exactly `d` Jacobi steps per layer (each step runs
//! one full MADE forward and fixes at least the next dimension — identical
//! compute to the classic per-dimension loop).

use super::jacobi::{InitStrategy, JacobiConfig, JacobiStats};
use crate::runtime::{Backend, HostTensor, ModelMeta};
use crate::tensor::{Pcg64, Tensor};
use anyhow::{bail, Context, Result};
use std::time::{Duration, Instant};

/// How a MAF sampling run decodes its layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MafMode {
    /// d full-MADE evaluations per layer (the sequential baseline).
    Sequential,
    /// Jacobi with τ stopping on all layers ("ours" for MAF).
    Jacobi,
}

/// Result of one MAF sampling run.
#[derive(Clone, Debug)]
pub struct MafOutput {
    /// Samples (B, d) in data space.
    pub samples: HostTensor,
    pub per_layer: Vec<JacobiStats>,
    pub total_wall: Duration,
}

impl MafOutput {
    /// Total MADE evaluations of the run (the cost metric).
    pub fn made_evals(&self) -> usize {
        self.per_layer.iter().map(|s| s.iterations).sum()
    }
}

/// MAF sampler bound to an engine + batch size.
pub struct MafSampler<'e, B: Backend> {
    engine: &'e B,
    pub meta: ModelMeta,
    pub batch: usize,
    art_fwd: String,
    art_jstep: String,
}

impl<'e, B: Backend> MafSampler<'e, B> {
    pub fn new(engine: &'e B, model: &str, batch: usize) -> Result<Self> {
        let meta = engine.model_meta(model)?;
        if meta.kind != "maf" {
            bail!("model '{model}' is not a maf model");
        }
        if !meta.batch_sizes.contains(&batch) {
            bail!("maf model '{model}' lacks batch {batch} (have {:?})", meta.batch_sizes);
        }
        Ok(MafSampler {
            engine,
            meta,
            batch,
            art_fwd: format!("{model}_fwd_b{batch}"),
            art_jstep: format!("{model}_layer_jstep_b{batch}"),
        })
    }

    pub fn sample_prior(&self, rng: &mut Pcg64) -> HostTensor {
        let (b, d) = (self.batch, self.meta.seq_len);
        HostTensor::f32(&[b, d], Tensor::randn(&[b, d], rng).into_data())
    }

    /// Encode x → (z, logdet) (density-estimation direction).
    pub fn encode(&self, x: &HostTensor) -> Result<(HostTensor, HostTensor)> {
        let outs = self.engine.call(&self.art_fwd, &[x.clone()])?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// Reverse one layer's dimension order (inter-layer permutation).
    fn reverse_dims(&self, t: &HostTensor) -> Result<HostTensor> {
        let shape = t.shape().to_vec();
        let (b, d) = (shape[0], shape[1]);
        let src = t.as_f32()?;
        let mut out = vec![0.0f32; src.len()];
        for bi in 0..b {
            for di in 0..d {
                out[bi * d + (d - 1 - di)] = src[bi * d + di];
            }
        }
        Ok(HostTensor::f32(&shape, out))
    }

    /// One layer inverse via Jacobi iteration, device-resident: `y` and the
    /// layer scalar are uploaded once, the iterate chains device→device, and
    /// per iteration only the `[B]` residual syncs for the τ test (mirrors
    /// `jacobi_decode_block_v`; the layer artifact takes no mask argument).
    fn layer_inverse(
        &self,
        k: usize,
        y: &HostTensor,
        tau: f32,
        cap: usize,
    ) -> Result<(HostTensor, JacobiStats)> {
        let t0 = Instant::now();
        let y_dev = self.engine.to_device(y)?;
        let k_scalar = self.engine.to_device(&HostTensor::scalar_i32(k as i32))?;
        let mut z = self.engine.to_device(&HostTensor::f32(y.shape(), vec![0.0; y.len()]))?;
        let mut residuals = Vec::new();
        let mut converged = false;
        let mut iterations = 0;
        while iterations < cap {
            let outs =
                self.engine.call_v(&self.art_jstep, &[k_scalar.clone(), z, y_dev.clone()])?;
            let mut it = outs.into_iter();
            let z_next = it.next().context("maf jstep returns z'")?;
            let resid_v = it.next().context("maf jstep returns residual")?;
            let resid = self
                .engine
                .to_host(resid_v)?
                .as_f32()?
                .iter()
                .copied()
                .fold(0.0f32, f32::max);
            residuals.push(resid);
            z = z_next;
            iterations += 1;
            if resid < tau {
                converged = true;
                break;
            }
        }
        let z_host = self.engine.to_host(z)?;
        Ok((
            z_host,
            JacobiStats {
                block: k,
                iterations,
                wall: t0.elapsed(),
                residuals,
                converged,
                host_syncs: iterations,
            },
        ))
    }

    /// Sample a batch: z ~ N(0, I) → x through all layers.
    pub fn sample(&self, mode: MafMode, cfg: &JacobiConfig, rng: &mut Pcg64) -> Result<MafOutput> {
        let t0 = Instant::now();
        let kk = self.meta.blocks;
        let d = self.meta.seq_len;
        let mut h = self.sample_prior(rng);
        let mut per_layer = Vec::with_capacity(kk);
        for pos in 0..kk {
            let k = kk - 1 - pos;
            let (tau, cap) = match mode {
                // τ = 0 never triggers: exactly d iterations (sequential cost).
                MafMode::Sequential => (0.0, d),
                MafMode::Jacobi => (cfg.tau, cfg.max_iters.unwrap_or(d)),
            };
            let (u, stats) = self.layer_inverse(k, &h, tau, cap)?;
            per_layer.push(stats);
            h = if k % 2 == 1 { self.reverse_dims(&u)? } else { u };
        }
        Ok(MafOutput { samples: h, per_layer, total_wall: t0.elapsed() })
    }


}

/// Default Jacobi config for MAF runs (the paper uses τ = 0.5 on images; MAF
/// here operates on dequantized ±1 spins, where a tighter τ keeps sign
/// fidelity).
pub fn maf_config(tau: f32) -> JacobiConfig {
    JacobiConfig { tau, max_iters: None, init: InitStrategy::Zeros, seed: 0 }
}
