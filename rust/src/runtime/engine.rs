//! The [`Engine`]: one PJRT client + a lazy compile cache over the artifacts
//! listed in the manifest.
//!
//! `PjRtClient` is `Rc`-based and therefore **thread-pinned**: an `Engine`
//! lives on one thread. Multi-worker serving (see `coordinator::router`)
//! gives each worker thread its own `Engine`; requests/results cross threads
//! as [`HostTensor`]s, which are plain `Send` data.

use super::manifest::{ArtifactMeta, DType, Manifest};
use super::HostTensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// Per-artifact call statistics (compile time, call count, execute time).
#[derive(Clone, Debug, Default)]
pub struct CallStats {
    pub compile_time: Duration,
    pub calls: u64,
    pub exec_time: Duration,
    /// Host→literal packing + literal→host unpacking time.
    pub marshal_time: Duration,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

/// Input to [`Engine::call_buffers`]: host data or a device-resident buffer
/// from a previous call.
pub enum BufferArg<'a> {
    Host(HostTensor),
    Device(&'a xla::PjRtBuffer),
}

/// Loads HLO-text artifacts on demand, validates signatures, executes.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
    stats: RefCell<HashMap<String, CallStats>>,
    /// When true, input shapes/dtypes are checked against the manifest on
    /// every call (cheap; disabled only in the innermost perf benches).
    pub validate_calls: bool,
}

impl Engine {
    /// Create an engine over `artifacts/manifest.json` in `artifacts_dir`.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir.as_ref().join("manifest.json"))?;
        Self::with_manifest(manifest)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            validate_calls: true,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn compiled(&self, name: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let meta = self.manifest.artifact(name)?.clone();
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let compile_time = t0.elapsed();
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_time = compile_time;
        log::info!("compiled artifact '{name}' in {compile_time:?}");
        let c = Rc::new(Compiled { exe, meta });
        self.cache.borrow_mut().insert(name.to_string(), c.clone());
        Ok(c)
    }

    /// Eagerly compile a set of artifacts (warmup before serving).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.compiled(n)?;
        }
        Ok(())
    }

    fn validate_inputs(&self, meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (spec, t) in meta.inputs.iter().zip(inputs) {
            let ok_dtype = matches!(
                (spec.dtype, t),
                (DType::F32, HostTensor::F32 { .. }) | (DType::I32, HostTensor::I32 { .. })
            );
            if !ok_dtype {
                bail!("artifact '{}' input '{}': dtype mismatch", meta.name, spec.name);
            }
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact '{}' input '{}': shape {:?} != expected {:?}",
                    meta.name,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact with host inputs; returns host outputs.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single result
    /// literal is a tuple which is decomposed into one `HostTensor` per
    /// declared output.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let c = self.compiled(name)?;
        if self.validate_calls {
            self.validate_inputs(&c.meta, inputs)?;
        }

        let tm0 = Instant::now();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let marshal_in = tm0.elapsed();

        let t0 = Instant::now();
        let result = c
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of '{name}'"))?;
        let exec_time = t0.elapsed();

        let tm1 = Instant::now();
        let parts = out_lit.to_tuple().context("decomposing output tuple")?;
        if parts.len() != c.meta.outputs.len() {
            bail!(
                "artifact '{}' declared {} outputs but returned {}",
                name,
                c.meta.outputs.len(),
                parts.len()
            );
        }
        let outs: Vec<HostTensor> =
            parts.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        let marshal_out = tm1.elapsed();

        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.exec_time += exec_time;
        s.marshal_time += marshal_in + marshal_out;
        Ok(outs)
    }

    /// Execute with a mix of host tensors and device-resident buffers.
    ///
    /// Positions listed in `buffers` are taken from the given
    /// [`xla::PjRtBuffer`]s (outputs of a previous call) instead of being
    /// marshalled from host memory — the perf-pass fast path for chained
    /// state like sequential-decode KV caches. Returns raw output buffers;
    /// use [`Engine::buffer_to_host`] for the ones you need on the host.
    ///
    /// The artifact must have been lowered WITHOUT tuple outputs flattened —
    /// outputs come back as one tuple buffer per PJRT semantics, so this
    /// path destructures via `to_literal_sync` only for requested outputs.
    pub fn call_buffers(
        &self,
        name: &str,
        inputs: &[BufferArg<'_>],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let c = self.compiled(name)?;
        // Promote host args to device buffers (two passes so the borrows of
        // `owned` are taken only after it stops growing).
        let mut owned: Vec<Option<xla::PjRtBuffer>> = Vec::with_capacity(inputs.len());
        for arg in inputs {
            owned.push(match arg {
                BufferArg::Host(t) => {
                    let lit = t.to_literal()?;
                    Some(self.client.buffer_from_host_literal(None, &lit)?)
                }
                BufferArg::Device(_) => None,
            });
        }
        let borrowed: Vec<&xla::PjRtBuffer> = inputs
            .iter()
            .zip(&owned)
            .map(|(arg, own)| match arg {
                BufferArg::Host(_) => own.as_ref().unwrap(),
                BufferArg::Device(b) => *b,
            })
            .collect();
        let t0 = Instant::now();
        let result = c.exe.execute_b::<&xla::PjRtBuffer>(&borrowed)?;
        let exec_time = t0.elapsed();
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.exec_time += exec_time;
        drop(stats);
        Ok(result.into_iter().next().unwrap_or_default())
    }

    /// Fetch one output buffer to the host, decomposing the result tuple.
    pub fn tuple_outputs_to_host(&self, buf: &xla::PjRtBuffer) -> Result<Vec<HostTensor>> {
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Snapshot of per-artifact statistics.
    pub fn stats(&self) -> HashMap<String, CallStats> {
        self.stats.borrow().clone()
    }

    /// Reset call statistics (keeps compile times).
    pub fn reset_stats(&self) {
        for s in self.stats.borrow_mut().values_mut() {
            s.calls = 0;
            s.exec_time = Duration::ZERO;
            s.marshal_time = Duration::ZERO;
        }
    }
}
