//! Decode-policy selection (paper §3.5, "Where to Use Jacobi Decoding").
//!
//! The flow has `K` blocks decoded in order `k = K, K−1, …, 1` during
//! sampling (noise → data). Block index here is the *decode position*
//! `0 .. K-1` where position 0 is the first block applied to Gaussian noise —
//! the paper's "first layer" with low redundancy.
//!
//! Every policy reduces to a per-position [`BlockDecode`] via
//! [`DecodePolicy::block_mode`]: sequential KV-cached decoding, full-sequence
//! Jacobi, or windowed GS-Jacobi (see
//! [`gs_jacobi_decode_block_v`](super::jacobi::gs_jacobi_decode_block_v)).
//! Calibration ([`calibrate`], [`calibrate_windows`]) learns a policy from
//! measured per-block decode traces; learned policies serialize to JSON
//! (`sjd calibrate` writes them, `--policy @file` / `--policy-file` load
//! them back).

use super::jacobi::JacobiStats;

/// Default window count for the `"gs"` policy shorthand.
pub const DEFAULT_GS_WINDOWS: usize = 4;

/// Default first-chunk size for the `"fuse"` policy shorthand — matches the
/// history length the python side lowers into the fused artifacts
/// (`aot.JSTEP_FUSE_STEPS`), so a default decode runs maximal chunks. The
/// drivers discover the real device cap from the returned history shape;
/// this is only the scheduler seed.
pub const DEFAULT_FUSE_CHUNK: usize = 8;

/// How one decode position is handled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockDecode {
    /// Autoregressive KV-cached decoding (L artifact calls).
    Sequential,
    /// Full-sequence Jacobi iteration (paper Alg 1).
    Jacobi,
    /// Windowed GS-Jacobi: Gauss–Seidel across `windows` windows, Jacobi
    /// inside the active window.
    GsJacobi { windows: usize },
    /// Full-sequence Jacobi through the fused multi-step artifact
    /// (`jacobi_decode_block_fused_v`): chunked dispatch with one residual
    /// history sync per chunk instead of per iteration. `chunk` seeds the
    /// first chunk — a calibrated per-block iteration count makes
    /// single-chunk decodes the common case.
    Fused { chunk: usize },
    /// Windowed GS-Jacobi with the fused multi-step window artifact
    /// (`gs_jacobi_decode_block_fused_v`): GS sweep semantics of
    /// [`BlockDecode::GsJacobi`], inner loops chunked like
    /// [`BlockDecode::Fused`].
    GsFused { windows: usize, chunk: usize },
}

impl BlockDecode {
    fn to_json(self) -> crate::jsonx::Value {
        use crate::jsonx::Value;
        match self {
            BlockDecode::Sequential => Value::obj(vec![("mode", Value::str("sequential"))]),
            BlockDecode::Jacobi => Value::obj(vec![("mode", Value::str("jacobi"))]),
            BlockDecode::GsJacobi { windows } => Value::obj(vec![
                ("mode", Value::str("gs")),
                ("windows", Value::num(windows as f64)),
            ]),
            BlockDecode::Fused { chunk } => Value::obj(vec![
                ("mode", Value::str("fuse")),
                ("chunk", Value::num(chunk as f64)),
            ]),
            BlockDecode::GsFused { windows, chunk } => Value::obj(vec![
                ("mode", Value::str("gs_fuse")),
                ("windows", Value::num(windows as f64)),
                ("chunk", Value::num(chunk as f64)),
            ]),
        }
    }

    fn from_json(v: &crate::jsonx::Value) -> anyhow::Result<Self> {
        match v.req_str("mode")? {
            "sequential" => Ok(BlockDecode::Sequential),
            "jacobi" => Ok(BlockDecode::Jacobi),
            "gs" => Ok(BlockDecode::GsJacobi { windows: windows_from_json(v)? }),
            "fuse" => Ok(BlockDecode::Fused { chunk: chunk_from_json(v)? }),
            "gs_fuse" => Ok(BlockDecode::GsFused {
                windows: windows_from_json(v)?,
                chunk: chunk_from_json(v)?,
            }),
            other => anyhow::bail!("unknown block mode '{other}'"),
        }
    }
}

/// Read an optional `windows` field: absent ⇒ the default, present ⇒ must be
/// a positive integer (a malformed value is an error, never silently the
/// default — the operator's policy file means what it says).
fn windows_from_json(v: &crate::jsonx::Value) -> anyhow::Result<usize> {
    match v.get("windows") {
        None => Ok(DEFAULT_GS_WINDOWS),
        Some(w) => w
            .as_usize()
            .filter(|&w| w >= 1)
            .ok_or_else(|| anyhow::anyhow!("gs windows must be a positive integer, got {w:?}")),
    }
}

/// Read an optional `chunk` field with the same strictness as
/// [`windows_from_json`]: absent ⇒ the default, present-but-malformed ⇒ an
/// error, never silently the default.
fn chunk_from_json(v: &crate::jsonx::Value) -> anyhow::Result<usize> {
    match v.get("chunk") {
        None => Ok(DEFAULT_FUSE_CHUNK),
        Some(c) => c
            .as_usize()
            .filter(|&c| c >= 1)
            .ok_or_else(|| anyhow::anyhow!("fuse chunk must be a positive integer, got {c:?}")),
    }
}

/// How each of the `K` blocks is decoded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    /// Standard sequential (autoregressive, KV cache) everywhere — the
    /// paper's baseline.
    Sequential,
    /// Jacobi everywhere (paper's "UJD" baseline).
    UniformJacobi,
    /// Paper's SJD: sequential for the first `seq_blocks` decode positions,
    /// Jacobi for the rest. `seq_blocks = 1` is the paper's setting.
    Selective { seq_blocks: usize },
    /// Windowed GS-Jacobi at every decode position. `windows = 1` is
    /// equivalent to [`DecodePolicy::UniformJacobi`]; `windows = L` is
    /// sequential-equivalent work done through the jstep_win artifact.
    GsJacobi { windows: usize },
    /// Fused chunked Jacobi at every decode position
    /// ([`BlockDecode::Fused`]) — UJD semantics with `⌈t/S⌉` host syncs per
    /// block instead of `t`. The sampler falls back to plain Jacobi where
    /// the fused artifact is absent.
    Fused { chunk: usize },
    /// Per-block Jacobi-vs-sequential choice learned by [`calibrate`].
    Custom { jacobi_mask: Vec<bool> },
    /// Fully per-block decode modes (window counts included) learned by
    /// [`calibrate_windows`].
    PerBlock { modes: Vec<BlockDecode> },
}

impl DecodePolicy {
    /// Parse CLI string:
    /// `"sequential" | "ujd" | "selective[:N]" | "gs[:W]" | "fuse[:S]"`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sequential" | "seq" => Some(DecodePolicy::Sequential),
            "ujd" | "uniform" | "jacobi" => Some(DecodePolicy::UniformJacobi),
            "selective" | "sjd" => Some(DecodePolicy::Selective { seq_blocks: 1 }),
            "gs" | "gs-jacobi" => Some(DecodePolicy::GsJacobi { windows: DEFAULT_GS_WINDOWS }),
            "fuse" | "fused" => Some(DecodePolicy::Fused { chunk: DEFAULT_FUSE_CHUNK }),
            _ => {
                if let Some(n) = s.strip_prefix("selective:") {
                    return Some(DecodePolicy::Selective { seq_blocks: n.parse().ok()? });
                }
                if let Some(c) = s.strip_prefix("fuse:") {
                    let chunk: usize = c.parse().ok()?;
                    if chunk == 0 {
                        return None;
                    }
                    return Some(DecodePolicy::Fused { chunk });
                }
                let w: usize = s.strip_prefix("gs:")?.parse().ok()?;
                if w == 0 {
                    return None;
                }
                Some(DecodePolicy::GsJacobi { windows: w })
            }
        }
    }

    /// Decode mode for decode-position `pos` (0-based, 0 = first block after
    /// noise).
    pub fn block_mode(&self, pos: usize, total_blocks: usize) -> BlockDecode {
        debug_assert!(pos < total_blocks);
        match self {
            DecodePolicy::Sequential => BlockDecode::Sequential,
            DecodePolicy::UniformJacobi => BlockDecode::Jacobi,
            DecodePolicy::Selective { seq_blocks } => {
                if pos < *seq_blocks {
                    BlockDecode::Sequential
                } else {
                    BlockDecode::Jacobi
                }
            }
            DecodePolicy::GsJacobi { windows } => BlockDecode::GsJacobi { windows: *windows },
            DecodePolicy::Fused { chunk } => BlockDecode::Fused { chunk: *chunk },
            DecodePolicy::Custom { jacobi_mask } => {
                if jacobi_mask.get(pos).copied().unwrap_or(true) {
                    BlockDecode::Jacobi
                } else {
                    BlockDecode::Sequential
                }
            }
            DecodePolicy::PerBlock { modes } => {
                modes.get(pos).copied().unwrap_or(BlockDecode::Jacobi)
            }
        }
    }

    /// Should decode-position `pos` use a Jacobi-family decode? (Legacy
    /// predicate over [`DecodePolicy::block_mode`].)
    pub fn use_jacobi(&self, pos: usize, total_blocks: usize) -> bool {
        self.block_mode(pos, total_blocks) != BlockDecode::Sequential
    }

    pub fn label(&self) -> String {
        match self {
            DecodePolicy::Sequential => "Sequential".into(),
            DecodePolicy::UniformJacobi => "UJD".into(),
            DecodePolicy::Selective { seq_blocks: 1 } => "SJD".into(),
            DecodePolicy::Selective { seq_blocks } => format!("SJD(seq={seq_blocks})"),
            DecodePolicy::GsJacobi { windows } => format!("GS-Jacobi(W={windows})"),
            DecodePolicy::Fused { chunk } => format!("Fused(S={chunk})"),
            DecodePolicy::Custom { .. } => "Adaptive".into(),
            DecodePolicy::PerBlock { .. } => "Adaptive-GS".into(),
        }
    }
}

/// Calibration: decide per-block Jacobi vs sequential from measured stats.
///
/// A block prefers Jacobi when its measured Jacobi wall time beats the
/// estimated sequential wall time for the same block. `seq_wall` comes from
/// a sequential calibration pass; if a block's Jacobi decode failed to
/// converge within the cap it is forced sequential.
pub fn calibrate(
    jacobi: &[JacobiStats],
    seq_wall: &[std::time::Duration],
) -> DecodePolicy {
    assert_eq!(jacobi.len(), seq_wall.len());
    let mask = jacobi
        .iter()
        .zip(seq_wall)
        .map(|(j, s)| j.converged && j.wall < *s)
        .collect();
    DecodePolicy::Custom { jacobi_mask: mask }
}

/// Window-aware calibration: learn a per-block [`BlockDecode`] — including
/// GS-Jacobi window counts — from full-sequence Jacobi iteration traces.
///
/// The window-count heuristic follows the GS-Jacobi cost model: a window of
/// length `len` converges in ≈ `min(t, len)` iterations, where `t` is the
/// block's measured full-sequence iteration count. A *hard* block
/// (`t ≈ L`, sequential-like coupling) costs `L²` position-updates under
/// plain Jacobi but `≈ L²/W` under `W` windows — more windows strictly help.
/// An *easy* block (`t ≪ L/W`) costs `t·L` either way, so extra windows only
/// add per-call overhead — one window (plain Jacobi) is best. Interpolating,
/// the learned count is `round(t/L · max_windows)`, clamped to
/// `[1, max_windows]`.
///
/// Blocks whose Jacobi decode failed to converge within the cap, or measured
/// slower than their sequential pass, stay sequential (the conservative
/// choice [`calibrate`] makes too).
pub fn calibrate_windows(
    jacobi: &[JacobiStats],
    seq_wall: &[std::time::Duration],
    seq_len: usize,
    max_windows: usize,
) -> DecodePolicy {
    assert_eq!(jacobi.len(), seq_wall.len());
    assert!(seq_len > 0 && max_windows > 0);
    let modes = jacobi
        .iter()
        .zip(seq_wall)
        .map(|(j, s)| {
            if !j.converged || j.wall >= *s {
                return BlockDecode::Sequential;
            }
            let ratio = j.iterations as f64 / seq_len as f64;
            let windows =
                ((ratio * max_windows as f64).round() as usize).clamp(1, max_windows);
            if windows == 1 {
                BlockDecode::Jacobi
            } else {
                BlockDecode::GsJacobi { windows }
            }
        })
        .collect();
    DecodePolicy::PerBlock { modes }
}

/// Chunk-aware calibration (`sjd calibrate --chunks`): the per-block modes
/// of [`calibrate_windows`], routed through the **fused multi-step**
/// artifacts with per-block chunk schedules learned from the same iteration
/// traces.
///
/// The first-chunk seed is the point of calibration: a block measured to
/// converge in `t` iterations gets `chunk = t` (full-sequence fused decode
/// lands its very first chunk exactly on the τ crossing — one host sync,
/// bit-identical iterate) and a windowed block gets `⌈t/W⌉` (the expected
/// per-window share of the trace). Both are clamped to `s_max`, the fused
/// artifacts' lowered history length, because a chunk can never run past
/// the device-side history. Blocks that failed to converge or measured
/// slower than sequential stay sequential, exactly like
/// [`calibrate_windows`].
pub fn calibrate_chunks(
    jacobi: &[JacobiStats],
    seq_wall: &[std::time::Duration],
    seq_len: usize,
    max_windows: usize,
    s_max: usize,
) -> DecodePolicy {
    assert!(s_max > 0);
    let DecodePolicy::PerBlock { modes } =
        calibrate_windows(jacobi, seq_wall, seq_len, max_windows)
    else {
        unreachable!("calibrate_windows returns PerBlock");
    };
    let modes = modes
        .into_iter()
        .zip(jacobi)
        .map(|(m, j)| match m {
            BlockDecode::Jacobi => {
                BlockDecode::Fused { chunk: j.iterations.clamp(1, s_max) }
            }
            BlockDecode::GsJacobi { windows } => BlockDecode::GsFused {
                windows,
                chunk: j.iterations.div_ceil(windows).clamp(1, s_max),
            },
            other => other,
        })
        .collect();
    DecodePolicy::PerBlock { modes }
}

impl DecodePolicy {
    /// Serialize to JSON (calibration persistence: `sjd calibrate` writes
    /// this; `sjd serve --policy @file.json` loads it).
    pub fn to_json(&self) -> crate::jsonx::Value {
        use crate::jsonx::Value;
        match self {
            DecodePolicy::Sequential => Value::obj(vec![("kind", Value::str("sequential"))]),
            DecodePolicy::UniformJacobi => Value::obj(vec![("kind", Value::str("ujd"))]),
            DecodePolicy::Selective { seq_blocks } => Value::obj(vec![
                ("kind", Value::str("selective")),
                ("seq_blocks", Value::num(*seq_blocks as f64)),
            ]),
            DecodePolicy::GsJacobi { windows } => Value::obj(vec![
                ("kind", Value::str("gs")),
                ("windows", Value::num(*windows as f64)),
            ]),
            DecodePolicy::Fused { chunk } => Value::obj(vec![
                ("kind", Value::str("fuse")),
                ("chunk", Value::num(*chunk as f64)),
            ]),
            DecodePolicy::Custom { jacobi_mask } => Value::obj(vec![
                ("kind", Value::str("custom")),
                (
                    "jacobi_mask",
                    Value::Arr(jacobi_mask.iter().map(|&b| Value::Bool(b)).collect()),
                ),
            ]),
            DecodePolicy::PerBlock { modes } => Value::obj(vec![
                ("kind", Value::str("per_block")),
                ("modes", Value::Arr(modes.iter().map(|m| m.to_json()).collect())),
            ]),
        }
    }

    /// Deserialize from JSON.
    pub fn from_json(v: &crate::jsonx::Value) -> anyhow::Result<Self> {
        use crate::jsonx::Value;
        match v.req_str("kind")? {
            "sequential" => Ok(DecodePolicy::Sequential),
            "ujd" => Ok(DecodePolicy::UniformJacobi),
            "selective" => Ok(DecodePolicy::Selective {
                seq_blocks: v.get("seq_blocks").and_then(Value::as_usize).unwrap_or(1),
            }),
            "gs" => Ok(DecodePolicy::GsJacobi { windows: windows_from_json(v)? }),
            "fuse" => Ok(DecodePolicy::Fused { chunk: chunk_from_json(v)? }),
            "custom" => {
                let mask = v
                    .req_arr("jacobi_mask")?
                    .iter()
                    .map(|b| b.as_bool().ok_or_else(|| anyhow::anyhow!("bad mask entry")))
                    .collect::<anyhow::Result<Vec<bool>>>()?;
                Ok(DecodePolicy::Custom { jacobi_mask: mask })
            }
            "per_block" => {
                let modes = v
                    .req_arr("modes")?
                    .iter()
                    .map(BlockDecode::from_json)
                    .collect::<anyhow::Result<Vec<_>>>()?;
                Ok(DecodePolicy::PerBlock { modes })
            }
            other => anyhow::bail!("unknown policy kind '{other}'"),
        }
    }

    /// Load from a `@path.json` reference or parse as a CLI string.
    pub fn parse_or_load(s: &str) -> anyhow::Result<Self> {
        if let Some(path) = s.strip_prefix('@') {
            let text = std::fs::read_to_string(path)?;
            return Self::from_json(&crate::jsonx::parse(&text)?);
        }
        Self::parse(s).ok_or_else(|| anyhow::anyhow!("bad policy '{s}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn parse_variants() {
        assert_eq!(DecodePolicy::parse("sequential"), Some(DecodePolicy::Sequential));
        assert_eq!(DecodePolicy::parse("ujd"), Some(DecodePolicy::UniformJacobi));
        assert_eq!(
            DecodePolicy::parse("selective"),
            Some(DecodePolicy::Selective { seq_blocks: 1 })
        );
        assert_eq!(
            DecodePolicy::parse("selective:2"),
            Some(DecodePolicy::Selective { seq_blocks: 2 })
        );
        assert_eq!(
            DecodePolicy::parse("gs"),
            Some(DecodePolicy::GsJacobi { windows: DEFAULT_GS_WINDOWS })
        );
        assert_eq!(DecodePolicy::parse("gs:8"), Some(DecodePolicy::GsJacobi { windows: 8 }));
        assert_eq!(
            DecodePolicy::parse("fuse"),
            Some(DecodePolicy::Fused { chunk: DEFAULT_FUSE_CHUNK })
        );
        assert_eq!(DecodePolicy::parse("fuse:4"), Some(DecodePolicy::Fused { chunk: 4 }));
        assert_eq!(DecodePolicy::parse("wat"), None);
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "Sequential", "SJD", "selective:", "selective:x", "selective:-1",
            "selective:1.5", "gs:", "gs:0", "gs:abc", "gs:-2", "gs :4", "ujd ",
            "@", "custom", "fuse:", "fuse:0", "fuse:x", "fuse:-3", "fuse :2",
        ] {
            assert_eq!(DecodePolicy::parse(bad), None, "'{bad}' must be rejected");
        }
    }

    #[test]
    fn init_strategy_parse_rejects_malformed() {
        use super::super::jacobi::InitStrategy;
        for bad in ["", "Zeros", "NORMAL", "prev-layer", "zeros ", "random", "0"] {
            assert_eq!(InitStrategy::parse(bad), None, "'{bad}' must be rejected");
        }
    }

    #[test]
    fn selective_matches_paper() {
        // Paper: sequential on the first layer only, Jacobi on the rest.
        let p = DecodePolicy::Selective { seq_blocks: 1 };
        assert!(!p.use_jacobi(0, 4));
        assert!(p.use_jacobi(1, 4));
        assert!(p.use_jacobi(3, 4));
    }

    #[test]
    fn uniform_and_sequential() {
        assert!(DecodePolicy::UniformJacobi.use_jacobi(0, 4));
        assert!(!DecodePolicy::Sequential.use_jacobi(3, 4));
    }

    #[test]
    fn custom_mask() {
        let p = DecodePolicy::Custom { jacobi_mask: vec![false, true, false] };
        assert!(!p.use_jacobi(0, 3));
        assert!(p.use_jacobi(1, 3));
        assert!(!p.use_jacobi(2, 3));
    }

    fn mk_stats(block: usize, iters: usize, ms: u64, converged: bool) -> JacobiStats {
        JacobiStats {
            block,
            iterations: iters,
            wall: Duration::from_millis(ms),
            residuals: vec![],
            converged,
            host_syncs: iters,
        }
    }

    #[test]
    fn calibrate_prefers_faster_converged() {
        let mk = mk_stats;
        let jacobi = vec![
            mk(0, 64, 900, true),  // slower than seq → sequential
            mk(1, 5, 50, true),    // faster → jacobi
            mk(2, 64, 10, false),  // failed to converge → sequential
        ];
        let seq = vec![
            Duration::from_millis(500),
            Duration::from_millis(500),
            Duration::from_millis(500),
        ];
        let p = calibrate(&jacobi, &seq);
        assert_eq!(
            p,
            DecodePolicy::Custom { jacobi_mask: vec![false, true, false] }
        );
    }

    #[test]
    fn json_roundtrip_all_variants() {
        for p in [
            DecodePolicy::Sequential,
            DecodePolicy::UniformJacobi,
            DecodePolicy::Selective { seq_blocks: 2 },
            DecodePolicy::GsJacobi { windows: 6 },
            DecodePolicy::Fused { chunk: 5 },
            DecodePolicy::Custom { jacobi_mask: vec![false, true, true] },
            DecodePolicy::PerBlock {
                modes: vec![
                    BlockDecode::Sequential,
                    BlockDecode::Jacobi,
                    BlockDecode::GsJacobi { windows: 8 },
                    BlockDecode::Fused { chunk: 3 },
                    BlockDecode::GsFused { windows: 4, chunk: 2 },
                ],
            },
        ] {
            let j = p.to_json();
            let back = DecodePolicy::from_json(&j).unwrap();
            assert_eq!(p, back);
        }
    }

    #[test]
    fn json_rejects_bad_gs_windows() {
        use crate::jsonx::Value;
        let v = Value::obj(vec![("kind", Value::str("gs")), ("windows", Value::num(0.0))]);
        assert!(DecodePolicy::from_json(&v).is_err());
        // Present-but-malformed must error, never silently default.
        for bad in [Value::num(2.5), Value::num(-3.0), Value::str("four")] {
            let v = Value::obj(vec![("kind", Value::str("gs")), ("windows", bad)]);
            assert!(DecodePolicy::from_json(&v).is_err());
        }
        // Absent windows falls back to the documented default.
        let v = Value::obj(vec![("kind", Value::str("gs"))]);
        assert_eq!(
            DecodePolicy::from_json(&v).unwrap(),
            DecodePolicy::GsJacobi { windows: DEFAULT_GS_WINDOWS }
        );
        let modes = Value::Arr(vec![Value::obj(vec![("mode", Value::str("warp"))])]);
        let v = Value::obj(vec![("kind", Value::str("per_block")), ("modes", modes)]);
        assert!(DecodePolicy::from_json(&v).is_err());
    }

    #[test]
    fn json_rejects_bad_fuse_chunk() {
        use crate::jsonx::Value;
        for bad in [Value::num(0.0), Value::num(1.5), Value::num(-2.0), Value::str("two")] {
            let v = Value::obj(vec![("kind", Value::str("fuse")), ("chunk", bad)]);
            assert!(DecodePolicy::from_json(&v).is_err());
        }
        // Absent chunk falls back to the documented default.
        let v = Value::obj(vec![("kind", Value::str("fuse"))]);
        assert_eq!(
            DecodePolicy::from_json(&v).unwrap(),
            DecodePolicy::Fused { chunk: DEFAULT_FUSE_CHUNK }
        );
        // Same strictness on the per-block gs_fuse mode.
        let modes = Value::Arr(vec![Value::obj(vec![
            ("mode", Value::str("gs_fuse")),
            ("chunk", Value::num(0.0)),
        ])]);
        let v = Value::obj(vec![("kind", Value::str("per_block")), ("modes", modes)]);
        assert!(DecodePolicy::from_json(&v).is_err());
    }

    #[test]
    fn fused_policy_block_mode_and_label() {
        let p = DecodePolicy::Fused { chunk: 6 };
        assert_eq!(p.block_mode(0, 4), BlockDecode::Fused { chunk: 6 });
        assert!(p.use_jacobi(0, 4), "fused decode is a Jacobi-family mode");
        assert_eq!(p.label(), "Fused(S=6)");
    }

    #[test]
    fn calibrate_chunks_seeds_from_iteration_traces() {
        let mk = mk_stats;
        let seq_len = 64;
        let jacobi = vec![
            mk(0, 60, 100, true),  // hard: max windows, per-window chunk share
            mk(1, 4, 100, true),   // easy: plain fused, chunk = measured iters
            mk(2, 64, 100, false), // no converge → sequential, untouched
            mk(3, 2, 900, true),   // slower than sequential → sequential
        ];
        let seq = vec![Duration::from_millis(500); 4];
        let p = calibrate_chunks(&jacobi, &seq, seq_len, 8, 8);
        assert_eq!(
            p,
            DecodePolicy::PerBlock {
                modes: vec![
                    // 60/64 · 8 → 8 windows; ⌈60/8⌉ = 8 chunk share.
                    BlockDecode::GsFused { windows: 8, chunk: 8 },
                    BlockDecode::Fused { chunk: 4 },
                    BlockDecode::Sequential,
                    BlockDecode::Sequential,
                ],
            }
        );
        // s_max caps every learned chunk: the same traces under a shorter
        // fused history never schedule past the device cap.
        let p = calibrate_chunks(&jacobi, &seq, seq_len, 8, 2);
        let DecodePolicy::PerBlock { modes } = p else { unreachable!() };
        assert_eq!(modes[0], BlockDecode::GsFused { windows: 8, chunk: 2 });
        assert_eq!(modes[1], BlockDecode::Fused { chunk: 2 });
        // JSON round-trip covers the learned fused modes.
        let p = calibrate_chunks(&jacobi, &seq, seq_len, 8, 8);
        assert_eq!(DecodePolicy::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn block_modes_per_policy() {
        let gs = DecodePolicy::GsJacobi { windows: 3 };
        assert_eq!(gs.block_mode(0, 4), BlockDecode::GsJacobi { windows: 3 });
        assert!(gs.use_jacobi(0, 4));

        let pb = DecodePolicy::PerBlock {
            modes: vec![
                BlockDecode::Sequential,
                BlockDecode::GsJacobi { windows: 2 },
                BlockDecode::Jacobi,
            ],
        };
        assert_eq!(pb.block_mode(0, 4), BlockDecode::Sequential);
        assert_eq!(pb.block_mode(1, 4), BlockDecode::GsJacobi { windows: 2 });
        assert_eq!(pb.block_mode(2, 4), BlockDecode::Jacobi);
        // Positions past the learned vector default to Jacobi (like Custom).
        assert_eq!(pb.block_mode(3, 4), BlockDecode::Jacobi);
        assert!(!pb.use_jacobi(0, 4));
        assert!(pb.use_jacobi(1, 4));
    }

    #[test]
    fn calibrate_windows_scales_with_iteration_ratio() {
        let mk = mk_stats;
        let seq_len = 64;
        let jacobi = vec![
            mk(0, 60, 100, true),  // hard: t ≈ L → max windows
            mk(1, 4, 100, true),   // easy: t ≪ L → plain Jacobi
            mk(2, 32, 100, true),  // middling → intermediate window count
            mk(3, 64, 100, false), // no converge → sequential
            mk(4, 4, 900, true),   // slower than sequential → sequential
        ];
        let seq = vec![Duration::from_millis(500); 5];
        let p = calibrate_windows(&jacobi, &seq, seq_len, 8);
        assert_eq!(
            p,
            DecodePolicy::PerBlock {
                modes: vec![
                    BlockDecode::GsJacobi { windows: 8 },
                    BlockDecode::Jacobi,
                    BlockDecode::GsJacobi { windows: 4 },
                    BlockDecode::Sequential,
                    BlockDecode::Sequential,
                ],
            }
        );
        assert_eq!(p.label(), "Adaptive-GS");
    }

    #[test]
    fn parse_or_load_file() {
        let p = DecodePolicy::Custom { jacobi_mask: vec![false, true] };
        let path = std::env::temp_dir().join("sjd_policy_test.json");
        std::fs::write(&path, crate::jsonx::to_string_pretty(&p.to_json())).unwrap();
        let loaded =
            DecodePolicy::parse_or_load(&format!("@{}", path.display())).unwrap();
        assert_eq!(loaded, p);
        // Plain strings still parse.
        assert_eq!(
            DecodePolicy::parse_or_load("ujd").unwrap(),
            DecodePolicy::UniformJacobi
        );
        assert!(DecodePolicy::parse_or_load("nope").is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(DecodePolicy::Sequential.label(), "Sequential");
        assert_eq!(DecodePolicy::Selective { seq_blocks: 1 }.label(), "SJD");
        assert_eq!(DecodePolicy::UniformJacobi.label(), "UJD");
    }
}
