//! Shared helpers for the paper-experiment benches.
//!
//! Every bench binary regenerates one table/figure of the paper. They skip
//! gracefully (exit 0 with a message) when `artifacts/` has not been built,
//! so `cargo bench` works in a fresh checkout.

#![allow(dead_code)]

use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::runtime::Engine;
use sjd::tensor::{Pcg64, Tensor};

pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("SJD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Load the engine, or exit 0 with a skip message (CI without artifacts).
pub fn engine_or_skip() -> Engine {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP: {} missing — run `make artifacts`", dir.join("manifest.json").display());
        std::process::exit(0);
    }
    match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine init failed: {e:#}");
            std::process::exit(1);
        }
    }
}

/// `--quick` in bench argv (or SJD_QUICK=1) shrinks sample counts.
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

/// Map the repo's model names to the paper's dataset labels.
pub fn paper_label(model: &str) -> &'static str {
    match model {
        "tf10" => "CIFAR-10 (synth10)",
        "tf100" => "CIFAR-100 (synth100)",
        "tfafhq" => "AFHQ (synthafhq)",
        _ => "?",
    }
}

/// Dataset name backing a tarflow model.
pub fn dataset_for(model: &str) -> &'static str {
    match model {
        "tf10" => "synth10",
        "tf100" => "synth100",
        "tfafhq" => "synthafhq",
        _ => panic!("unknown model {model}"),
    }
}

/// Metric network matching a model's resolution.
pub fn metricnet_for(model: &str) -> &'static str {
    match model {
        "tfafhq" => "metricnet32",
        _ => "metricnet16",
    }
}

/// Generate `n` images under `policy`, returning (images, wall seconds,
/// total jacobi iters, per-position step counts accumulated).
pub struct GenRun {
    pub images: Vec<Tensor>,
    pub wall: f64,
    pub batches: usize,
    pub per_position_steps: Vec<Vec<usize>>,
    pub per_position_wall: Vec<Vec<f64>>,
    pub other_wall: f64,
}

pub fn generate(
    sampler: &Sampler<Engine>,
    policy: DecodePolicy,
    tau: f32,
    n_images: usize,
    seed: u64,
) -> anyhow::Result<GenRun> {
    let mut opts = SampleOptions { policy, ..Default::default() };
    opts.jacobi.tau = tau;
    let kk = sampler.meta.blocks;
    let mut run = GenRun {
        images: Vec::with_capacity(n_images),
        wall: 0.0,
        batches: 0,
        per_position_steps: vec![Vec::new(); kk],
        per_position_wall: vec![Vec::new(); kk],
        other_wall: 0.0,
    };
    let mut rng = Pcg64::seed(seed);
    while run.images.len() < n_images {
        opts.seed = seed.wrapping_add(run.batches as u64);
        let (imgs, out) = sampler.sample_images(&opts, &mut rng)?;
        run.wall += out.total_wall.as_secs_f64();
        run.other_wall += out.other_wall.as_secs_f64();
        for t in &out.traces {
            run.per_position_steps[t.position].push(t.steps);
            run.per_position_wall[t.position].push(t.wall.as_secs_f64());
        }
        run.batches += 1;
        for img in imgs {
            if run.images.len() < n_images {
                run.images.push(img);
            }
        }
    }
    Ok(run)
}

pub fn mean_usize(v: &[usize]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<usize>() as f64 / v.len() as f64
}

pub fn mean_f64(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<f64>() / v.len() as f64
}
