//! **Table A6**: context comparison — our accelerated flow vs an MMD
//! generator (FastGAN substitute) and 20-step DDIM on the CIFAR-10 stand-in:
//! inference time + proxy-FID.

mod common;

use common::*;
use sjd::benchkit::{time_fn, Report};
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;
use sjd::quality::evaluate_quality;
use sjd::runtime::{Engine, HostTensor};
use sjd::tensor::{Pcg64, Tensor};

/// 20-step DDIM sampler over the `ddpm_eps_b{B}` artifact (deterministic,
/// eta = 0).
fn ddim_sample(
    engine: &Engine,
    batch: usize,
    timesteps: usize,
    steps: usize,
    hw: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<Vec<Tensor>> {
    let artifact = format!("ddpm_eps_b{batch}");
    // Linear beta schedule must match python's ddpm_schedule.
    let betas: Vec<f64> = (0..timesteps)
        .map(|i| 1e-4 + (0.02 - 1e-4) * i as f64 / (timesteps - 1) as f64)
        .collect();
    let mut abars = Vec::with_capacity(timesteps);
    let mut acc = 1.0;
    for b in &betas {
        acc *= 1.0 - b;
        abars.push(acc);
    }
    let shape = [batch, hw, hw, 3];
    let mut x = Tensor::randn(&shape, rng);
    let plan: Vec<usize> = (0..steps)
        .map(|i| (timesteps - 1) - i * (timesteps - 1) / (steps - 1).max(1))
        .collect();
    for (si, &t) in plan.iter().enumerate() {
        let out = engine.call(
            &artifact,
            &[HostTensor::f32(&shape, x.data().to_vec()), HostTensor::scalar_i32(t as i32)],
        )?;
        let eps = out.into_iter().next().unwrap();
        let eps = Tensor::new(&shape, eps.into_f32()?)?;
        let ab_t = abars[t];
        let ab_prev = if si + 1 < plan.len() { abars[plan[si + 1]] } else { 1.0 };
        // x0 estimate, then DDIM deterministic step.
        let x0 = x
            .zip_map(&eps, |xt, e| {
                ((xt as f64 - (1.0 - ab_t).sqrt() * e as f64) / ab_t.sqrt()) as f32
            })?
            .clamp(-1.5, 1.5);
        x = x0.zip_map(&eps, |x0v, e| {
            (ab_prev.sqrt() * x0v as f64 + (1.0 - ab_prev).sqrt() * e as f64) as f32
        })?;
    }
    // Split into per-image tensors.
    let hwc = hw * hw * 3;
    Ok((0..batch)
        .map(|i| Tensor::new(&[hw, hw, 3], x.data()[i * hwc..(i + 1) * hwc].to_vec()).unwrap())
        .collect())
}

fn mmd_generate(
    engine: &Engine,
    batch: usize,
    z_dim: usize,
    hw: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<Vec<Tensor>> {
    let artifact = format!("mmdgen_gen_b{batch}");
    let z = Tensor::randn(&[batch, z_dim], rng);
    let out = engine.call(&artifact, &[HostTensor::f32(&[batch, z_dim], z.into_data())])?;
    let imgs = out.into_iter().next().unwrap().into_f32()?;
    let hwc = hw * hw * 3;
    Ok((0..batch)
        .map(|i| Tensor::new(&[hw, hw, 3], imgs[i * hwc..(i + 1) * hwc].to_vec()).unwrap())
        .collect())
}

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    for needed in ["ddpm", "mmdgen", "tf10"] {
        if engine.manifest().model(needed).is_err() {
            println!("SKIP: model '{needed}' not in manifest");
            return Ok(());
        }
    }
    let reference = engine.manifest().load_dataset("synth10")?;
    let n = if quick() { 8 } else { 64 };
    let batch = 8;
    let timesteps = engine
        .manifest()
        .model("ddpm")?
        .extra
        .get("timesteps")
        .and_then(|v| v.as_usize())
        .unwrap_or(200);
    let z_dim = engine
        .manifest()
        .model("mmdgen")?
        .extra
        .get("z_dim")
        .and_then(|v| v.as_usize())
        .unwrap_or(64);

    let mut report = Report::new("Table A6 — vs MMD generator (FastGAN sub) and DDIM-20");
    let mut rows = Vec::new();

    // MMD generator.
    let mut rng = Pcg64::seed(5);
    let mut gan_imgs = Vec::new();
    let t = time_fn(1, n / batch, || {
        let imgs = mmd_generate(&engine, batch, z_dim, 16, &mut rng).unwrap();
        gan_imgs.extend(imgs);
    });
    gan_imgs.truncate(n);
    let q = evaluate_quality(&engine, "metricnet16", &gan_imgs, &reference)?;
    rows.push(vec!["MMD-Gen (FastGAN sub)".into(), format!("{:.3}", t.mean_secs()), format!("{:.2}", q.fid)]);
    println!("mmdgen: {:.3}s/batch FID* {:.2}", t.mean_secs(), q.fid);

    // DDIM 20 steps.
    let mut rng = Pcg64::seed(6);
    let mut ddim_imgs = Vec::new();
    let t = time_fn(1, n / batch, || {
        let imgs = ddim_sample(&engine, batch, timesteps, 20, 16, &mut rng).unwrap();
        ddim_imgs.extend(imgs);
    });
    ddim_imgs.truncate(n);
    let q = evaluate_quality(&engine, "metricnet16", &ddim_imgs, &reference)?;
    rows.push(vec!["DDIM (20 steps)".into(), format!("{:.3}", t.mean_secs()), format!("{:.2}", q.fid)]);
    println!("ddim-20: {:.3}s/batch FID* {:.2}", t.mean_secs(), q.fid);

    // Ours: tf10 with SJD.
    let sampler = Sampler::new(&engine, "tf10", batch)?;
    let _ = generate(&sampler, DecodePolicy::Selective { seq_blocks: 1 }, 0.5, batch, 1)?;
    let run = generate(&sampler, DecodePolicy::Selective { seq_blocks: 1 }, 0.5, n, 42)?;
    let q = evaluate_quality(&engine, "metricnet16", &run.images, &reference)?;
    rows.push(vec![
        "Ours (TarFlow + SJD)".into(),
        format!("{:.3}", run.wall / run.batches as f64),
        format!("{:.2}", q.fid),
    ]);
    println!("ours: {:.3}s/batch FID* {:.2}", run.wall / run.batches as f64, q.fid);

    report.table(&["Method", "Time/batch (s)", "FID*"], &rows);
    report.note("Paper shape: ours competitive with single-pass GAN on speed at better/comparable FID than DDIM-20.");
    report.finish();
    Ok(())
}
