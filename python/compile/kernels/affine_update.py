"""L1 Pallas kernel: fused affine inverse update + convergence residual.

The body of the paper's Alg 1 — ``z' = y ⊙ exp(−s) + g`` with the first
token passed through, fused with the stopping-criterion reduction
``‖z' − z^t‖∞`` so the iterate update and the residual need a single VMEM
pass (the unfused form reads z', z^t again from HBM for the norm).

Grid is (B,): one program per batch element over an (L, D) tile — for the
model sizes here (L ≤ 256, D = 12) that is ≤ 12 KB per operand, far under
VMEM. The reduction output is a (1,) tile per program.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(z_ref, y_ref, s_ref, g_ref, out_ref, resid_ref):
    z_prev = z_ref[0]  # (L, D)
    y = y_ref[0]
    s = s_ref[0]
    g = g_ref[0]
    z_next = y * jnp.exp(-s) + g
    # First token is copied through (eq 5: z_{k,1} = z_{k+1,1}).
    l, d = z_next.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, d), 0)
    z_next = jnp.where(rows == 0, y, z_next)
    out_ref[0] = z_next
    resid_ref[0] = jnp.max(jnp.abs(z_next - z_prev))


@functools.partial(jax.jit, static_argnames=("interpret",))
def affine_inverse_update(z_prev, y, s, g, interpret=True):
    """Fused Jacobi update + residual.

    Args:
      z_prev, y, s, g: (B, L, D) f32

    Returns:
      (z_next (B, L, D), resid (B,))
    """
    b, l, d = z_prev.shape
    spec = pl.BlockSpec((1, l, d), lambda i: (i, 0, 0))
    rspec = pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        _update_kernel,
        grid=(b,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, rspec],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, d), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(z_prev, y, s, g)


def _update_window_kernel(win_ref, z_ref, y_ref, s_ref, g_ref, out_ref, resid_ref):
    """Windowed GS-Jacobi update: only rows in [off, off+len) move.

    ``win_ref`` is a (2,) i32 tile holding (offset, length). Rows left of the
    window are the frozen converged prefix (they condition the (s, g) net but
    are copied through verbatim); rows right of it have not been reached by
    the Gauss–Seidel sweep yet. Because frozen rows satisfy z' == z, the
    plain ‖z' − z‖∞ reduction *is* the windowed residual — no second mask
    pass is needed for the τ test.
    """
    off = win_ref[0]
    wlen = win_ref[1]
    z_prev = z_ref[0]  # (L, D)
    y = y_ref[0]
    s = s_ref[0]
    g = g_ref[0]
    z_next = y * jnp.exp(-s) + g
    l, d = z_next.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, d), 0)
    # First token is copied through (eq 5: z_{k,1} = z_{k+1,1}).
    z_next = jnp.where(rows == 0, y, z_next)
    # Freeze everything outside the active window.
    in_window = (rows >= off) & (rows < off + wlen)
    z_next = jnp.where(in_window, z_next, z_prev)
    out_ref[0] = z_next
    resid_ref[0] = jnp.max(jnp.abs(z_next - z_prev))


@functools.partial(jax.jit, static_argnames=("interpret",))
def affine_inverse_update_window(z_prev, y, s, g, off, wlen, interpret=True):
    """Fused windowed Jacobi update + windowed residual (GS-Jacobi inner step).

    Args:
      z_prev, y, s, g: (B, L, D) f32
      off, wlen: scalar i32 window offset / length (traced; passed to the
        kernel as one (2,) tile)

    Returns:
      (z_next (B, L, D), resid (B,)) — z_next differs from z_prev only on
      positions [off, off+wlen), and resid is the ‖·‖∞ residual over exactly
      those positions.
    """
    b, l, d = z_prev.shape
    win = jnp.stack([jnp.asarray(off, jnp.int32), jnp.asarray(wlen, jnp.int32)])
    spec = pl.BlockSpec((1, l, d), lambda i: (i, 0, 0))
    rspec = pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        _update_window_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((2,), lambda i: (0,)), spec, spec, spec, spec],
        out_specs=[spec, rspec],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, d), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(win, z_prev, y, s, g)


def _init_kernel(y_ref, s_ref, g_ref, out_ref):
    """Speculative z⁰ extrapolation: the Alg 1 affine body evaluated once
    with the conditioner run on the block input ``y`` itself. No residual
    output — the result seeds the Jacobi solve, it is not an iterate under
    the τ test — so the program lowers with a single (chainable) root."""
    y = y_ref[0]  # (L, D)
    s = s_ref[0]
    g = g_ref[0]
    z0 = y * jnp.exp(-s) + g
    l, d = z0.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, d), 0)
    out_ref[0] = jnp.where(rows == 0, y, z0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def init_extrapolate(y, s, g, interpret=True):
    """Fused speculative-init extrapolation (see :func:`ref.init_extrapolate_ref`).

    Args:
      y, s, g: (B, L, D) f32

    Returns:
      z0 (B, L, D) with z0[:, 0] = y[:, 0]
    """
    b, l, d = y.shape
    spec = pl.BlockSpec((1, l, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _init_kernel,
        grid=(b,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, l, d), jnp.float32),
        interpret=interpret,
    )(y, s, g)


def vmem_bytes_estimate(l: int, d: int) -> int:
    """Per-program VMEM working set: four input tiles + output tile, f32."""
    return 4 * (5 * l * d)
