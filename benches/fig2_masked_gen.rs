//! **Fig 2**: generations with the o nearest dependencies masked (eq 6) —
//! the images should degrade gradually with o but remain meaningful,
//! demonstrating exploitable redundancy.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::imageio::{compose_grid, write_png, Image};
use sjd::quality::evaluate_quality;
use sjd::tensor::Pcg64;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = "tf10";
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let reference = engine.manifest().load_dataset(dataset_for(model))?;
    let n = if quick() { batch } else { 32 };

    let mut report = Report::new("Fig 2 — generations with o-masked dependencies");
    let mut rows = Vec::new();
    let mut strips: Vec<Image> = Vec::new();

    for o in [0usize, 1, 2, 5] {
        let mut opts = SampleOptions {
            policy: DecodePolicy::UniformJacobi,
            mask_o: o,
            ..Default::default()
        };
        // Run masked decoding to its exact fixed point (= the paper's masked
        // sequential inference) rather than τ-early-stopping.
        opts.jacobi.tau = 1e-5;
        let mut rng = Pcg64::seed(3);
        let mut images = Vec::new();
        while images.len() < n {
            let (imgs, _) = sampler.sample_images(&opts, &mut rng)?;
            images.extend(imgs);
        }
        images.truncate(n);
        let q = evaluate_quality(&engine, metricnet_for(model), &images, &reference)?;
        println!("o={o}: FID* {:.2} IQA* {:.3}", q.fid, q.clip_iqa);
        rows.push(vec![format!("{o}"), format!("{:.2}", q.fid), format!("{:.3}", q.clip_iqa)]);
        for img in images.iter().take(8) {
            strips.push(Image::from_tensor_pm1(img)?);
        }
    }

    let grid = compose_grid(&strips, 8, 2);
    let out = artifacts_dir().join("fig2_masked_generations.png");
    write_png(&grid, &out)?;
    report.table(&["o (masked deps)", "FID*", "CLIP-IQA*"], &rows);
    report.note(format!("sample sheet: {} (rows: o = 0, 1, 2, 5)", out.display()));
    report.note("Paper shape: quality degrades gradually with o; images stay meaningful.");
    report.finish();
    Ok(())
}
