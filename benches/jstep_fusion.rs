//! **Fused multi-step Jacobi chunking**: artifact dispatches, blocking host
//! syncs and wall time of the per-iteration UJD decode vs the chunked fused
//! decode (`jacobi_decode_block_fused_v`), over the **mock backend** — no
//! artifacts needed, so it runs everywhere (including the CI smoke step).
//!
//! The mock charges every jstep-family call a fixed dispatch/sync overhead
//! (`CALL_OVERHEAD` — the launch + blocking round-trip latency chunking
//! exists to amortize) plus batch- and step-proportional kernel time
//! (`SLOT_DELAY` — fusing removes round-trips, never compute). The
//! acceptance gate mirrors the mock-ledger test in
//! `rust/tests/mock_backend.rs`: at τ = 0 the fused decode must produce
//! **bit-identical tokens** while performing strictly fewer host syncs
//! (`⌈iterations/S⌉` per block instead of `iterations`); the default-τ rows
//! are reported for the convergent regime. Exits non-zero if chunking fails
//! to reduce host syncs at equal output.
//!
//! ```bash
//! cargo bench --bench jstep_fusion            # full run
//! cargo bench --bench jstep_fusion -- --quick # CI smoke
//! ```

use anyhow::Result;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::runtime::HostTensor;
use sjd::tensor::Pcg64;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::time::Duration;

/// Per-step kernel time (× batch × fused steps — compute is never faked away).
const SLOT_DELAY: Duration = Duration::from_micros(30);
/// Per-call dispatch + blocking-sync overhead (what chunking amortizes).
const CALL_OVERHEAD: Duration = Duration::from_micros(500);

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

struct Run {
    label: String,
    tokens: Vec<HostTensor>,
    iters: usize,
    syncs: usize,
    dispatches: usize,
    wall: f64,
}

fn run(policy: DecodePolicy, tau: f32, repeats: usize) -> Result<Run> {
    let ledger = MockLedger::new();
    let be = MockServeBackend::new(&[2], SLOT_DELAY, ledger.clone())
        .with_call_overhead(CALL_OVERHEAD);
    let sampler = Sampler::new(&be, "mock", 2)?;
    let label = format!("{} τ={tau}", policy.label());
    let mut opts = SampleOptions { policy, ..Default::default() };
    opts.jacobi.tau = tau;
    let mut out_tokens = Vec::with_capacity(repeats);
    let (mut iters, mut syncs) = (0usize, 0usize);
    let mut wall = 0.0f64;
    for r in 0..repeats {
        opts.seed = 42 + r as u64;
        let mut rng = Pcg64::seed(opts.seed);
        let z = sampler.sample_prior(&mut rng);
        let out = sampler.decode_tokens(z, &opts)?;
        iters += out.total_jacobi_iters();
        syncs += out.total_host_syncs();
        wall += out.total_wall.as_secs_f64();
        out_tokens.push(out.tokens);
    }
    Ok(Run {
        label,
        tokens: out_tokens,
        iters,
        syncs,
        dispatches: ledger.count_containing("jstep"),
        wall,
    })
}

fn main() -> Result<()> {
    let repeats = if quick() { 2 } else { 8 };
    println!(
        "=== jstep_fusion: per-iteration vs chunked fused decode \
         ({repeats} decodes per config, mock backend) ==="
    );
    let mut report = Report::new(
        "Fused multi-step Jacobi — host syncs / dispatches / wall vs per-iteration UJD",
    );

    // τ = 0: every block runs its full L-iteration exactness sweep on both
    // paths, so the outputs must be bit-identical — the equal-output gate.
    let base0 = run(DecodePolicy::UniformJacobi, 0.0, repeats)?;
    let fuse0 = run(DecodePolicy::Fused { chunk: 4 }, 0.0, repeats)?;
    // Default τ = 0.5: the convergent serving regime (reported; the τ-stop
    // iterate may carry documented overshoot steps, so the bitwise gate
    // applies to the τ=0 rows only).
    let base5 = run(DecodePolicy::UniformJacobi, 0.5, repeats)?;
    let fuse5 = run(DecodePolicy::Fused { chunk: 4 }, 0.5, repeats)?;

    let rows: Vec<Vec<String>> = [&base0, &fuse0, &base5, &fuse5]
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                r.dispatches.to_string(),
                r.syncs.to_string(),
                r.iters.to_string(),
                format!("{:.3}", r.wall),
            ]
        })
        .collect();
    for r in [&base0, &fuse0, &base5, &fuse5] {
        println!(
            "{:>16}: {:>4} dispatches, {:>4} host syncs, {:>4} iters, {:.3}s",
            r.label, r.dispatches, r.syncs, r.iters, r.wall
        );
    }
    report.table(&["config", "jstep dispatches", "host syncs", "iterations", "wall (s)"], &rows);

    let equal_output = base0.tokens == fuse0.tokens;
    let syncs_reduced = fuse0.syncs < base0.syncs && fuse5.syncs < base5.syncs;
    let pass = equal_output && syncs_reduced;
    report.note(if pass {
        "PASS: chunked fused decode produced bit-identical τ=0 output with \
         strictly fewer host syncs (and fewer again at the default τ)."
    } else {
        "FAIL: chunking must reduce host syncs at equal output."
    });
    report.note(format!(
        "τ=0 host syncs {} → {} ({}×, dispatches {} → {}); wall {:.3}s → {:.3}s. \
         Per block the sync count falls from `iterations` to ⌈iterations/S⌉ \
         (S = fused history length).",
        base0.syncs,
        fuse0.syncs,
        base0.syncs / fuse0.syncs.max(1),
        base0.dispatches,
        fuse0.dispatches,
        base0.wall,
        fuse0.wall,
    ));
    report.finish();
    anyhow::ensure!(equal_output, "fused τ=0 output diverged from the per-iteration decode");
    anyhow::ensure!(syncs_reduced, "fused decode did not reduce host syncs");
    Ok(())
}
