"""L2 TarFlow model invariants: invertibility, logdet correctness, seqstep ≡
exact inverse, Jacobi finite convergence (Prop 3.2), masked-redundancy
behaviour, patchify round trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import tarflow


@pytest.fixture(scope="module")
def small():
    cfg = tarflow.TarFlowConfig(
        name="t", img_hw=8, channels=3, patch=2, blocks=3, layers_per_block=2,
        model_dim=32, heads=4, noise_std=0.05, dataset="synth10",
        train_steps=1, train_batch=4, lr=1e-3)
    params = tarflow.init_params(jax.random.PRNGKey(0), cfg)
    # Perturb so the flow is not the identity.
    key = jax.random.PRNGKey(99)
    params["out_w"] = 0.1 * jax.random.normal(key, params["out_w"].shape)
    params["out_b"] = 0.05 * jax.random.normal(key, params["out_b"].shape)
    return cfg, params


class TestInvertibility:
    def test_block_forward_then_exact_inverse(self, small):
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.seq_len, cfg.token_dim))
        for k in range(cfg.blocks):
            v, _ = tarflow.block_forward(params, cfg, k, u)
            u_rec = tarflow.block_inverse_exact(params, cfg, k, v)
            np.testing.assert_allclose(np.asarray(u_rec), np.asarray(u), atol=1e-4)

    def test_first_token_identity(self, small):
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 0, u)
        np.testing.assert_allclose(np.asarray(v)[:, 0], np.asarray(u)[:, 0], atol=1e-6)

    def test_full_flow_roundtrip(self, small):
        """Encode then rust-style decode (Jacobi-exact per block + reversal)."""
        cfg, params = small
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 8, 3)) * 0.5
        z, _ = tarflow.flow_forward(params, cfg, x)
        # Decode: h_k = P_k(A_k^{-1}(h_{k+1})), k = K-1 .. 0.
        h = z
        for k in reversed(range(cfg.blocks)):
            u = tarflow.block_inverse_exact(params, cfg, k, h)
            h = u[:, ::-1, :] if k % 2 == 1 else u
        x_rec = tarflow.unpatchify(h, cfg)
        np.testing.assert_allclose(np.asarray(x_rec), np.asarray(x), atol=1e-3)


class TestLogdet:
    def test_matches_autodiff_jacobian(self, small):
        cfg, params = small
        cfg2 = cfg._replace(img_hw=4)  # 4 tokens × 12 dims = 48-dim jacobian
        p2 = tarflow.init_params(jax.random.PRNGKey(5), cfg2)
        p2["out_w"] = 0.1 * jax.random.normal(jax.random.PRNGKey(6), p2["out_w"].shape)
        x = jax.random.normal(jax.random.PRNGKey(7), (1, 4, 4, 3))

        def f_flat(xf):
            z, _ = tarflow.flow_forward(p2, cfg2, xf.reshape(1, 4, 4, 3))
            return z.reshape(-1)

        jac = jax.jacfwd(f_flat)(x.reshape(-1))
        _, logdet_num = np.linalg.slogdet(np.asarray(jac))
        _, ld = tarflow.flow_forward(p2, cfg2, x)
        assert abs(float(ld[0]) - logdet_num) < 1e-3


class TestJacobi:
    def test_finite_convergence_within_L(self, small):
        """Prop 3.2: the Jacobi iterate equals the exact solution after at
        most L iterations, and stays there."""
        cfg, params = small
        L = cfg.seq_len
        u = jax.random.normal(jax.random.PRNGKey(8), (1, L, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 1, u)
        z = jnp.zeros_like(v)
        for _ in range(L):
            z, _ = tarflow.block_jacobi_step(params, cfg, 1, z, v, 0, use_pallas=False)
        np.testing.assert_allclose(np.asarray(z), np.asarray(u), atol=1e-4)
        z2, resid = tarflow.block_jacobi_step(params, cfg, 1, z, v, 0, use_pallas=False)
        assert float(resid.max()) < 1e-4  # stays at the fixed point

    def test_prefix_exactness_grows(self, small):
        """After t iterations the first t+1 tokens are exact (the induction
        in Prop 3.2's proof)."""
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(9), (1, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 0, u)
        z = jnp.zeros_like(v)
        for t in range(1, 6):
            z, _ = tarflow.block_jacobi_step(params, cfg, 0, z, v, 0, use_pallas=False)
            np.testing.assert_allclose(
                np.asarray(z)[:, :t], np.asarray(u)[:, :t], atol=1e-4,
                err_msg=f"prefix of length {t} not exact after {t} iterations")

    def test_residual_decreases(self, small):
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(10), (1, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 2, u)
        z = jnp.zeros_like(v)
        resids = []
        for _ in range(16):
            z, r = tarflow.block_jacobi_step(params, cfg, 2, z, v, 0, use_pallas=False)
            resids.append(float(r.max()))
        # Overall downward trend (L = 16 here, so 16 iterations are exact by
        # Prop 3.2; a randomly-initialized flow converges non-monotonically,
        # unlike the trained flows in the paper's Fig 4).
        assert resids[-1] < resids[0] / 50.0, resids

    def test_pallas_and_ref_paths_agree(self, small):
        cfg, params = small
        z = jax.random.normal(jax.random.PRNGKey(11), (2, cfg.seq_len, cfg.token_dim))
        y = jax.random.normal(jax.random.PRNGKey(12), (2, cfg.seq_len, cfg.token_dim))
        zp, rp = tarflow.block_jacobi_step(params, cfg, 0, z, y, 0, use_pallas=True)
        zr, rr = tarflow.block_jacobi_step(params, cfg, 0, z, y, 0, use_pallas=False)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=1e-4)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rr), atol=1e-4)


class TestGsJacobi:
    """Windowed GS-Jacobi sweep: Gauss–Seidel across windows, Jacobi inside —
    exact after `wlen` iterations per window (Prop 3.2 per window), mirroring
    the rust driver `gs_jacobi_decode_block_v`."""

    def _gs_sweep(self, params, cfg, k, v, windows, use_pallas=False):
        L = cfg.seq_len
        base, rem = divmod(L, windows)
        z = jnp.zeros_like(v)
        off = 0
        for w in range(windows):
            wlen = base + (1 if w < rem else 0)
            for _ in range(wlen):
                z, _ = tarflow.block_jacobi_step_window(
                    params, cfg, k, z, v, off, wlen, use_pallas=use_pallas)
            off += wlen
        return z

    @pytest.mark.parametrize("windows", [1, 2, 3, 16])
    def test_gs_sweep_is_exact(self, small, windows):
        """Every window count (incl. non-divisible 3 for L=16 and the W=L
        sequential-equivalent extreme) reproduces the exact inverse."""
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(20), (1, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 1, u)
        z = self._gs_sweep(params, cfg, 1, v, windows)
        np.testing.assert_allclose(np.asarray(z), np.asarray(u), atol=1e-4)

    def test_gs_matches_full_jacobi_bitwise(self, small):
        """W=1 GS-Jacobi and plain Jacobi run the same arithmetic: after L
        full-window iterations the iterates must agree exactly."""
        cfg, params = small
        L = cfg.seq_len
        u = jax.random.normal(jax.random.PRNGKey(21), (1, L, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 0, u)
        z_gs = self._gs_sweep(params, cfg, 0, v, 1)
        z = jnp.zeros_like(v)
        for _ in range(L):
            z, _ = tarflow.block_jacobi_step(params, cfg, 0, z, v, 0, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(z_gs), np.asarray(z))

    def test_windowed_residual_ignores_frozen_prefix(self, small):
        """At the fixed point of a window, the windowed residual is ~0 even
        though later (untouched) positions are far from converged."""
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(22), (1, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 2, u)
        wlen = 4
        z = jnp.zeros_like(v)
        for _ in range(wlen):
            z, r = tarflow.block_jacobi_step_window(
                params, cfg, 2, z, v, 0, wlen, use_pallas=False)
        _, r = tarflow.block_jacobi_step_window(
            params, cfg, 2, z, v, 0, wlen, use_pallas=False)
        assert float(r.max()) < 1e-4
        # The suffix is still the zero init, far from the solution.
        assert float(jnp.abs(z[:, wlen:] - u[:, wlen:]).max()) > 1e-2

    def test_pallas_and_ref_paths_agree(self, small):
        cfg, params = small
        z = jax.random.normal(jax.random.PRNGKey(23), (2, cfg.seq_len, cfg.token_dim))
        y = jax.random.normal(jax.random.PRNGKey(24), (2, cfg.seq_len, cfg.token_dim))
        zp, rp = tarflow.block_jacobi_step_window(params, cfg, 0, z, y, 4, 6, use_pallas=True)
        zr, rr = tarflow.block_jacobi_step_window(params, cfg, 0, z, y, 4, 6, use_pallas=False)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=1e-4)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rr), atol=1e-4)


class TestJacobiFused:
    """Fused multi-step Jacobi (`block_jacobi_multi_step[_window]`): one
    lax.fori_loop program must reproduce the per-step iteration exactly and
    record the per-iteration residual history the rust chunk scheduler scans
    (`jacobi_decode_block_fused_v`)."""

    def test_matches_repeated_single_steps(self, small):
        cfg, params = small
        s_max = 8
        u = jax.random.normal(jax.random.PRNGKey(40), (2, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 1, u)
        for steps in (1, 3, s_max):
            z_f, hist = tarflow.block_jacobi_multi_step(
                params, cfg, 1, jnp.zeros_like(v), v, steps, s_max,
                use_pallas=False)
            z = jnp.zeros_like(v)
            for i in range(steps):
                z, r = tarflow.block_jacobi_step(params, cfg, 1, z, v, 0,
                                                 use_pallas=False)
                np.testing.assert_allclose(
                    np.asarray(hist)[i], np.asarray(r), atol=1e-5,
                    err_msg=f"residual history row {i} (steps={steps})")
            np.testing.assert_allclose(np.asarray(z_f), np.asarray(z), atol=1e-5)

    def test_sentinel_rows_and_clamping(self, small):
        cfg, params = small
        s_max = 4
        y = jax.random.normal(jax.random.PRNGKey(41), (1, cfg.seq_len, cfg.token_dim))
        z0 = jnp.zeros_like(y)
        # Rows past `steps` keep the −1 "not run" sentinel.
        _, hist = tarflow.block_jacobi_multi_step(
            params, cfg, 0, z0, y, 2, s_max, use_pallas=False)
        assert np.all(np.asarray(hist)[:2] >= 0.0)
        assert np.all(np.asarray(hist)[2:] == -1.0)
        # steps = 0 is the identity; steps > s_max clamps to s_max.
        z_id, hist0 = tarflow.block_jacobi_multi_step(
            params, cfg, 0, z0, y, 0, s_max, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(z_id), np.asarray(z0))
        assert np.all(np.asarray(hist0) == -1.0)
        z_a, hist_a = tarflow.block_jacobi_multi_step(
            params, cfg, 0, z0, y, s_max + 5, s_max, use_pallas=False)
        z_b, hist_b = tarflow.block_jacobi_multi_step(
            params, cfg, 0, z0, y, s_max, s_max, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(z_a), np.asarray(z_b))
        np.testing.assert_array_equal(np.asarray(hist_a), np.asarray(hist_b))

    def test_windowed_matches_repeated_window_steps(self, small):
        cfg, params = small
        s_max = 8
        off, wlen = 4, 6
        u = jax.random.normal(jax.random.PRNGKey(42), (2, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 2, u)
        steps = 4
        z_f, hist = tarflow.block_jacobi_multi_step_window(
            params, cfg, 2, jnp.zeros_like(v), v, steps, off, wlen, s_max,
            use_pallas=False)
        z = jnp.zeros_like(v)
        for i in range(steps):
            z, r = tarflow.block_jacobi_step_window(
                params, cfg, 2, z, v, off, wlen, use_pallas=False)
            np.testing.assert_allclose(np.asarray(hist)[i], np.asarray(r), atol=1e-5)
        np.testing.assert_allclose(np.asarray(z_f), np.asarray(z), atol=1e-5)
        # Positions outside the window never moved.
        np.testing.assert_array_equal(np.asarray(z_f)[:, :off], 0.0)
        np.testing.assert_array_equal(np.asarray(z_f)[:, off + wlen:], 0.0)

    def test_chunked_sweep_equals_per_step_at_tau0(self, small):
        """Chunks summing to L reproduce the full L-step sweep (the τ=0
        bit-exactness contract the rust mock-ledger test pins end to end)."""
        cfg, params = small
        L = cfg.seq_len
        s_max = 8
        u = jax.random.normal(jax.random.PRNGKey(43), (1, L, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 0, u)
        z = jnp.zeros_like(v)
        done = 0
        while done < L:
            chunk = min(s_max, L - done)
            z, _ = tarflow.block_jacobi_multi_step(
                params, cfg, 0, z, v, chunk, s_max, use_pallas=False)
            done += chunk
        np.testing.assert_allclose(np.asarray(z), np.asarray(u), atol=1e-4)


class TestInitProj:
    """Speculative-init projection: a cheap z⁰ predictor whose only
    correctness obligation is that the exact Jacobi iteration started from
    it reaches the same fixed point (Prop 3.2 from any z⁰)."""

    def test_pallas_and_ref_paths_agree(self, small):
        cfg, params = small
        y = jax.random.normal(jax.random.PRNGKey(50), (2, cfg.seq_len, cfg.token_dim))
        zp = tarflow.block_init_proj(params, cfg, 1, y, use_pallas=True)
        zr = tarflow.block_init_proj(params, cfg, 1, y, use_pallas=False)
        np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=1e-4)

    def test_first_token_passthrough(self, small):
        cfg, params = small
        y = jax.random.normal(jax.random.PRNGKey(51), (1, cfg.seq_len, cfg.token_dim))
        z0 = tarflow.block_init_proj(params, cfg, 0, y, use_pallas=False)
        np.testing.assert_allclose(np.asarray(z0)[:, 0], np.asarray(y)[:, 0], atol=1e-6)

    def test_jacobi_from_prediction_reaches_exact_inverse(self, small):
        """L Jacobi steps from the predicted z⁰ land on the same solution as
        from zeros — the seed can never change the decoded output at τ=0."""
        cfg, params = small
        L = cfg.seq_len
        u = jax.random.normal(jax.random.PRNGKey(52), (1, L, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 1, u)
        z = tarflow.block_init_proj(params, cfg, 1, v, use_pallas=False)
        for _ in range(L):
            z, _ = tarflow.block_jacobi_step(params, cfg, 1, z, v, 0, use_pallas=False)
        np.testing.assert_allclose(np.asarray(z), np.asarray(u), atol=1e-4)

    def test_prediction_beats_zeros_on_first_residual(self, small):
        """The point of the provider: the first exact Jacobi step from the
        prediction should see a smaller residual than from the zero init
        (the conditioner shares the in/out projections with the exact net)."""
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(53), (2, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 2, u)
        z0 = tarflow.block_init_proj(params, cfg, 2, v, use_pallas=False)
        _, r_pred = tarflow.block_jacobi_step(params, cfg, 2, z0, v, 0, use_pallas=False)
        _, r_zero = tarflow.block_jacobi_step(
            params, cfg, 2, jnp.zeros_like(v), v, 0, use_pallas=False)
        assert float(r_pred.max()) < float(r_zero.max())


class TestSeqStep:
    def test_matches_exact_inverse(self, small):
        cfg, params = small
        L, D = cfg.seq_len, cfg.token_dim
        NL, DM = cfg.layers_per_block, cfg.model_dim
        b = 2
        u = jax.random.normal(jax.random.PRNGKey(13), (b, L, D))
        v, _ = tarflow.block_forward(params, cfg, 1, u)
        kv_k = jnp.zeros((NL, b, L, DM))
        kv_v = jnp.zeros((NL, b, L, DM))
        u_prev = jnp.zeros((b, D))
        toks = []
        for pos in range(L):
            u_tok, kv_k, kv_v = tarflow.block_seq_step(
                params, cfg, 1, u_prev, v[:, pos, :], pos, kv_k, kv_v)
            toks.append(u_tok)
            u_prev = u_tok
        u_seq = jnp.stack(toks, axis=1)
        np.testing.assert_allclose(np.asarray(u_seq), np.asarray(u), atol=1e-4)


class TestSeqFull:
    def test_scan_fused_matches_exact_inverse(self, small):
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(16), (2, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 2, u)
        u_fused = tarflow.block_seq_full(params, cfg, 2, v)
        np.testing.assert_allclose(np.asarray(u_fused), np.asarray(u), atol=1e-4)


class TestMaskedRedundancy:
    def test_masked_fixed_point_differs_but_bounded(self, small):
        """eq 6: masking o nearest deps changes the solution, but for a
        smooth flow the deviation stays finite and grows with o."""
        cfg, params = small
        u = jax.random.normal(jax.random.PRNGKey(14), (1, cfg.seq_len, cfg.token_dim))
        v, _ = tarflow.block_forward(params, cfg, 1, u)
        errs = []
        for o in [0, 1, 3]:
            z = jnp.zeros_like(v)
            for _ in range(cfg.seq_len):
                z, _ = tarflow.block_jacobi_step(params, cfg, 1, z, v, o, use_pallas=False)
            errs.append(float(jnp.linalg.norm(z - u)))
        assert errs[0] < 1e-3          # o=0 is exact
        assert errs[1] > errs[0]       # masking introduces deviation
        assert np.isfinite(errs[2])


class TestPatchify:
    def test_roundtrip(self, small):
        cfg, _ = small
        x = jax.random.normal(jax.random.PRNGKey(15), (3, 8, 8, 3))
        t = tarflow.patchify(x, cfg)
        assert t.shape == (3, cfg.seq_len, cfg.token_dim)
        x2 = tarflow.unpatchify(t, cfg)
        np.testing.assert_allclose(np.asarray(x2), np.asarray(x), atol=1e-6)

    def test_token_layout_matches_rust(self, small):
        """Token l = (py, px) raster order; token vector = (dy, dx, c) —
        the exact layout `Sampler::patchify` implements in rust."""
        cfg, _ = small
        x = jnp.arange(8 * 8 * 3, dtype=jnp.float32).reshape(1, 8, 8, 3)
        t = tarflow.patchify(x, cfg)
        # Token 1 is patch (py=0, px=1); its first element is pixel (0, 2, 0).
        assert float(t[0, 1, 0]) == float(x[0, 0, 2, 0])
        # Token at (py=1, px=0) is index gw=4; first element pixel (2, 0, 0).
        assert float(t[0, 4, 0]) == float(x[0, 2, 0, 0])


class TestTraining:
    def test_loss_decreases_quickly(self, small):
        from compile import train as train_mod
        cfg, _ = small
        cfg = cfg._replace(train_steps=30, train_batch=16, dataset="synth10",
                           img_hw=16, model_dim=32, blocks=2, layers_per_block=1)
        log = []
        train_mod.train_tarflow(cfg, loss_log=log, log_every=1000)
        first, last = log[0][1], log[-1][1]
        assert last < first, f"nll did not decrease: {first} -> {last}"
