//! Small dense linear algebra: matmul, symmetric eigendecomposition (cyclic
//! Jacobi rotations), Cholesky. Used by the proxy-FID metric (Fréchet distance
//! needs `tr((Σ₁Σ₂)^{1/2})`, computed via eigendecomposition).

use super::Tensor;
use anyhow::{bail, Result};

/// Dense matmul (M,K)×(K,N) → (M,N). Metrics-path only — model matmuls run
/// inside XLA.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.ndim() != 2 || b.ndim() != 2 || a.shape()[1] != b.shape()[0] {
        bail!("matmul shape mismatch {:?} x {:?}", a.shape(), b.shape());
    }
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a.data()[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data()[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(&[m, n], out)
}

/// Trace of a square matrix.
pub fn trace(a: &Tensor) -> f32 {
    let n = a.shape()[0];
    (0..n).map(|i| a.data()[i * n + i]).sum()
}

/// Symmetric eigendecomposition via the cyclic Jacobi rotation method.
/// Returns (eigenvalues, eigenvectors-as-columns). Input must be symmetric.
pub fn sym_eigen(a: &Tensor, max_sweeps: usize) -> Result<(Vec<f32>, Tensor)> {
    if a.ndim() != 2 || a.shape()[0] != a.shape()[1] {
        bail!("sym_eigen needs square matrix, got {:?}", a.shape());
    }
    let n = a.shape()[0];
    let mut m: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off.sqrt() < 1e-10 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-14 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    let eigvals: Vec<f32> = (0..n).map(|i| m[i * n + i] as f32).collect();
    let eigvecs = Tensor::new(&[n, n], v.into_iter().map(|x| x as f32).collect())?;
    Ok((eigvals, eigvecs))
}

/// Cholesky factor L (lower) of a positive-definite matrix: A = L Lᵀ.
pub fn cholesky(a: &Tensor) -> Result<Tensor> {
    if a.ndim() != 2 || a.shape()[0] != a.shape()[1] {
        bail!("cholesky needs square matrix");
    }
    let n = a.shape()[0];
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.data()[i * n + j] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (s = {s})");
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Tensor::new(&[n, n], l.into_iter().map(|x| x as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_basic() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
        assert!(matmul(&a, &a).is_err());
    }

    #[test]
    fn eigen_of_diagonal() {
        let a = Tensor::new(&[2, 2], vec![3., 0., 0., 1.]).unwrap();
        let (mut vals, _) = sym_eigen(&a, 30).unwrap();
        vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((vals[0] - 1.0).abs() < 1e-5);
        assert!((vals[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn eigen_reconstructs() {
        // Symmetric matrix; check V diag(λ) Vᵀ ≈ A.
        let a = Tensor::new(&[3, 3], vec![4., 1., 0.5, 1., 3., 0.2, 0.5, 0.2, 2.]).unwrap();
        let (vals, vecs) = sym_eigen(&a, 50).unwrap();
        let n = 3;
        let mut recon = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    recon[i * n + j] += vecs.at(&[i, k]) * vals[k] * vecs.at(&[j, k]);
                }
            }
        }
        for (r, o) in recon.iter().zip(a.data()) {
            assert!((r - o).abs() < 1e-4, "{r} vs {o}");
        }
    }

    #[test]
    fn eigen_trace_preserved() {
        let a = Tensor::new(&[3, 3], vec![2., 0.3, 0.1, 0.3, 1.5, 0.2, 0.1, 0.2, 1.0]).unwrap();
        let (vals, _) = sym_eigen(&a, 50).unwrap();
        let tr: f32 = vals.iter().sum();
        assert!((tr - trace(&a)).abs() < 1e-4);
    }

    #[test]
    fn cholesky_roundtrip() {
        // A = L0 L0ᵀ for a chosen L0.
        let l0 = Tensor::new(&[2, 2], vec![2., 0., 1., 1.5]).unwrap();
        let mut a = vec![0.0f32; 4];
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    a[i * 2 + j] += l0.at(&[i, k]) * l0.at(&[j, k]);
                }
            }
        }
        let a = Tensor::new(&[2, 2], a).unwrap();
        let l = cholesky(&a).unwrap();
        for (x, y) in l.data().iter().zip(l0.data()) {
            assert!((x - y).abs() < 1e-5);
        }
        // Non-PD rejected.
        let bad = Tensor::new(&[2, 2], vec![1., 2., 2., 1.]).unwrap();
        assert!(cholesky(&bad).is_err());
    }
}
